"""Batched LM serving with the sharded-vocab head (deploy path, §4.5 analog):
prefill a batch of prompts, then greedy-decode with the rotating KV cache
and the distributed argmax. Works for any decoder-only zoo arch.

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2_370m
"""
import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm_135m")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=24)
    p.add_argument("--gen", type=int, default=12)
    args = p.parse_args()
    from repro.launch.serve import main as serve_main
    return serve_main(["--arch", args.arch, "--reduced",
                       "--batch", str(args.batch),
                       "--prompt-len", str(args.prompt_len),
                       "--gen", str(args.gen)])


if __name__ == "__main__":
    sys.exit(main())
