"""End-to-end driver (deliverable b): the paper's own setting — a CNN
feature extractor (ResNet-family trunk, GroupNorm adaptation) + extreme-
classification head — trained for a few hundred steps on the synthetic SKU
image stream with the hybrid-parallel system. This exercises the FULL paper
pipeline: data-parallel conv trunk, all-gathered features, model-parallel
fc, KNN softmax, DGC on the trunk gradients.

  PYTHONPATH=src python examples/train_sku_cnn.py [--steps 200]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.configs import sku100m_resnet  # noqa: E402
from repro.configs.base import DGCConfig, HeadConfig, TrainConfig  # noqa: E402
from repro.data.synthetic import sku_image_batch  # noqa: E402
from repro.train import hybrid  # noqa: E402
from repro.train.trainer import PaperTrainer  # noqa: E402


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--classes", type=int, default=512)
    p.add_argument("--batch", type=int, default=64)
    args = p.parse_args()

    mesh = hybrid.make_hybrid_mesh()
    model = sku100m_resnet.reduced(args.classes)
    import dataclasses
    model = dataclasses.replace(model, dtype="float32")
    head = HeadConfig(softmax_impl="knn", knn_k=16, knn_kprime=32,
                      active_frac=0.2, rebuild_every=60)
    train = TrainConfig(optimizer="sgd", momentum=0.9,
                        dgc=DGCConfig(enabled=True, sparsity=0.99,
                                      chunk=2048))

    trainer = PaperTrainer(
        model, head, train, mesh,
        lambda t, b: sku_image_batch(t, b, args.classes),
        hw_batch=args.batch, log_every=20,
        lr_fn=lambda t: 0.5 * min(1.0, (t + 1) / 20))
    trainer.run(args.steps, use_fccs_batch=False)
    acc = trainer.evaluate(sku_image_batch(10**6, 256, args.classes))
    print(f"\nfinal accuracy (CNN trunk + KNN softmax + DGC): {acc:.4f}")


if __name__ == "__main__":
    main()
