"""Quickstart: the whole paper system through the ``Experiment`` API.

Trains a 4096-class extreme classifier with hybrid parallelism, the KNN
softmax head (periodic exact-graph refresh), and FCCS batch growth on 8
fake devices, then evaluates AND serves with the deploy-style
nearest-class-weight lookup (§4.5).

Swap ``softmax_impl`` for "full", "selective" or "mach" to train any other
registered head strategy under identical conditions — no other change.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Experiment, ensure_host_devices
from repro.configs.base import DGCConfig, FCCSConfig, HeadConfig, TrainConfig

ensure_host_devices(8)


def main():
    n_classes, batch, steps = 4096, 128, 150

    exp = Experiment.from_config(
        system="paper",
        classes=n_classes,
        feat_dim=64,
        batch=batch,
        head=HeadConfig(softmax_impl="knn", knn_k=16, knn_kprime=32,
                        active_frac=0.1, rebuild_every=50),
        train=TrainConfig(
            optimizer="sgd",
            fccs=FCCSConfig(eta0=5.0, t_warm=15, b0=batch, b_min=batch,
                            b_max=8 * batch, t_ini=40, t_final=150),
            dgc=DGCConfig(enabled=False)),
        log_every=25)

    exp.fit(steps, use_fccs_batch=True)
    acc = exp.evaluate(eval_batch=1024)
    preds = exp.serve(batch=64)
    print(f"\nfinal deploy-style (nearest class weight) accuracy: {acc:.4f}")
    print(f"serve() returned {preds.shape[0]} retrieval ids; final batch = "
          f"{exp.trainer.history[-1]['batch']}")


if __name__ == "__main__":
    main()
