"""Quickstart: the whole paper system through the ``Experiment`` API.

Trains a 4096-class extreme classifier with hybrid parallelism, the KNN
softmax head (periodic exact-graph refresh), and FCCS batch growth on 8
fake devices, then evaluates AND serves with the deploy-style
nearest-class-weight lookup (§4.5).

``HeadConfig.softmax_impl`` picks the output-layer strategy — any of the
six registered heads trains through the SAME trainer with no other change:

    softmax_impl="full"       exact distributed softmax (paper baseline)
    softmax_impl="knn"        KNN softmax, the paper's contribution (§3.2)
    softmax_impl="selective"  LSH active classes [Zhang et al., AAAI'18]
    softmax_impl="mach"       hashed bucket softmaxes [Medini et al.'19]
    softmax_impl="sampled"    logQ-corrected negative sampling [Jean'15]
    softmax_impl="csoft"      count-min sketch, min-decode

Swap ``system="paper"`` for ``system="zoo"`` (plus an ``arch=...``) to
train the same heads under the GSPMD zoo trainer — the head registry is the
single seam between the two systems (docs/architecture.md).

Run me:             PYTHONPATH=src python examples/quickstart.py
Pre-merge gate:     bash scripts/smoke.sh   (all six heads on both systems)
"""
from repro.api import Experiment, ensure_host_devices
from repro.configs.base import DGCConfig, FCCSConfig, HeadConfig, TrainConfig

ensure_host_devices(8)

# any registered head; see the table in the module docstring / docs/heads.md
SOFTMAX_IMPL = "knn"


def main():
    n_classes, batch, steps = 4096, 128, 150

    exp = Experiment.from_config(
        system="paper",
        classes=n_classes,
        feat_dim=64,
        batch=batch,
        head=HeadConfig(softmax_impl=SOFTMAX_IMPL, knn_k=16, knn_kprime=32,
                        active_frac=0.1, rebuild_every=50,
                        sampled_n=n_classes // 10, csoft_b=256, csoft_r=4),
        train=TrainConfig(
            optimizer="sgd",
            fccs=FCCSConfig(eta0=5.0, t_warm=15, b0=batch, b_min=batch,
                            b_max=8 * batch, t_ini=40, t_final=150),
            dgc=DGCConfig(enabled=False)),
        log_every=25)

    exp.fit(steps, use_fccs_batch=True)
    acc = exp.evaluate(eval_batch=1024)
    preds = exp.serve(batch=64)
    print(f"\nfinal deploy-style (nearest class weight) accuracy: {acc:.4f}")
    print(f"serve() returned {preds.shape[0]} retrieval ids; final batch = "
          f"{exp.trainer.history[-1]['batch']}")


if __name__ == "__main__":
    main()
