"""Quickstart: train a 4096-class extreme classifier with the paper's full
system — hybrid parallelism, KNN softmax, DGC sparsification, FCCS — on 8
fake devices, then evaluate with the deploy-style nearest-class lookup.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,  # noqa: E402
                                ModelConfig, TrainConfig)
from repro.data.synthetic import (ClassificationStream,  # noqa: E402
                                  sku_feature_batch)
from repro.train import hybrid  # noqa: E402
from repro.train.trainer import PaperTrainer  # noqa: E402


def main():
    n_classes, d, batch = 4096, 64, 128
    steps = 150

    stream = ClassificationStream(n_classes, d, seed=0)
    mesh = hybrid.make_hybrid_mesh()

    model = ModelConfig(name="quickstart", family="feats", n_layers=0,
                        d_model=d, n_heads=0, n_kv_heads=0, d_ff=0,
                        vocab_size=n_classes, dtype="float32")
    head = HeadConfig(softmax_impl="knn", knn_k=16, knn_kprime=32,
                      active_frac=0.1, rebuild_every=50)
    fccs = FCCSConfig(eta0=5.0, t_warm=15, b0=batch, b_min=batch,
                      b_max=8 * batch, t_ini=40, t_final=150)
    train = TrainConfig(optimizer="sgd", fccs=fccs,
                        dgc=DGCConfig(enabled=False))

    trainer = PaperTrainer(model, head, train, mesh,
                           lambda t, b: sku_feature_batch(t, b, stream),
                           hw_batch=batch, use_knn=True, log_every=25)
    trainer.run(steps, use_fccs_batch=True)
    acc = trainer.evaluate(sku_feature_batch(10**6, 1024, stream))
    print(f"\nfinal deploy-style (nearest class weight) accuracy: {acc:.4f}")
    print(f"graph rebuilds took the place of LR decay; final batch = "
          f"{trainer.history[-1]['batch']}")


if __name__ == "__main__":
    main()
