"""Paper Table 4: training speedup from the communication strategy
(+overlapping: 1.042-1.054x; +layer-wise sparsification: 1.123-1.162x).

CPU fake devices cannot show real overlap (no async ICI), so this benchmark
reports (a) measured step times for the three configurations and (b) the
paper-style model: per-step wire bytes from the trainer's own accounting,
converted to comm seconds on the paper's 25 Gbit network and combined with
the measured compute time — the same accounting the paper's table reflects.

The wire-byte accounting is now AUDITED against the compiled step: the
analytic ``repro.telemetry`` comm ledger charges the softmax-completion
collectives, the remainder of the compiled HLO's all-reduce bytes is the
gradient exchange, and the run FAILS LOUDLY when that measured exchange
diverges from the trainer's own ``comm_dense_bytes`` metric by >10%.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import row, timeit
from repro.configs.base import DGCConfig, HeadConfig, TrainConfig
from repro.data.synthetic import lm_batch
from repro.roofline.hlo import analyze as hlo_analyze
from repro.telemetry import train_step_ledger
from repro.train import hybrid
from tests.conftest import reduced_cfg

NET_BYTES_PER_S = 25e9 / 8  # paper: 25 Gbit Ethernet
LEDGER_RTOL = 0.10          # measured-vs-accounted divergence that FAILS


def run(quick: bool = False):
    cfg = dataclasses.replace(reduced_cfg("smollm_135m"),
                              tie_embeddings=False)
    B, S = (32, 32) if quick else (64, 64)
    mesh = hybrid.make_hybrid_mesh(8)
    hcfg = HeadConfig()
    variants = {
        "baseline": dict(n_micro=1, dgc=DGCConfig(enabled=False)),
        "overlap": dict(n_micro=4, dgc=DGCConfig(enabled=False)),
        "overlap_sparsify": dict(n_micro=4, dgc=DGCConfig(
            enabled=True, sparsity=0.99, chunk=2048)),
    }
    out = {}
    with jax.set_mesh(mesh):
        for name, v in variants.items():
            tcfg = TrainConfig(optimizer="sgd", dgc=v["dgc"])
            state = hybrid.init_state(jax.random.PRNGKey(0), cfg, hcfg,
                                      tcfg, 8)
            step = hybrid.make_train_step(cfg, hcfg, tcfg, mesh,
                                          n_micro=v["n_micro"],
                                          state_template=state)
            inputs = lm_batch(0, B, S, cfg.vocab_size)
            t = timeit(lambda: step(state, inputs, 0.1),
                       n=5 if quick else 10)
            _, _, metrics = step(state, inputs, 0.1)
            wire = float(metrics["comm_wire_bytes"]) or \
                float(metrics["comm_dense_bytes"])
            out[name] = {"t": t, "wire": wire}
            row(f"table4/{name}_measured", t * 1e6,
                f"wire_bytes={wire:.0f}")

            # audit the accounting against the compiled step: the analytic
            # repro.telemetry ledger charges the softmax-completion terms;
            # the remainder of the HLO's all-reduce bytes IS the gradient
            # exchange, and it must agree with the trainer's own
            # comm_dense_bytes metric
            fe_param_count = sum(
                leaf.size for leaf in jax.tree.leaves(state.fe_params))
            led = train_step_ledger(
                n_dev=8, rows=B * S, feat_dim=cfg.d_model, head="full",
                backend="ref", n_micro=v["n_micro"],
                fe_param_count=fe_param_count)
            coll = hlo_analyze(
                step.lower(state, inputs, 0.1).compile().as_text()
            ).collectives
            ce_bytes = sum(e.bytes for e in led.entries
                           if e.kind == "all-reduce"
                           and e.label != "fe_grad_exchange")
            measured = coll.get("all-reduce", {}).get("bytes", 0.0) - ce_bytes
            dense = float(metrics["comm_dense_bytes"])
            rel = abs(measured - dense) / max(measured, dense, 1.0)
            out[name]["exchange_bytes_measured"] = measured
            out[name]["exchange_bytes_accounted"] = dense
            row(f"table4/{name}_ledger", 0.0,
                f"exchange_measured={measured:.0f} accounted={dense:.0f} "
                f"rel={rel:.1%} ledger_total={led.total_bytes():.0f}")
            if rel > LEDGER_RTOL:
                raise RuntimeError(
                    f"table4/{name}: measured gradient-exchange bytes "
                    f"{measured:.0f} diverge from the trainer's accounting "
                    f"{dense:.0f} by {rel:.1%} (> {LEDGER_RTOL:.0%})")
            divergence = led.compare(coll, rtol=LEDGER_RTOL)
            if divergence:
                raise RuntimeError(
                    f"table4/{name}: comm ledger vs compiled HLO: "
                    f"{divergence}")

    # paper-regime projection. CPU fake devices can't exhibit async-ICI
    # overlap, so we model the paper's cluster: comm is ~15% of a step for
    # ResNet-50 @ 25 Gbit (consistent with the paper's 12-16% total win),
    # the micro-batch pipeline overlaps ~30% of it (Fig. 4b), and DGC cuts
    # the wire bytes by the factor we MEASURE from the trainer's accounting.
    comm_share, overlap_hidden = 0.15, 0.30
    wire_cut = out["baseline"]["wire"] / max(out["overlap_sparsify"]["wire"], 1)
    s_overlap = 1.0 / (1 - comm_share * overlap_hidden)
    s_sparse = 1.0 / ((1 - comm_share)
                      + comm_share * (1 - overlap_hidden) / wire_cut)
    row("table4/projected_overlap_speedup", 0.0,
        f"{s_overlap:.3f}x (paper 1.042-1.054x)")
    row("table4/projected_sparsify_speedup", 0.0,
        f"{s_sparse:.3f}x (paper 1.123-1.162x)")
    row("table4/measured_wire_reduction", 0.0, f"{wire_cut:.0f}x fewer bytes")
    return out


if __name__ == "__main__":
    run(quick=True)
