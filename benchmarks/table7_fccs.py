"""Paper Table 7 / Figs 6-7: fast continuous convergence strategy.

Compares, at identical step budget:
  * FCCS (warmup LR + cosine batch growth via grad accumulation)
  * FCCS without batch-size policy (constant LR, constant batch) — the
    paper's ablation that collapses (68.12% vs 87.40%)
  * piecewise decay (the traditional policy; paper's accuracy reference)
  * Adam (paper: noticeably worse)
and reports accuracy + effective epochs (sample budget) consumed.
"""
from __future__ import annotations

import jax

from benchmarks.common import row
from repro.configs.base import FCCSConfig, HeadConfig, ModelConfig, TrainConfig
from repro.core import fccs
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.train import hybrid
from repro.train.trainer import PaperTrainer


def run(quick: bool = False):
    N, D, B = (1024, 64, 64) if quick else (4096, 64, 128)
    steps = 120 if quick else 500
    eta0 = 4.0
    stream = ClassificationStream(N, D, seed=0)
    mesh = hybrid.make_hybrid_mesh(8)
    mcfg = ModelConfig(name="t7", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       dtype="float32")
    hcfg = HeadConfig()
    fcfg = FCCSConfig(eta0=eta0, t_warm=steps // 10, b0=B, b_min=B,
                      b_max=8 * B, t_ini=steps // 4, t_final=steps)
    data_fn = lambda t, b: sku_feature_batch(t, b, stream)

    def train(name, tcfg, lr_fn=None, use_fccs_batch=False):
        trainer = PaperTrainer(mcfg, hcfg, tcfg, mesh, data_fn, hw_batch=B,
                               lr_fn=lr_fn, log_every=0)
        hist = trainer.run(steps, use_fccs_batch=use_fccs_batch)
        acc = trainer.evaluate(data_fn(10**6, 512))
        samples = sum(h["batch"] for h in hist)
        row(f"table7/{name}", 0.0,
            f"accuracy={acc:.4f} samples={samples} "
            f"final_loss={hist[-1]['loss']:.3f}")
        return acc

    accs = {}
    accs["fccs"] = train("fccs", TrainConfig(optimizer="sgd", fccs=fcfg),
                         use_fccs_batch=True)
    accs["fccs_no_batch_policy"] = train(
        "fccs_no_batch_policy", TrainConfig(optimizer="sgd", fccs=fcfg),
        use_fccs_batch=False)
    accs["piecewise"] = train(
        "piecewise_decay", TrainConfig(optimizer="sgd", fccs=fcfg),
        lr_fn=lambda t: fccs.piecewise_decay_lr(
            t, eta0=eta0, steps_per_epoch=max(1, steps // 20)))
    accs["adam"] = train(
        "adam", TrainConfig(optimizer="adam", fccs=fcfg),
        lr_fn=lambda t: 1e-3)

    ok = (accs["fccs"] >= accs["fccs_no_batch_policy"] - 0.01
          and accs["fccs"] >= accs["piecewise"] - 0.08)
    row("table7/claim_fccs_competitive", 0.0, f"holds={ok}")
    return accs


if __name__ == "__main__":
    run(quick=True)
