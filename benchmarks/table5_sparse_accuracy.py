"""Paper Table 5: layer-wise top-k sparsification causes no accuracy loss
(87.43 -> 87.40 at 1M classes etc.).

DGC applies to the data-parallel FE gradients (paper §3.3.2), so this
benchmark trains a real trunk (reduced llama-family LM) on the synthetic LM
stream with and without DGC and compares end-of-training next-token accuracy.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import row
from repro.configs.base import DGCConfig, HeadConfig, TrainConfig
from repro.data.synthetic import lm_batch
from repro.train import hybrid
from tests.conftest import reduced_cfg


def run(quick: bool = False):
    cfg = dataclasses.replace(reduced_cfg("smollm_135m"),
                              tie_embeddings=False)
    B, S = (16, 32) if quick else (32, 64)
    steps = 60 if quick else 300
    mesh = hybrid.make_hybrid_mesh(8)
    hcfg = HeadConfig()
    accs = {}
    wire = {}
    for name, dgc in (
        ("baseline", DGCConfig(enabled=False)),
        ("sparsified_99", DGCConfig(enabled=True, sparsity=0.99,
                                    momentum=0.9, chunk=2048)),
    ):
        tcfg = TrainConfig(optimizer="sgd", dgc=dgc)
        state = hybrid.init_state(jax.random.PRNGKey(0), cfg, hcfg, tcfg, 8)
        step = hybrid.make_train_step(cfg, hcfg, tcfg, mesh,
                                      state_template=state)
        tail = []
        with jax.set_mesh(mesh):
            for t in range(steps):
                state, loss, m = step(state, lm_batch(t, B, S,
                                                      cfg.vocab_size), 0.5)
                if t >= steps - 10:
                    tail.append(float(m["accuracy"]))
        accs[name] = float(np.mean(tail))
        wire[name] = float(m["comm_wire_bytes"]) or \
            float(m["comm_dense_bytes"])
        row(f"table5/{name}", 0.0,
            f"next_token_acc={accs[name]:.4f} wire_bytes={wire[name]:.0f}")
    delta = accs["baseline"] - accs["sparsified_99"]
    row("table5/claim_no_accuracy_loss", 0.0,
        f"delta={delta:+.4f} holds={abs(delta) < 0.05}")
    row("table5/wire_reduction", 0.0,
        f"{wire['baseline'] / max(wire['sparsified_99'], 1):.0f}x")
    return accs


if __name__ == "__main__":
    run(quick=True)
