"""Load-replay benchmark for the ``repro.serving`` tier.

Drives the batched serving engine with a synthetic production-shaped
workload — bursty arrivals (Poisson base + on/off bursts) x Zipfian query
mix — and reports what a serving SLO cares about: p50/p95/p99 request
latency, sustained QPS, micro-batch occupancy, and score-cache hit-rate,
for an uncached and a cached run over the IDENTICAL trace. Appends one
schema-versioned record to ``BENCH_serve.json`` (see
``benchmarks.common.write_bench``) — the repo's serving perf trajectory.

Latency model: arrivals and coalescer deadlines advance a virtual clock;
each micro-batch's compute is measured wall-clock and charged against a
single serial executor (a batch starts when the previous one finishes),
so queueing during bursts shows up in the tail exactly as a busy server.
CPU wall-clock is NOT TPU-representative — the numbers gate regressions
of the serving path, not absolute throughput claims.

``--index ivf`` switches to the exact-vs-IVF leg: the data stream's
clustered class prototypes are installed as the head weights (a converged
cosine head; a random matrix has no cluster structure to index), an
``IVFIndex`` is fit, and the IDENTICAL trace is replayed through the
exact scan and the IVF path. Reports recall@k of IVF against exact, the
latency delta, and the SATURATED scan throughput of both step functions
(full micro-batch, median of repeated timed calls — replay QPS is
arrival-limited, so the sublinear-serving claim is gated on scan_qps).

  PYTHONPATH=src:. python benchmarks/serve_replay.py --classes 4096 \
      --head full [--backend pallas] [--topk 5] [--quick] [--out DIR]
  PYTHONPATH=src:. python benchmarks/serve_replay.py --index ivf [--nprobe N]
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def _run_ivf(quick: bool, *, classes: int, feat_dim: int, head: str,
             backend: str, topk: int, duration: float, pool: int,
             zipf: float, max_batch: int, max_wait_ms: float, nprobe: int,
             seed: int, out_root: str, write: bool) -> dict:
    """Exact-vs-IVF serving leg (see module docstring)."""
    import jax
    import numpy as np
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from benchmarks.common import row, timeit, write_bench
    from repro.api import Experiment
    from repro.configs.base import HeadConfig
    from repro.serving import (TraceConfig, VirtualClock, generate_trace,
                               latency_stats, replay_trace)
    from repro.train import hybrid

    exp = Experiment.from_config(
        system="paper", classes=classes, feat_dim=feat_dim, batch=max_batch,
        head=HeadConfig(softmax_impl=head, backend=backend), log_every=0)
    if not exp.head.params_are_class_weights or not topk:
        raise ValueError("--index ivf needs a W-head and --topk > 0")
    # install CLUSTERED class weights — a stand-in for what a converged
    # cosine head learns (confusable classes share a neighborhood). The
    # quantizer needs real cluster structure: an untrained random matrix
    # would cap recall near nprobe/n_clusters. Offset norm 0.5 around unit
    # centers keeps clusters tight, as trained class embeddings are.
    rng = np.random.default_rng(seed)
    n_cent = max(1, classes // 64)
    centers = rng.standard_normal((n_cent, feat_dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    cls_of = rng.integers(0, n_cent, classes)
    protos = (centers[cls_of]
              + rng.standard_normal((classes, feat_dim)).astype(np.float32)
              * (0.5 / np.sqrt(feat_dim)))
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    protos = protos.astype(np.float32)
    v_pad = exp.state.head_params.shape[0]
    w_host = (np.pad(protos, ((0, v_pad - classes), (0, 0)))
              if v_pad != classes else protos)
    w = jax.device_put(w_host, NamedSharding(exp.mesh, P(hybrid.AXIS, None)))
    exp.trainer.state = exp.trainer.state._replace(head_params=w)

    t0 = time.perf_counter()
    idx = exp.ivf_index(nprobe=nprobe, refit=True)
    fit_s = time.perf_counter() - t0
    np_eff = idx.resolve_nprobe(nprobe or None)
    row("serve/ivf_fit", fit_s * 1e6,
        f"n_clusters={idx.n_clusters} cap={idx.cap} nprobe={np_eff} "
        f"fit_s={fit_s:.2f}")

    tcfg = TraceConfig(duration=duration, pool=pool, zipf_s=zipf, seed=seed)
    times, qids = generate_trace(tcfg)
    # query pool matched to the installed weights: each query targets a
    # class prototype plus small noise (the Zipfian trace stays Zipfian
    # over the pool). make_query_pool draws from the data stream's looser
    # prototypes, which would not match the weights installed above.
    labels = rng.integers(0, classes, pool)
    queries = (protos[labels]
               + rng.standard_normal((pool, feat_dim)).astype(np.float32)
               * (0.1 / np.sqrt(feat_dim))).astype(np.float32)
    full = np.resize(queries, (max_batch, feat_dim)).astype(np.float32)
    runs = {}
    for mode in ("exact", "ivf"):
        clock = VirtualClock()
        eng = exp.serving_engine(
            top_k=topk, max_batch=max_batch, max_wait_ms=max_wait_ms,
            cache=None, clock=clock.now,
            index="ivf" if mode == "ivf" else None, nprobe=nprobe or None)
        eng.warmup(queries[0])
        done = replay_trace(eng, clock, times, qids, queries)
        assert len(done) == len(times), (len(done), len(times))
        lat = latency_stats(done)
        st = eng.stats()
        span = (max(r.t_done for r in done) - min(r.t_submit for r in done)
                if done else 0.0)
        # saturated scan throughput: one full micro-batch, median of
        # repeated timed step calls (the replay itself is arrival-limited)
        step_s = timeit(eng.step_fn, full, max_batch,
                        n=5 if quick else 15, warmup=2)
        runs[mode] = {
            **lat,
            "qps": lat["n"] / span if span > 0 else 0.0,
            "mean_batch_occupancy": st["mean_batch_occupancy"],
            "n_batches": st["n_batches"],
            "compute_s": st["compute_s"],
            "step_s": step_s,
            "scan_qps": max_batch / step_s,
            "results": {r.rid: np.atleast_1d(r.ids) for r in done},
        }
        row(f"serve/{mode}_p99", lat["p99_ms"] * 1e3,
            f"p50_ms={lat['p50_ms']:.2f} p99_ms={lat['p99_ms']:.2f} "
            f"qps={runs[mode]['qps']:.1f} "
            f"scan_qps={runs[mode]['scan_qps']:.1f}")

    res_e, res_i = runs["exact"]["results"], runs["ivf"]["results"]
    recall = float(np.mean([
        len(set(res_e[rid].tolist()) & set(res_i[rid].tolist())) / topk
        for rid in res_e]))
    for r in runs.values():
        r.pop("results")
    speedup = runs["ivf"]["scan_qps"] / runs["exact"]["scan_qps"]
    row("serve/ivf_vs_exact", 0.0,
        f"recall@{topk}={recall:.3f} scan_speedup={speedup:.2f}x "
        f"probed={np_eff}/{idx.n_clusters} clusters")

    payload = {
        "quick": quick,
        "config": {
            "classes": classes, "feat_dim": feat_dim, "head": head,
            "backend": backend, "top_k": topk, "max_batch": max_batch,
            "max_wait_ms": max_wait_ms, "index": "ivf", "nprobe": np_eff,
            "n_clusters": idx.n_clusters, "cap": idx.cap,
            "trace": {"duration": duration, "pool": pool, "zipf_s": zipf,
                      "base_rate": tcfg.base_rate,
                      "burst_rate": tcfg.burst_rate, "seed": seed,
                      "n_requests": int(times.shape[0]),
                      "expected_rate": tcfg.expected_rate},
        },
        "exact": runs["exact"],
        "ivf": runs["ivf"],
        "recall_at_k": recall,
        "speedup_scan": speedup,
        "fit_s": fit_s,
    }
    if write:
        path = write_bench("serve", payload, root=out_root)
        print(f"# BENCH record appended to {path}")
    return payload


def run(quick: bool = False, *, classes: int = None, feat_dim: int = 64,
        head: str = "full", backend: str = "ref", topk: int = 5,
        duration: float = 2.0, pool: int = 256, zipf: float = 1.1,
        max_batch: int = 32, max_wait_ms: float = 2.0,
        cache_capacity: int = 1024, cosine_threshold: float = 0.0,
        seed: int = 0, out_root: str = None, write: bool = True,
        index: str = "none", nprobe: int = 0) -> dict:
    import numpy as np

    from benchmarks.common import row, write_bench
    from repro.api import Experiment
    from repro.configs.base import HeadConfig
    from repro.serving import (ScoreCache, TraceConfig, VirtualClock,
                               generate_trace, latency_stats,
                               make_query_pool, replay_trace)

    use_ivf = index == "ivf"
    if classes is None:
        # the sublinear-serving claim needs a class count where the exact
        # scan actually hurts; the cached-vs-uncached leg doesn't
        classes = 32768 if use_ivf else 4096
    if quick:
        classes = min(classes, 2048 if use_ivf else 256)
        duration = min(duration, 0.4)
        pool = min(pool, 64)
        max_batch = min(max_batch, 8)
    if use_ivf:
        return _run_ivf(quick, classes=classes, feat_dim=feat_dim,
                        head=head, backend=backend, topk=topk,
                        duration=duration, pool=pool, zipf=zipf,
                        max_batch=max_batch, max_wait_ms=max_wait_ms,
                        nprobe=nprobe, seed=seed, out_root=out_root,
                        write=write)

    exp = Experiment.from_config(
        system="paper", classes=classes, feat_dim=feat_dim, batch=max_batch,
        head=HeadConfig(softmax_impl=head, backend=backend), log_every=0)
    # sketch heads decode greedy (no [V, D] retrieval index to top-k over)
    top_k = topk if (topk and exp.head.params_are_class_weights) else None

    tcfg = TraceConfig(duration=duration, pool=pool, zipf_s=zipf, seed=seed)
    times, qids = generate_trace(tcfg)
    queries = make_query_pool(classes, feat_dim, pool, seed=seed)
    runs = {}
    for mode in ("uncached", "cached"):
        cache = None
        if mode == "cached":
            cache = ScoreCache(cache_capacity,
                               cosine_threshold=cosine_threshold or None)
        clock = VirtualClock()
        eng = exp.serving_engine(top_k=top_k, max_batch=max_batch,
                                 max_wait_ms=max_wait_ms, cache=cache,
                                 clock=clock.now)
        eng.warmup(queries[0])
        done = replay_trace(eng, clock, times, qids, queries)
        assert len(done) == len(times), (len(done), len(times))
        lat = latency_stats(done)
        st = eng.stats()
        span = (max(r.t_done for r in done) - min(r.t_submit for r in done)
                if done else 0.0)
        runs[mode] = {
            **lat,
            "qps": lat["n"] / span if span > 0 else 0.0,
            "mean_batch_occupancy": st["mean_batch_occupancy"],
            "n_batches": st["n_batches"],
            "cache_hit_rate": st["cache_hit_rate"],
            "compute_s": st["compute_s"],
            "results": {r.rid: np.atleast_1d(r.ids) for r in done},
        }
        row(f"serve/{mode}_p99", runs[mode]["p99_ms"] * 1e3,
            f"p50_ms={lat['p50_ms']:.2f} p95_ms={lat['p95_ms']:.2f} "
            f"p99_ms={lat['p99_ms']:.2f} qps={runs[mode]['qps']:.1f} "
            f"occupancy={st['mean_batch_occupancy']:.2f} "
            f"hit_rate={st['cache_hit_rate']:.2f}")

    # the exact-match cache must not change results: cached-run answers are
    # bitwise-equal to the uncached run over the identical trace (cosine
    # hits deliberately trade exactness and are exempt)
    if not cosine_threshold:
        res_u, res_c = runs["uncached"]["results"], runs["cached"]["results"]
        same = all((res_u[rid] == res_c[rid]).all() for rid in res_u)
        row("serve/cache_consistency", 0.0, f"cached_equals_uncached={same}")
        assert same, "cache returned different ids than fresh computation"
    for r in runs.values():
        r.pop("results")

    payload = {
        "quick": quick,
        "config": {
            "classes": classes, "feat_dim": feat_dim, "head": head,
            "backend": backend, "top_k": top_k, "max_batch": max_batch,
            "max_wait_ms": max_wait_ms, "cache_capacity": cache_capacity,
            "cosine_threshold": cosine_threshold or None,
            "trace": {"duration": duration, "pool": pool, "zipf_s": zipf,
                      "base_rate": tcfg.base_rate,
                      "burst_rate": tcfg.burst_rate, "seed": seed,
                      "n_requests": int(times.shape[0]),
                      "expected_rate": tcfg.expected_rate},
        },
        "uncached": runs["uncached"],
        "cached": runs["cached"],
    }
    if write:
        path = write_bench("serve", payload, root=out_root)
        print(f"# BENCH record appended to {path}")
    return payload


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced sizes (CI / smoke)")
    p.add_argument("--classes", type=int, default=None,
                   help="class count (default 4096; 32768 with --index ivf)")
    p.add_argument("--index", choices=["none", "ivf"], default="none",
                   help="'ivf' runs the exact-vs-IVF leg: recall@k, "
                        "latency delta, saturated scan QPS of both paths")
    p.add_argument("--nprobe", type=int, default=0,
                   help="--index ivf: centroids probed per shard "
                        "(0 = index default, max(2, n_clusters/32))")
    p.add_argument("--feat-dim", type=int, default=64)
    p.add_argument("--head", default="full",
                   choices=["full", "knn", "selective", "mach", "sampled",
                            "csoft"])
    p.add_argument("--backend", choices=["ref", "pallas"], default="ref")
    p.add_argument("--topk", type=int, default=5,
                   help="0 = greedy argmax serving")
    p.add_argument("--duration", type=float, default=2.0,
                   help="virtual seconds of trace")
    p.add_argument("--pool", type=int, default=256,
                   help="distinct queries in the Zipfian mix")
    p.add_argument("--zipf", type=float, default=1.1)
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--max-wait-ms", type=float, default=2.0)
    p.add_argument("--cache-capacity", type=int, default=1024)
    p.add_argument("--cosine-threshold", type=float, default=0.0,
                   help="accept near-duplicate cached queries at this "
                        "cosine similarity (0 = exact-match only)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None, metavar="DIR",
                   help="directory for BENCH_serve.json (default: repo "
                        "root — the committed trajectory)")
    p.add_argument("--no-write", action="store_true",
                   help="don't append a BENCH record")
    args = p.parse_args(argv)
    # 8 fake devices for the hybrid-parallel mesh (before jax import)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    print("name,us_per_call,derived")
    run(quick=args.quick, classes=args.classes, feat_dim=args.feat_dim,
        head=args.head, backend=args.backend, topk=args.topk,
        duration=args.duration, pool=args.pool, zipf=args.zipf,
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        cache_capacity=args.cache_capacity,
        cosine_threshold=args.cosine_threshold, seed=args.seed,
        out_root=args.out, write=not args.no_write,
        index=args.index, nprobe=args.nprobe)
    return 0


if __name__ == "__main__":
    sys.exit(main())
