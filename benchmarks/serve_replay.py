"""Load-replay benchmark for the ``repro.serving`` tier.

Drives the batched serving engine with a synthetic production-shaped
workload — bursty arrivals (Poisson base + on/off bursts) x Zipfian query
mix — and reports what a serving SLO cares about: p50/p95/p99 request
latency, sustained QPS, micro-batch occupancy, and score-cache hit-rate,
for an uncached and a cached run over the IDENTICAL trace. Appends one
schema-versioned record to ``BENCH_serve.json`` (see
``benchmarks.common.write_bench``) — the repo's serving perf trajectory.

Latency model: arrivals and coalescer deadlines advance a virtual clock;
each micro-batch's compute is measured wall-clock and charged against a
single serial executor (a batch starts when the previous one finishes),
so queueing during bursts shows up in the tail exactly as a busy server.
CPU wall-clock is NOT TPU-representative — the numbers gate regressions
of the serving path, not absolute throughput claims.

  PYTHONPATH=src:. python benchmarks/serve_replay.py --classes 4096 \
      --head full [--backend pallas] [--topk 5] [--quick] [--out DIR]
"""
from __future__ import annotations

import argparse
import os
import sys


def run(quick: bool = False, *, classes: int = 4096, feat_dim: int = 64,
        head: str = "full", backend: str = "ref", topk: int = 5,
        duration: float = 2.0, pool: int = 256, zipf: float = 1.1,
        max_batch: int = 32, max_wait_ms: float = 2.0,
        cache_capacity: int = 1024, cosine_threshold: float = 0.0,
        seed: int = 0, out_root: str = None, write: bool = True) -> dict:
    import numpy as np

    from benchmarks.common import row, write_bench
    from repro.api import Experiment
    from repro.configs.base import HeadConfig
    from repro.serving import (ScoreCache, TraceConfig, VirtualClock,
                               generate_trace, latency_stats,
                               make_query_pool, replay_trace)

    if quick:
        classes = min(classes, 256)
        duration = min(duration, 0.4)
        pool = min(pool, 64)
        max_batch = min(max_batch, 8)

    exp = Experiment.from_config(
        system="paper", classes=classes, feat_dim=feat_dim, batch=max_batch,
        head=HeadConfig(softmax_impl=head, backend=backend), log_every=0)
    # sketch heads decode greedy (no [V, D] retrieval index to top-k over)
    top_k = topk if (topk and exp.head.params_are_class_weights) else None

    tcfg = TraceConfig(duration=duration, pool=pool, zipf_s=zipf, seed=seed)
    times, qids = generate_trace(tcfg)
    queries = make_query_pool(classes, feat_dim, pool, seed=seed)
    runs = {}
    for mode in ("uncached", "cached"):
        cache = None
        if mode == "cached":
            cache = ScoreCache(cache_capacity,
                               cosine_threshold=cosine_threshold or None)
        clock = VirtualClock()
        eng = exp.serving_engine(top_k=top_k, max_batch=max_batch,
                                 max_wait_ms=max_wait_ms, cache=cache,
                                 clock=clock.now)
        eng.warmup(queries[0])
        done = replay_trace(eng, clock, times, qids, queries)
        assert len(done) == len(times), (len(done), len(times))
        lat = latency_stats(done)
        st = eng.stats()
        span = (max(r.t_done for r in done) - min(r.t_submit for r in done)
                if done else 0.0)
        runs[mode] = {
            **lat,
            "qps": lat["n"] / span if span > 0 else 0.0,
            "mean_batch_occupancy": st["mean_batch_occupancy"],
            "n_batches": st["n_batches"],
            "cache_hit_rate": st["cache_hit_rate"],
            "compute_s": st["compute_s"],
            "results": {r.rid: np.atleast_1d(r.ids) for r in done},
        }
        row(f"serve/{mode}_p99", runs[mode]["p99_ms"] * 1e3,
            f"p50_ms={lat['p50_ms']:.2f} p95_ms={lat['p95_ms']:.2f} "
            f"p99_ms={lat['p99_ms']:.2f} qps={runs[mode]['qps']:.1f} "
            f"occupancy={st['mean_batch_occupancy']:.2f} "
            f"hit_rate={st['cache_hit_rate']:.2f}")

    # the exact-match cache must not change results: cached-run answers are
    # bitwise-equal to the uncached run over the identical trace (cosine
    # hits deliberately trade exactness and are exempt)
    if not cosine_threshold:
        res_u, res_c = runs["uncached"]["results"], runs["cached"]["results"]
        same = all((res_u[rid] == res_c[rid]).all() for rid in res_u)
        row("serve/cache_consistency", 0.0, f"cached_equals_uncached={same}")
        assert same, "cache returned different ids than fresh computation"
    for r in runs.values():
        r.pop("results")

    payload = {
        "quick": quick,
        "config": {
            "classes": classes, "feat_dim": feat_dim, "head": head,
            "backend": backend, "top_k": top_k, "max_batch": max_batch,
            "max_wait_ms": max_wait_ms, "cache_capacity": cache_capacity,
            "cosine_threshold": cosine_threshold or None,
            "trace": {"duration": duration, "pool": pool, "zipf_s": zipf,
                      "base_rate": tcfg.base_rate,
                      "burst_rate": tcfg.burst_rate, "seed": seed,
                      "n_requests": int(times.shape[0]),
                      "expected_rate": tcfg.expected_rate},
        },
        "uncached": runs["uncached"],
        "cached": runs["cached"],
    }
    if write:
        path = write_bench("serve", payload, root=out_root)
        print(f"# BENCH record appended to {path}")
    return payload


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced sizes (CI / smoke)")
    p.add_argument("--classes", type=int, default=4096)
    p.add_argument("--feat-dim", type=int, default=64)
    p.add_argument("--head", default="full",
                   choices=["full", "knn", "selective", "mach", "sampled",
                            "csoft"])
    p.add_argument("--backend", choices=["ref", "pallas"], default="ref")
    p.add_argument("--topk", type=int, default=5,
                   help="0 = greedy argmax serving")
    p.add_argument("--duration", type=float, default=2.0,
                   help="virtual seconds of trace")
    p.add_argument("--pool", type=int, default=256,
                   help="distinct queries in the Zipfian mix")
    p.add_argument("--zipf", type=float, default=1.1)
    p.add_argument("--max-batch", type=int, default=32)
    p.add_argument("--max-wait-ms", type=float, default=2.0)
    p.add_argument("--cache-capacity", type=int, default=1024)
    p.add_argument("--cosine-threshold", type=float, default=0.0,
                   help="accept near-duplicate cached queries at this "
                        "cosine similarity (0 = exact-match only)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", default=None, metavar="DIR",
                   help="directory for BENCH_serve.json (default: repo "
                        "root — the committed trajectory)")
    p.add_argument("--no-write", action="store_true",
                   help="don't append a BENCH record")
    args = p.parse_args(argv)
    # 8 fake devices for the hybrid-parallel mesh (before jax import)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    print("name,us_per_call,derived")
    run(quick=args.quick, classes=args.classes, feat_dim=args.feat_dim,
        head=args.head, backend=args.backend, topk=args.topk,
        duration=args.duration, pool=args.pool, zipf=args.zipf,
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        cache_capacity=args.cache_capacity,
        cosine_threshold=args.cosine_threshold, seed=args.seed,
        out_root=args.out, write=not args.no_write)
    return 0


if __name__ == "__main__":
    sys.exit(main())
