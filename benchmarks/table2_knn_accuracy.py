"""Paper Table 2: classification accuracy of softmax variants.

Trains the same extreme-classification head under IDENTICAL conditions with
every registered head strategy — Full softmax, KNN softmax, Selective
softmax (LSH), MACH, Sampled softmax (logQ-corrected negatives), CSoft
count-min sketch — through the one head-agnostic hybrid-parallel trainer
(this is the comparison the paper actually ran, extended with the two
baselines the related work motivates). The claims to validate:
  KNN == Full  >  Selective  >  MACH,  and sampled/csoft slot between.
"""
from __future__ import annotations

import jax

from benchmarks.common import row
from repro.api.heads import make_head
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.train import hybrid

IMPLS = ("full", "knn", "selective", "mach", "sampled", "csoft")
LR = {"full": 5.0, "knn": 5.0, "selective": 5.0, "mach": 0.5,
      "sampled": 5.0, "csoft": 0.5}
NAMES = {"full": "full_softmax", "knn": "knn_softmax",
         "selective": "selective_softmax", "mach": "mach",
         "sampled": "sampled_softmax", "csoft": "csoft_countmin"}


def run(quick: bool = False):
    # Scale note: the paper's lossless condition is M >= |union of label
    # neighborhoods| (their M=10M >= B*K=4.9M at N=100M). At benchmark-scale
    # N/B ratios this requires a larger active fraction than the paper's
    # 10%; we keep the CONDITION, not the constant.
    N, D, B = (1024, 64, 64) if quick else (8192, 64, 128)
    frac = 0.5 if quick else 0.2
    steps = 500 if quick else 800
    stream = ClassificationStream(N, D, seed=0)
    mesh = hybrid.make_hybrid_mesh(8)
    mcfg = ModelConfig(name="t2", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       dtype="float32")
    tcfg = TrainConfig(optimizer="sgd", momentum=0.0)

    results = {}
    for impl in IMPLS:
        hcfg = HeadConfig(softmax_impl=impl, knn_k=16, knn_kprime=32,
                          active_frac=frac,
                          rebuild_every=max(10, steps // 10),
                          mach_b=max(64, N // 16), mach_r=4,
                          sampled_n=max(64, int(N * frac)),
                          csoft_b=max(64, N // 16), csoft_r=4)
        head = make_head(mcfg, hcfg)
        state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg,
                                  8, head=head)
        step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh, head=head,
                                      state_template=state)
        with jax.set_mesh(mesh):
            state = hybrid.refresh_head_state(head, mesh, state)
            for t in range(steps):
                state, loss, m = step(state, sku_feature_batch(t, B, stream),
                                      LR[impl])
                if head.refresh_every and (t + 1) % head.refresh_every == 0:
                    state = hybrid.refresh_head_state(head, mesh, state)
            ev = hybrid.make_eval_step(mcfg, hcfg, mesh, state, head=head)
            results[NAMES[impl]] = float(
                ev(state, sku_feature_batch(10**6, 2048, stream)))

    for name, acc in results.items():
        row(f"table2/{name}", 0.0, f"accuracy={acc:.4f}")
    ok = (abs(results["knn_softmax"] - results["full_softmax"]) < 0.05
          and results["full_softmax"] > results["mach"])
    row("table2/claim_knn_equals_full_gt_mach", 0.0, f"holds={ok}")
    return results


if __name__ == "__main__":
    run(quick=True)
