"""Paper Table 2: classification accuracy of softmax variants.

Trains the same extreme-classification head under identical conditions with
four methods — Full softmax, KNN softmax, Selective softmax (LSH), MACH —
on the synthetic SKU stream. The paper's claims to validate:
  KNN == Full  >  Selective  >  MACH.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.core import baselines as bl
from repro.core.sharded_softmax import ce_ref
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.train import hybrid


def _eval_nearest(w, stream, n=2048):
    f, y = stream.eval_batch(0, n)
    fn = f / jnp.linalg.norm(f, axis=-1, keepdims=True)
    wn = w / (jnp.linalg.norm(w, axis=-1, keepdims=True) + 1e-12)
    pred = jnp.argmax(fn @ wn.T, axis=-1)
    return float(jnp.mean((pred == y).astype(jnp.float32)))


def run(quick: bool = False):
    # Scale note: the paper's lossless condition is M >= |union of label
    # neighborhoods| (their M=10M >= B*K=4.9M at N=100M). At benchmark-scale
    # N/B ratios this requires a larger active fraction than the paper's
    # 10%; we keep the CONDITION, not the constant.
    N, D, B = (1024, 64, 64) if quick else (8192, 64, 128)
    frac = 0.5 if quick else 0.2
    steps = 500 if quick else 800
    lr = 5.0
    stream = ClassificationStream(N, D, seed=0)
    mesh = hybrid.make_hybrid_mesh(8)
    mcfg = ModelConfig(name="t2", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       dtype="float32")
    tcfg = TrainConfig(optimizer="sgd", momentum=0.0)

    results = {}
    # ---- full & knn via the hybrid-parallel trainer ----------------------
    for name, use_knn in (("full_softmax", False), ("knn_softmax", True)):
        hcfg = HeadConfig(knn_k=16, knn_kprime=32, active_frac=frac,
                          rebuild_every=max(10, steps // 10))
        state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8)
        step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh, use_knn=use_knn,
                                      state_template=state)
        graph = hybrid.dummy_graph(8)
        with jax.set_mesh(mesh):
            if use_knn:
                graph = hybrid.rebuild_graph(mesh, state.w_head, k=16,
                                             kprime=32)
            for t in range(steps):
                state, loss, m = step(state, sku_feature_batch(t, B, stream),
                                      graph, lr)
                if use_knn and (t + 1) % hcfg.rebuild_every == 0:
                    graph = hybrid.rebuild_graph(mesh, state.w_head, k=16,
                                                 kprime=32)
        results[name] = _eval_nearest(state.w_head, stream)

    # ---- selective softmax (LSH) -----------------------------------------
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (N, D)) / jnp.sqrt(D)
    m_act = max(64, N // 10)

    @jax.jit
    def sel_step(w, t, tabs_planes, tabs_off, tabs_cls):
        tabs = bl.LSHTables(tabs_planes, tabs_off, tabs_cls)
        f, y = stream.batch(t, B)
        loss, g = jax.value_and_grad(
            lambda w_: bl.selective_softmax_ce(f, y, w_, tabs, m=m_act,
                                               cap=64))(w)
        return w - lr * g

    tabs = bl.build_lsh_tables(jax.random.fold_in(key, 1), w, 4, 8)
    for t in range(steps):
        w = sel_step(w, t, *tabs)
        if (t + 1) % (steps // 3) == 0:  # rebuild tables on fresh weights
            tabs = bl.build_lsh_tables(jax.random.fold_in(key, t), w, 4, 8)
    results["selective_softmax"] = _eval_nearest(w, stream)

    # ---- MACH -------------------------------------------------------------
    head = bl.init_mach(jax.random.PRNGKey(2), N, D,
                        n_buckets=max(64, N // 16), n_rep=4)

    @jax.jit
    def mach_step(wh, t):
        f, y = stream.batch(t, B)
        loss, g = jax.value_and_grad(
            lambda w_: bl.mach_loss(bl.MACHHead(head.hashes, w_), f, y))(wh)
        return wh - 0.5 * g

    wh = head.w
    for t in range(steps):
        wh = mach_step(wh, t)
    f, y = stream.eval_batch(0, 512)
    pred = bl.mach_predict(bl.MACHHead(head.hashes, wh), f)
    results["mach"] = float(jnp.mean((pred == y).astype(jnp.float32)))

    for name, acc in results.items():
        row(f"table2/{name}", 0.0, f"accuracy={acc:.4f}")
    ok = (abs(results["knn_softmax"] - results["full_softmax"]) < 0.05
          and results["full_softmax"] > results["mach"])
    row("table2/claim_knn_equals_full_gt_mach", 0.0, f"holds={ok}")
    return results


if __name__ == "__main__":
    run(quick=True)
