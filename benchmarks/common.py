"""Shared benchmark utilities.

Benchmarks run on 8 fake host devices (set before jax import by run.py).
CPU wall-clock is NOT TPU-representative; each table therefore reports both
measured time and the derived/model quantity the paper's table is about
(accuracy, wire bytes, selection cost, iteration counts).
"""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, n: int = 20, warmup: int = 3):
    """Median wall-clock seconds per call (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
