"""Shared benchmark utilities.

Benchmarks run on 8 fake host devices (set before jax import by run.py).
CPU wall-clock is NOT TPU-representative; each table therefore reports both
measured time and the derived/model quantity the paper's table is about
(accuracy, wire bytes, selection cost, iteration counts).

``write_bench`` is the perf-trajectory seam: benchmarks append
schema-versioned records to ``BENCH_<table>.json`` at the repo root, so
every PR's speed claim can be checked against the records the previous
PRs committed (ROADMAP "start measuring"). The file is a JSON array; each
record carries the schema version, a UTC timestamp, the jax/device
environment, and the benchmark's own payload dict.
"""
from __future__ import annotations

import datetime
import json
import os
import subprocess
import time

import jax

BENCH_SCHEMA = 1
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# process-wide override for where ``write_bench`` appends records (None =
# REPO_ROOT). ``benchmarks/run.py --bench-root`` sets this so pre-merge
# gate runs (scripts/smoke.sh) keep fresh records out of the committed
# trajectory files while still comparing against them.
BENCH_ROOT = None


def set_bench_root(path) -> None:
    global BENCH_ROOT
    BENCH_ROOT = path


def git_rev(root: str = None) -> str:
    """Short git SHA of the tree the benchmark ran in, with a ``-dirty``
    suffix when the working tree is modified; ``"unknown"`` outside a git
    checkout. Stamped onto every BENCH record for traceability."""
    cwd = root or REPO_ROOT
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
        porcelain = subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd,
            capture_output=True, text=True, timeout=10, check=True,
        ).stdout.strip()
        return f"{rev}-dirty" if porcelain else rev
    except Exception:
        return "unknown"


def timeit(fn, *args, n: int = 20, warmup: int = 3):
    """Median wall-clock seconds per call (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def write_bench(name: str, payload: dict, *, root: str = None) -> str:
    """Append one schema-versioned record to ``BENCH_<name>.json``.

    ``payload`` is the benchmark's own result dict (must be
    JSON-serializable). Returns the file path. Records are never
    rewritten — the file is the trajectory, one record per run."""
    path = os.path.join(root or BENCH_ROOT or REPO_ROOT,
                        f"BENCH_{name}.json")
    records = []
    if os.path.exists(path):
        with open(path) as f:
            try:
                records = json.load(f)
            except json.JSONDecodeError as e:
                # refuse to append over a half-written/garbage file, and do
                # NOT touch it — the trajectory history is the deliverable
                raise ValueError(
                    f"{path} is corrupt ({e}); repair or remove it before "
                    f"appending") from e
        if not isinstance(records, list):
            raise ValueError(
                f"{path} is not a BENCH trajectory (expected a JSON array)")
    records.append({
        "schema": BENCH_SCHEMA,
        "table": name,
        "written": datetime.datetime.now(datetime.timezone.utc)
                   .isoformat(timespec="seconds"),
        "git_rev": git_rev(),
        "jax": jax.__version__,
        "platform": jax.devices()[0].platform,
        "n_devices": len(jax.devices()),
        "payload": payload,
    })
    with open(path, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


# ---------------------------------------------------------------------------
# regression gate (benchmarks/run.py --check)
# ---------------------------------------------------------------------------


def _dig(record: dict, dotted: str):
    """Fetch a dotted path ("payload.uncached.p99_ms") out of a record;
    None when any hop is missing."""
    cur = record
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def comparable(prev: dict, new: dict) -> bool:
    """Records are comparable when they measured the same thing on the
    same environment: platform, device count, quick flag, and the
    benchmark's own config block (when it records one) all match."""
    for key in ("platform", "n_devices"):
        if prev.get(key) != new.get(key):
            return False
    for key in ("quick", "config"):
        if _dig(prev, f"payload.{key}") != _dig(new, f"payload.{key}"):
            return False
    return True


def check_regression(prev: dict, new: dict, metrics: dict, *,
                     threshold: float = 0.25) -> list:
    """Compare a fresh record against a committed baseline.

    ``metrics`` maps dotted payload paths to a direction: "lower" = the
    metric is a cost (regression when it grows), "higher" = the metric is
    a score (regression when it shrinks). A trailing ".*" expands over
    the keys of the dict at that path (present in BOTH records). Returns
    a list of human-readable failure strings (empty = no regression
    beyond ``threshold``); non-numeric, missing, or <= 0 baselines are
    skipped — absent legs must not fail the gate."""
    failures = []
    expanded = {}
    for path, direction in metrics.items():
        if path.endswith(".*"):
            base = path[:-2]
            pd, nd = _dig(prev, f"payload.{base}"), _dig(new,
                                                         f"payload.{base}")
            if isinstance(pd, dict) and isinstance(nd, dict):
                for k in pd.keys() & nd.keys():
                    expanded[f"{base}.{k}"] = direction
        else:
            expanded[path] = direction
    for path, direction in expanded.items():
        pv, nv = _dig(prev, f"payload.{path}"), _dig(new, f"payload.{path}")
        if not isinstance(pv, (int, float)) or not isinstance(nv,
                                                              (int, float)):
            continue
        if isinstance(pv, bool) or isinstance(nv, bool) or pv <= 0:
            continue
        delta = (nv - pv) / pv if direction == "lower" else (pv - nv) / pv
        if delta > threshold:
            failures.append(
                f"{path}: {pv:.6g} -> {nv:.6g} "
                f"({'+' if nv >= pv else '-'}{abs(nv - pv) / pv:.0%}, "
                f"{direction} is better, threshold {threshold:.0%})")
    return failures
