"""Shared benchmark utilities.

Benchmarks run on 8 fake host devices (set before jax import by run.py).
CPU wall-clock is NOT TPU-representative; each table therefore reports both
measured time and the derived/model quantity the paper's table is about
(accuracy, wire bytes, selection cost, iteration counts).

``write_bench`` is the perf-trajectory seam: benchmarks append
schema-versioned records to ``BENCH_<table>.json`` at the repo root, so
every PR's speed claim can be checked against the records the previous
PRs committed (ROADMAP "start measuring"). The file is a JSON array; each
record carries the schema version, a UTC timestamp, the jax/device
environment, and the benchmark's own payload dict.
"""
from __future__ import annotations

import datetime
import json
import os
import time

import jax

BENCH_SCHEMA = 1
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def timeit(fn, *args, n: int = 20, warmup: int = 3):
    """Median wall-clock seconds per call (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def write_bench(name: str, payload: dict, *, root: str = None) -> str:
    """Append one schema-versioned record to ``BENCH_<name>.json``.

    ``payload`` is the benchmark's own result dict (must be
    JSON-serializable). Returns the file path. Records are never
    rewritten — the file is the trajectory, one record per run."""
    path = os.path.join(root or REPO_ROOT, f"BENCH_{name}.json")
    records = []
    if os.path.exists(path):
        with open(path) as f:
            try:
                records = json.load(f)
            except json.JSONDecodeError as e:
                # refuse to append over a half-written/garbage file, and do
                # NOT touch it — the trajectory history is the deliverable
                raise ValueError(
                    f"{path} is corrupt ({e}); repair or remove it before "
                    f"appending") from e
        if not isinstance(records, list):
            raise ValueError(
                f"{path} is not a BENCH trajectory (expected a JSON array)")
    records.append({
        "schema": BENCH_SCHEMA,
        "table": name,
        "written": datetime.datetime.now(datetime.timezone.utc)
                   .isoformat(timespec="seconds"),
        "jax": jax.__version__,
        "platform": jax.devices()[0].platform,
        "n_devices": len(jax.devices()),
        "payload": payload,
    })
    with open(path, "w") as f:
        json.dump(records, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
