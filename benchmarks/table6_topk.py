"""Paper Table 6: wall-clock of top-k selection methods (avg of trials).

  for-loop baseline     204.58 ms   (k sequential max+mask sweeps over HBM)
  sampling top-k         83.27 ms   (DGC's approximate selection)
  divide-and-conquer     36.08 ms   (paper's exact method)
  + tensor grouping      11.81 ms

We measure all four on the same gradient-sized tensor. Absolute times are
CPU; the paper's ORDERING and the exactness property (d&c == reference,
sampling != reference) are the claims under test.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import sparsify as sp


def _forloop_topk_threshold(x, k):
    """k sequential max-extractions (the paper's naive baseline)."""
    def body(i, carry):
        vals, cur = carry
        m = jnp.max(cur)
        am = jnp.argmax(cur)
        cur = cur.at[am].set(-jnp.inf)
        vals = vals.at[i].set(m)
        return vals, cur
    vals, _ = jax.lax.fori_loop(0, k, body,
                                (jnp.zeros((k,), x.dtype), x))
    return vals[-1]


def _sampling_topk_threshold(x, k, sample_frac=0.01, seed=0):
    """DGC's sampling selection: threshold from a random subsample
    (approximate — can over/under-select)."""
    n = x.shape[0]
    m = max(k, int(n * sample_frac))
    idx = jax.random.randint(jax.random.PRNGKey(seed), (m,), 0, n)
    sub = x[idx]
    kk = max(1, int(k * m / n))
    vals, _ = jax.lax.top_k(sub, min(kk, m))
    return vals[-1]


def run(quick: bool = False):
    n = 1 << 20 if quick else 1 << 24         # 16M elements (ResNet-50-ish)
    k = max(1, n // 1000)                      # 99.9% sparsity
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (n,)))
    ref_thr = float(sp.topk_threshold_ref(x, k))

    chunk = 65536
    fl = jax.jit(lambda v: _forloop_topk_threshold(v, k))
    sa = jax.jit(lambda v: _sampling_topk_threshold(v, k))
    dc = jax.jit(lambda v: sp.topk_threshold_dc(v, k, chunk=chunk))

    # grouping: LAYER-WISE selection means one small selection per tensor
    # (ResNet-50 has ~160 grad tensors); grouping packs similar-size tensors
    # into one batched selection (paper Fig. 5 right).
    n_parts = 64
    parts = [x[i::n_parts] for i in range(n_parts)]
    kk = max(1, k // n_parts)
    def grouped(vs):
        cat = jnp.concatenate(vs)
        return sp.topk_threshold_dc(cat, kk * n_parts, chunk=chunk)
    gr = jax.jit(grouped)
    def ungrouped(vs):
        return [sp.topk_threshold_dc(v, kk, chunk=chunk) for v in vs]
    ug = jax.jit(ungrouped)

    nrep = 5 if quick else 15
    t_fl = timeit(fl, x, n=max(3, nrep // 3))
    t_sa = timeit(sa, x, n=nrep)
    t_dc = timeit(dc, x, n=nrep)
    t_ug = timeit(ug, parts, n=nrep)
    t_gr = timeit(gr, parts, n=nrep)

    row("table6/forloop", t_fl * 1e6, "exact=True")
    row("table6/sampling", t_sa * 1e6,
        f"exact={abs(float(sa(x)) - ref_thr) < 1e-6}")
    row("table6/divide_conquer", t_dc * 1e6,
        f"exact={abs(float(dc(x)) - ref_thr) < 1e-6}")
    row("table6/layerwise_ungrouped", t_ug * 1e6, "8 tensors separately")
    row("table6/plus_grouping", t_gr * 1e6,
        f"speedup_vs_ungrouped={t_ug / t_gr:.2f}x")
    row("table6/speedup_dc_vs_forloop", 0.0, f"{t_fl / t_dc:.1f}x")
    row("table6/claim_ordering", 0.0,
        f"holds={t_dc < t_fl and t_gr < t_ug}")
    return {"forloop": t_fl, "sampling": t_sa, "dc": t_dc,
            "grouped": t_gr, "ungrouped": t_ug}


if __name__ == "__main__":
    run(quick=True)
