"""Paper Fig. 8 / Table 8: the composed system.

Stacks the methods cumulatively — full softmax baseline -> +KNN softmax ->
+overlap (micro-batch pipeline) -> +sparsification -> +FCCS — and reports
step wall-clock, throughput, and final accuracy, mirroring the paper's
"3.9x throughput, 45 -> 5 days, comparable accuracy" composition.
"""
from __future__ import annotations

import jax

from benchmarks.common import row, timeit
from repro.api.heads import make_head
from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                ModelConfig, TrainConfig)
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.train import hybrid
from repro.train.trainer import PaperTrainer


def run(quick: bool = False):
    N, D, B = (32768, 64, 256) if quick else (65536, 64, 256)
    steps = 100 if quick else 400
    stream = ClassificationStream(N, D, seed=0)
    mesh = hybrid.make_hybrid_mesh(8)
    mcfg = ModelConfig(name="t8", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       dtype="float32")
    stages = [
        ("baseline_full", dict(knn=False, n_micro=1, dgc=False)),
        ("plus_knn", dict(knn=True, n_micro=1, dgc=False)),
        ("plus_overlap", dict(knn=True, n_micro=4, dgc=False)),
        ("plus_sparsify", dict(knn=True, n_micro=4, dgc=True)),
    ]
    base_t = None
    with jax.set_mesh(mesh):
        for name, s in stages:
            hcfg = HeadConfig(softmax_impl="knn" if s["knn"] else "full",
                              knn_k=16, knn_kprime=32, active_frac=0.1)
            tcfg = TrainConfig(optimizer="sgd", dgc=DGCConfig(
                enabled=s["dgc"], sparsity=0.99, chunk=2048))
            head = make_head(mcfg, hcfg)
            state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg,
                                      tcfg, 8, head=head)
            state = hybrid.refresh_head_state(head, mesh, state)
            step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh,
                                          n_micro=s["n_micro"], head=head,
                                          state_template=state)
            inputs = sku_feature_batch(0, B, stream)
            t = timeit(lambda: step(state, inputs, 1.0),
                       n=5 if quick else 10)
            base_t = base_t or t
            row(f"table8/{name}", t * 1e6,
                f"throughput={B / t:.0f}/s speedup={base_t / t:.2f}x")

    # FCCS epoch reduction (paper: 20 -> 8 epochs == 2.5x fewer iterations)
    hcfg = HeadConfig(softmax_impl="knn", knn_k=16, knn_kprime=32,
                      active_frac=0.1)
    fcfg = FCCSConfig(eta0=4.0, t_warm=steps // 10, b0=B, b_min=B,
                      b_max=8 * B, t_ini=steps // 4, t_final=steps)
    tcfg = TrainConfig(optimizer="sgd", fccs=fcfg)
    trainer = PaperTrainer(mcfg, hcfg, tcfg, mesh,
                           lambda t, b: sku_feature_batch(t, b, stream),
                           hw_batch=B, log_every=0)
    hist = trainer.run(steps, use_fccs_batch=True)
    acc = trainer.evaluate(sku_feature_batch(10**6, 512, stream))
    # steps a constant-batch run would need for the same sample budget
    samples = sum(h["batch"] for h in hist)
    equiv_steps = samples // B
    row("table8/fccs_final", 0.0,
        f"accuracy={acc:.4f} steps={steps} equiv_const_batch_steps="
        f"{equiv_steps} iteration_reduction={equiv_steps / steps:.2f}x")
    return acc


if __name__ == "__main__":
    run(quick=True)
