"""Paper Fig. 8 / Table 8: the composed system, end to end.

Stacks the methods cumulatively — full softmax baseline -> +KNN softmax ->
+overlap (micro-batch pipeline) -> +sparsification -> +FCCS — and reports
step wall-clock, throughput, and final accuracy, mirroring the paper's
"3.9x throughput, 45 -> 5 days, comparable accuracy" composition.

This is also the simulated-100M end-to-end dry run (ROADMAP): for every
head x backend it shape-lowers the hybrid train step at the benchmark's
class count AND at the simulated paper scale (2**20 quick / 10**8 full)
via ``repro.launch.dryrun.lower_paper_one`` — no state materialized — and
reports peak memory plus comm volume per step, with the analytic
``repro.telemetry`` comm ledger cross-checked against the compiled HLO.
The whole payload is appended to ``BENCH_table8.json`` — the repo's first
training-side perf trajectory (gated by ``benchmarks/run.py --check``).

  PYTHONPATH=src:. python benchmarks/table8_end2end.py --quick
"""
from __future__ import annotations

if __name__ == "__main__":
    # standalone bootstrap (run.py does this for the driver path): 8 fake
    # host devices BEFORE jax initializes, src/ + repo root on sys.path
    import os
    import sys
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path[:0] = [os.path.join(_root, "src"), _root]

import argparse

import jax

from benchmarks.common import row, timeit, write_bench
from repro.api.heads import make_head
from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                ModelConfig, TrainConfig)
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.launch.dryrun import lower_paper_one
from repro.train import hybrid
from repro.train.trainer import PaperTrainer

SIM_CLASSES_QUICK = 2 ** 20          # simulated "100M" dry-run scale
SIM_CLASSES_FULL = 10 ** 8


def _head_report(classes: int, head: str, backend: str, *, batch: int,
                 feat_dim: int, n_micro: int = 1) -> dict:
    """Peak memory + comm volume for one head x backend at ``classes``,
    from the shape-lowered compiled step (nothing materialized)."""
    r = lower_paper_one(classes=classes, head=head, backend=backend,
                        batch=batch, feat_dim=feat_dim, n_micro=n_micro)
    measured = r["collectives"].get("total_bytes", 0.0)
    return {
        "classes": classes,
        "peak_bytes": (r["memory"]["peak_bytes"]
                       or r["memory"]["argument_bytes"]
                       + r["memory"]["temp_bytes"]),
        "argument_bytes": r["memory"]["argument_bytes"],
        "temp_bytes": r["memory"]["temp_bytes"],
        "comm_bytes_per_step": r["ledger"]["total_bytes"],
        "comm_bytes_measured_hlo": measured,
        "ledger_divergence": r["ledger_divergence"],
        "compile_s": r["compile_s"],
    }


def run(quick: bool = False):
    N, D, B = (32768, 64, 256) if quick else (65536, 64, 256)
    steps = 100 if quick else 400
    stream = ClassificationStream(N, D, seed=0)
    mesh = hybrid.make_hybrid_mesh(8)
    mcfg = ModelConfig(name="t8", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       dtype="float32")
    stages = [
        ("baseline_full", dict(knn=False, n_micro=1, dgc=False)),
        ("plus_knn", dict(knn=True, n_micro=1, dgc=False)),
        ("plus_overlap", dict(knn=True, n_micro=4, dgc=False)),
        ("plus_sparsify", dict(knn=True, n_micro=4, dgc=True)),
    ]
    base_t = None
    stage_out = {}
    throughput = {}
    with jax.set_mesh(mesh):
        for name, s in stages:
            hcfg = HeadConfig(softmax_impl="knn" if s["knn"] else "full",
                              knn_k=16, knn_kprime=32, active_frac=0.1)
            tcfg = TrainConfig(optimizer="sgd", dgc=DGCConfig(
                enabled=s["dgc"], sparsity=0.99, chunk=2048))
            head = make_head(mcfg, hcfg)
            state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg,
                                      tcfg, 8, head=head)
            state = hybrid.refresh_head_state(head, mesh, state)
            step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh,
                                          n_micro=s["n_micro"], head=head,
                                          state_template=state)
            inputs = sku_feature_batch(0, B, stream)
            t = timeit(lambda: step(state, inputs, 1.0),
                       n=5 if quick else 10)
            base_t = base_t or t
            stage_out[name] = {"step_s": t, "throughput_sps": B / t,
                               "speedup": base_t / t}
            throughput[name] = B / t
            row(f"table8/{name}", t * 1e6,
                f"throughput={B / t:.0f}/s speedup={base_t / t:.2f}x")

    # per-head x backend: peak memory + comm volume from the compiled step
    # at the benchmark scale, ledger cross-checked against HLO
    heads = {}
    for h in ("full", "knn"):
        for bk in ("ref", "pallas"):
            rep = _head_report(N, h, bk, batch=B, feat_dim=D)
            key = f"{h}_{bk}"
            # measured wall-clock throughput exists for the timed (ref)
            # stages; pallas legs are lowered/analyzed only
            rep["throughput_sps"] = (throughput.get(
                "baseline_full" if h == "full" else "plus_knn")
                if bk == "ref" else None)
            heads[key] = rep
            if rep["ledger_divergence"]:
                raise RuntimeError(
                    f"table8 comm ledger diverged from compiled HLO for "
                    f"{key}: {rep['ledger_divergence']}")
            row(f"table8/head_{key}", 0.0,
                f"peak_bytes={rep['peak_bytes']} "
                f"comm_bytes_per_step={rep['comm_bytes_per_step']:.0f} "
                f"(hlo {rep['comm_bytes_measured_hlo']:.0f})")

    # simulated-100M dry run: same heads, paper scale, shape-only
    sim_classes = SIM_CLASSES_QUICK if quick else SIM_CLASSES_FULL
    sim = {"classes": sim_classes}
    for h in ("full", "knn"):
        rep = _head_report(sim_classes, h, "ref", batch=B, feat_dim=D)
        sim[h] = rep
        row(f"table8/sim100m_{h}", 0.0,
            f"classes={sim_classes} peak_bytes={rep['peak_bytes']} "
            f"comm_bytes_per_step={rep['comm_bytes_per_step']:.0f} "
            f"compile_s={rep['compile_s']:.1f}")

    # elastic reshard traffic (repro.elastic): analytic dense-head bytes a
    # checkpoint written on the 8-way ring moves when restored onto a
    # shrunk (4) and a grown (16) mesh — the benchmark-side twin of the
    # restore path's measured "reshard.bytes_moved" counter
    from repro.elastic import MeshGeometry, analytic_reshard_ledger
    src_geo = MeshGeometry(n_model=8, n_data=8, n_classes=N)
    reshard = {}
    for n_dst in (4, 16):
        led = analytic_reshard_ledger(
            src_geo, MeshGeometry(n_model=n_dst, n_data=n_dst, n_classes=N),
            row_bytes=D * 4, n_moment_trees=1)
        reshard[f"bytes_moved_8to{n_dst}"] = led.total_bytes()
        row(f"table8/reshard_8to{n_dst}", 0.0,
            f"bytes_moved={led.total_bytes():.0f}")

    # FCCS epoch reduction (paper: 20 -> 8 epochs == 2.5x fewer iterations)
    hcfg = HeadConfig(softmax_impl="knn", knn_k=16, knn_kprime=32,
                      active_frac=0.1)
    fcfg = FCCSConfig(eta0=4.0, t_warm=steps // 10, b0=B, b_min=B,
                      b_max=8 * B, t_ini=steps // 4, t_final=steps)
    tcfg = TrainConfig(optimizer="sgd", fccs=fcfg)
    trainer = PaperTrainer(mcfg, hcfg, tcfg, mesh,
                           lambda t, b: sku_feature_batch(t, b, stream),
                           hw_batch=B, log_every=0)
    hist = trainer.run(steps, use_fccs_batch=True)
    acc = trainer.evaluate(sku_feature_batch(10**6, 512, stream))
    # steps a constant-batch run would need for the same sample budget
    samples = sum(h["batch"] for h in hist)
    equiv_steps = samples // B
    row("table8/fccs_final", 0.0,
        f"accuracy={acc:.4f} steps={steps} equiv_const_batch_steps="
        f"{equiv_steps} iteration_reduction={equiv_steps / steps:.2f}x")

    payload = {
        "quick": quick,
        "config": {"N": N, "D": D, "B": B, "n_dev": 8,
                   "sim_classes": sim_classes},
        "stages": stage_out,
        "throughput_sps": throughput,
        "heads": heads,
        "sim100m": sim,
        "reshard": reshard,
        "fccs": {"accuracy": acc, "steps": steps,
                 "equiv_const_batch_steps": equiv_steps,
                 "iteration_reduction": equiv_steps / steps},
    }
    path = write_bench("table8", payload)
    row("table8/bench_written", 0.0, path)
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
