"""Benchmark driver — one module per paper table. Prints
``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src:. python -m benchmarks.run [--quick] [--only tableN]
      [--check] [--check-threshold 0.25]

``--check`` turns the trajectory files into a regression gate: after each
table runs, its freshly appended ``BENCH_<table>.json`` record is compared
against the most recent COMPARABLE prior record (same platform, device
count, quick flag, and config block) and the driver fails when any gated
metric regressed by more than ``--check-threshold`` (default 25%).
"""
import argparse
import json
import os
import sys

# dotted payload paths gated per table; "lower" = cost, "higher" = score.
# A trailing ".*" expands over the keys of the dict at that path. Tables
# without an entry run ungated (their payloads are derived/model numbers,
# not wall-clock claims).
CHECK_METRICS = {
    "serve": {
        "uncached.compute_s": "lower",
        "uncached.p99_ms": "lower",
        "exact.compute_s": "lower",
        "exact.scan_qps": "higher",
        "ivf.scan_qps": "higher",
        "recall_at_k": "higher",
        "speedup_scan": "higher",
    },
    "table3": {
        "step_s.*": "lower",
        "backend_step_s.*": "lower",
    },
    "table8": {
        "throughput_sps.*": "higher",
        # elastic reshard traffic is analytic (repro.elastic); ".*" only
        # expands over keys present in BOTH records, so baselines written
        # before the entry existed do not fail the gate
        "reshard.*": "lower",
    },
}


def _check_table(name: str, threshold: float, bench_root: str = "") -> list:
    """Compare the just-written record of BENCH_<name>.json against the
    most recent comparable prior record. With ``bench_root`` set (gate
    mode), the fresh record lives in the bench-root copy of the file and
    the baseline is searched in the COMMITTED repo-root trajectory.
    Returns failure strings."""
    from benchmarks.common import REPO_ROOT, check_regression, comparable
    metrics = CHECK_METRICS.get(name)
    if not metrics:
        return []
    fresh_path = os.path.join(bench_root or REPO_ROOT, f"BENCH_{name}.json")
    if not os.path.exists(fresh_path):
        return []
    with open(fresh_path) as f:
        records = json.load(f)
    if bench_root:
        fresh = records[-1]
        base_path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
        if not os.path.exists(base_path):
            print(f"{name}/CHECK,0.0,no committed trajectory to compare "
                  f"against")
            return []
        with open(base_path) as f:
            baselines = json.load(f)
    else:
        if len(records) < 2:
            print(f"{name}/CHECK,0.0,no prior record to compare against")
            return []
        fresh, baselines = records[-1], records[:-1]
    for prev in reversed(baselines):
        if comparable(prev, fresh):
            fails = check_regression(prev, fresh, metrics,
                                     threshold=threshold)
            for msg in fails:
                print(f"{name}/REGRESSION,0.0,{msg}")
            if not fails:
                print(f"{name}/CHECK,0.0,ok vs "
                      f"{prev.get('written', '?')} ({prev.get('git_rev')})")
            return fails
    print(f"{name}/CHECK,0.0,no comparable prior record "
          f"(config/platform changed)")
    return []


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced sizes/steps (CI)")
    p.add_argument("--only", default="",
                   help="comma-separated table names (e.g. table2,table6)")
    p.add_argument("--check", action="store_true",
                   help="fail when a gated metric regresses vs the last "
                        "comparable committed BENCH record")
    p.add_argument("--check-threshold", type=float, default=0.25,
                   help="relative regression tolerance for --check")
    p.add_argument("--bench-root", default="", metavar="DIR",
                   help="append fresh BENCH records under DIR instead of "
                        "the repo root; --check then gates them against "
                        "the committed repo-root trajectories (pre-merge "
                        "mode, used by scripts/smoke.sh)")
    args = p.parse_args(argv)
    if args.check_threshold <= 0:
        p.error(f"--check-threshold must be > 0, got {args.check_threshold}")
    if args.bench_root and not os.path.isdir(args.bench_root):
        p.error(f"--bench-root {args.bench_root} is not a directory")
    # 8 fake devices for the hybrid-parallel benchmarks (before jax import)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    if args.bench_root:
        from benchmarks.common import set_bench_root
        set_bench_root(args.bench_root)

    from benchmarks import (serve_replay, table2_knn_accuracy,
                            table3_knn_throughput, table4_comm,
                            table5_sparse_accuracy, table6_topk, table7_fccs,
                            table8_end2end)
    tables = {
        "table2": table2_knn_accuracy.run,
        "table3": table3_knn_throughput.run,
        "table4": table4_comm.run,
        "table5": table5_sparse_accuracy.run,
        "table6": table6_topk.run,
        "table7": table7_fccs.run,
        "table8": table8_end2end.run,
        "serve": serve_replay.run,
    }
    only = set(args.only.split(",")) if args.only else set(tables)
    print("name,us_per_call,derived")
    regressions = []
    for name, fn in tables.items():
        if name not in only:
            continue
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            raise
        if args.check:
            regressions += _check_table(name, args.check_threshold,
                                        args.bench_root)
    if regressions:
        print(f"check/FAILED,0.0,{len(regressions)} metric(s) regressed "
              f"beyond {args.check_threshold:.0%}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
