"""Benchmark driver — one module per paper table. Prints
``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src:. python -m benchmarks.run [--quick] [--only tableN]
"""
import argparse
import os
import sys


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="reduced sizes/steps (CI)")
    p.add_argument("--only", default="",
                   help="comma-separated table names (e.g. table2,table6)")
    args = p.parse_args(argv)
    # 8 fake devices for the hybrid-parallel benchmarks (before jax import)
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

    from benchmarks import (serve_replay, table2_knn_accuracy,
                            table3_knn_throughput, table4_comm,
                            table5_sparse_accuracy, table6_topk, table7_fccs,
                            table8_end2end)
    tables = {
        "table2": table2_knn_accuracy.run,
        "table3": table3_knn_throughput.run,
        "table4": table4_comm.run,
        "table5": table5_sparse_accuracy.run,
        "table6": table6_topk.run,
        "table7": table7_fccs.run,
        "table8": table8_end2end.run,
        "serve": serve_replay.run,
    }
    only = set(args.only.split(",")) if args.only else set(tables)
    print("name,us_per_call,derived")
    for name, fn in tables.items():
        if name not in only:
            continue
        try:
            fn(quick=args.quick)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}")
            raise
    return 0


if __name__ == "__main__":
    sys.exit(main())
