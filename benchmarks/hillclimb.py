"""§Perf hillclimb driver: lower+compile a (arch x shape) variant with
experiment knobs and print its roofline terms — the measure step of the
hypothesis -> change -> measure -> validate loop (EXPERIMENTS.md §Perf).

  PYTHONPATH=src python -m benchmarks.hillclimb smollm_135m prefill_32k \
      --rules seq=model
  PYTHONPATH=src python -m benchmarks.hillclimb kimi_k2_1t_a32b decode_32k \
      --param-rules expert_mlp=data --no-fsdp-embed
  PYTHONPATH=src python -m benchmarks.hillclimb gemma_2b train_4k --knn
"""
import argparse
import json
import os
import sys


def parse_rules(items):
    out = []
    for it in items:
        k, _, v = it.partition("=")
        if v in ("none", "None", ""):
            out.append((k, None))
        elif "," in v:
            out.append((k, tuple(v.split(","))))
        else:
            out.append((k, v))
    return tuple(out)


def main(argv=None):
    # The 512 fake host devices are a CLI-only concern. Keep the env mutation
    # out of module scope: pytest collection imports this module (for
    # parse_rules), and appending to XLA_FLAGS before jax's backend
    # initializes would silently override the test suite's 8-device setup —
    # 512 CPU device threads on a small host deadlock collective device_get.
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")

    p = argparse.ArgumentParser()
    p.add_argument("arch")
    p.add_argument("shape")
    p.add_argument("--rules", nargs="*", default=[],
                   help="activation rule overrides, e.g. seq=model")
    p.add_argument("--param-rules", nargs="*", default=[])
    p.add_argument("--knn", action="store_true")
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--remat", default="full", choices=["none", "full"])
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--tag", default="")
    p.add_argument("--log", default="perf_iterations.jsonl")
    args = p.parse_args(argv)

    from repro.launch.dryrun import lower_one
    from repro.roofline.analysis import analyze_record

    res = lower_one(args.arch, args.shape, multi_pod=args.multi_pod,
                    use_knn=args.knn, remat=args.remat,
                    extra_rules=parse_rules(args.rules),
                    extra_param_rules=parse_rules(args.param_rules),
                    fsdp=not args.no_fsdp)
    res["tag"] = args.tag or "baseline"
    res["knobs"] = {"rules": args.rules, "param_rules": args.param_rules,
                    "knn": args.knn, "fsdp": not args.no_fsdp,
                    "remat": args.remat}
    row = analyze_record(res)
    print(f"[hillclimb] {args.arch} x {args.shape} [{res['tag']}]")
    print(f"  compute    {row.compute_s:10.3e} s")
    print(f"  memory     {row.memory_s:10.3e} s")
    print(f"  collective {row.collective_s:10.3e} s   dominant={row.dominant}")
    print(f"  useful     {row.useful_ratio:.3f}   peak {row.peak_gib:.1f} "
          f"GiB/dev (fits16G={row.fits})")
    with open(args.log, "a") as f:
        f.write(json.dumps(res) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
