"""Paper Table 3: KNN softmax throughput vs full softmax (1.2x/1.5x/3.5x at
1M/10M/100M classes).

Three views:
  * measured: hybrid-trainer step wall-clock, full vs KNN head, growing N
    (CPU-scale class counts; the softmax-stage share grows with N exactly as
    in the paper, so the speedup trend is reproducible).
  * model: softmax-stage FLOPs ratio N vs (active M + graph amortization) at
    the paper's scales — the paper's own speedup mechanism.
  * backend: per-head hybrid-trainer step wall-clock, ref (XLA) vs pallas
    (fused kernels). NOTE: the container runs Pallas in INTERPRET mode
    (CPU), so these numbers measure the emulation, not TPU silicon — they
    gate correctness/regressions of the routed path, not the speedup claim.
"""
from __future__ import annotations

import jax

from benchmarks.common import row, timeit, write_bench
from repro.api.heads import make_head
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.train import hybrid

ALL_HEADS = ("full", "knn", "selective", "mach", "sampled", "csoft")


def run_backends(quick: bool = False, heads=ALL_HEADS):
    """Ref-vs-pallas (interpret mode) step wall-clock per registry head."""
    N, D, B = (1024, 64, 64) if quick else (4096, 64, 128)
    mesh = hybrid.make_hybrid_mesh(8)
    tcfg = TrainConfig(optimizer="sgd")
    stream = ClassificationStream(N, D, seed=0)
    mcfg = ModelConfig(name="t3b", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       dtype="float32")
    inputs = sku_feature_batch(0, B, stream)
    results = {}
    with jax.set_mesh(mesh):
        for name in heads:
            times = {}
            for backend in ("ref", "pallas"):
                hcfg = HeadConfig(softmax_impl=name, backend=backend,
                                  knn_k=16, knn_kprime=32, active_frac=0.1,
                                  sampled_n=max(64, N // 4))
                head = make_head(mcfg, hcfg)
                state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg,
                                          tcfg, 8, head=head)
                state = hybrid.refresh_head_state(head, mesh, state)
                step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh,
                                              head=head,
                                              state_template=state)
                t = timeit(lambda: step(state, inputs, 1.0),
                           n=3 if quick else 10)
                times[backend] = t
                row(f"table3/backend_{name}_{backend}", t * 1e6,
                    f"images_per_s={B / t:.0f}")
            results[name] = times
            row(f"table3/backend_{name}_ratio", 0.0,
                f"pallas_vs_ref={times['ref'] / times['pallas']:.2f}x "
                f"(interpret mode)")
    return results


def run(quick: bool = False, *, write: bool = True, out_root: str = None):
    sizes = [1024, 32768] if quick else [4096, 32768, 131072]
    D, B = 64, 128
    mesh = hybrid.make_hybrid_mesh(8)
    tcfg = TrainConfig(optimizer="sgd")
    speedups = {}
    step_times = {}
    for N in sizes:
        stream = ClassificationStream(N, D, seed=0)
        mcfg = ModelConfig(name="t3", family="feats", n_layers=0, d_model=D,
                           n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                           dtype="float32")
        times = {}
        with jax.set_mesh(mesh):
            for name in ("full", "knn"):
                hcfg = HeadConfig(softmax_impl=name, knn_k=16, knn_kprime=32,
                                  active_frac=0.1)
                head = make_head(mcfg, hcfg)
                state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg,
                                          tcfg, 8, head=head)
                state = hybrid.refresh_head_state(head, mesh, state)
                step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh,
                                              head=head,
                                              state_template=state)
                inputs = sku_feature_batch(0, B, stream)
                t = timeit(lambda: step(state, inputs, 1.0),
                           n=10 if quick else 20)
                times[name] = t
                row(f"table3/N{N}_{name}", t * 1e6,
                    f"images_per_s={B / t:.0f}")
        step_times[N] = times
        speedups[N] = times["full"] / times["knn"]
        row(f"table3/N{N}_speedup", 0.0, f"knn_vs_full={speedups[N]:.2f}x")

    # paper-scale model: softmax-stage cost ratio = N / (M + rebuild amort.)
    for N, paper_x in ((1_020_250, 1.2), (9_890_866, 1.5), (100_001_020, 3.5)):
        m_active = 0.1 * N
        stage_ratio = N / m_active  # 10x on the softmax stage
        # paper: softmax stage is ~80% of step at 100M, less at 1M
        stage_share = {1_020_250: 0.35, 9_890_866: 0.55,
                       100_001_020: 0.8}[N]
        end2end = 1.0 / ((1 - stage_share) + stage_share / stage_ratio)
        row(f"table3/model_N{N}", 0.0,
            f"modeled={end2end:.2f}x paper={paper_x}x")
    # claim: speedup grows with N
    ks = sorted(speedups)
    row("table3/claim_speedup_grows_with_N", 0.0,
        f"holds={speedups[ks[-1]] >= speedups[ks[0]]}")
    backends = run_backends(quick=quick,
                            heads=("full", "knn") if quick else ALL_HEADS)
    if write:
        write_bench("table3", {
            "quick": quick,
            "step_s": {str(N): t for N, t in step_times.items()},
            "knn_speedup": {str(N): s for N, s in speedups.items()},
            "backend_step_s": {h: t for h, t in backends.items()},
        }, root=out_root)
    return speedups


if __name__ == "__main__":
    run(quick=True)
