from repro.optim.optimizers import (
    Optimizer,
    OptState,
    adam,
    apply_updates,
    lars,
    make_optimizer,
    sgd,
)
from repro.optim.scale import LossScaleState, dynamic_loss_scale, scaled_grads

__all__ = [
    "Optimizer", "OptState", "adam", "apply_updates", "lars",
    "make_optimizer", "sgd", "LossScaleState", "dynamic_loss_scale",
    "scaled_grads",
]
