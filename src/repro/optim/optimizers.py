"""Minimal functional optimizers (no optax in this environment).

API (optax-flavored):
    opt = sgd(momentum=0.9) | lars(...) | adam(...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params, lr)
    params = apply_updates(params, updates)

All states are fp32 (the paper's master-copy discipline); ``lr`` is a traced
scalar so FCCS can drive it per step without recompilation.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, lr) -> (updates, state)


class OptState(NamedTuple):
    step: jax.Array
    mu: Any            # first moment / momentum
    nu: Any = None     # second moment (adam only)


def _zeros_like_tree(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def _wd(g, p, weight_decay):
    g = g.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p.astype(jnp.float32)
    return g


def sgd(momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_zeros_like_tree(params))

    def update(grads, state, params, lr):
        mu = jax.tree.map(
            lambda g, m, p: momentum * m + _wd(g, p, weight_decay),
            grads, state.mu, params)
        if nesterov:
            upd = jax.tree.map(
                lambda g, m, p: -lr * (_wd(g, p, weight_decay) + momentum * m),
                grads, mu, params)
        else:
            upd = jax.tree.map(lambda m: -lr * m, mu)
        return upd, OptState(step=state.step + 1, mu=mu)

    return Optimizer(init, update)


def lars(momentum: float = 0.9, weight_decay: float = 1e-4,
         trust_coef: float = 0.001, eps: float = 1e-9) -> Optimizer:
    """LARS [You et al. '17] — the paper's FCCS local policy (§3.4).
    Per-leaf trust ratio: lr_local = trust * ||w|| / (||g|| + wd*||w||)."""

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_zeros_like_tree(params))

    def update(grads, state, params, lr):
        def new_m(g, m, p):
            g = _wd(g, p, weight_decay)
            pf = p.astype(jnp.float32)
            wn = jnp.linalg.norm(pf)
            gn = jnp.linalg.norm(g)
            trust = jnp.where((wn > 0) & (gn > 0),
                              trust_coef * wn / (gn + eps), 1.0)
            return momentum * m + (lr * trust) * g

        mu = jax.tree.map(new_m, grads, state.mu, params)
        upd = jax.tree.map(lambda m: -m, mu)
        return upd, OptState(step=state.step + 1, mu=mu)

    return Optimizer(init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=_zeros_like_tree(params),
                        nu=_zeros_like_tree(params))

    def update(grads, state, params, lr):
        t = state.step + 1
        c1 = 1 - b1 ** t.astype(jnp.float32)
        c2 = 1 - b2 ** t.astype(jnp.float32)
        mu = jax.tree.map(
            lambda g, m, p: b1 * m + (1 - b1) * _wd(g, p, weight_decay),
            grads, state.mu, params)
        nu = jax.tree.map(
            lambda g, v, p: b2 * v + (1 - b2) * jnp.square(_wd(g, p, weight_decay)),
            grads, state.nu, params)
        upd = jax.tree.map(
            lambda m, v: -lr * (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu)
        return upd, OptState(step=t, mu=mu, nu=nu)

    return Optimizer(init, update)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer == "sgd":
        return sgd(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    if cfg.optimizer == "lars":
        return lars(momentum=cfg.momentum, weight_decay=cfg.weight_decay)
    if cfg.optimizer == "adam":
        return adam(weight_decay=cfg.weight_decay)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
