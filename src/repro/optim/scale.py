"""Mixed-precision loss scaling (paper §3.1 / Micikevicius et al.).

bf16 on TPU does not *require* scaling (fp32 exponent range) but the paper's
fp16 recipe is implemented faithfully and selectable: static scaling
(loss_scale > 0) and dynamic scaling (loss_scale < 0 -> |value| is the
initial scale; grows 2x every ``growth_interval`` good steps, halves on
non-finite grads, skipping that update).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LossScaleState(NamedTuple):
    scale: jax.Array          # fp32 scalar
    good_steps: jax.Array     # int32


def init_loss_scale(initial: float) -> LossScaleState:
    return LossScaleState(scale=jnp.asarray(abs(initial), jnp.float32),
                          good_steps=jnp.zeros((), jnp.int32))


def scaled_grads(loss_fn, params, *args, scale: jax.Array):
    """value_and_grad of ``scale * loss``; grads returned unscaled + finite
    flag. loss_fn must return (loss, aux)."""
    def scaled(p, *a):
        loss, aux = loss_fn(p, *a)
        return loss * scale, (loss, aux)

    (_, (loss, aux)), grads = jax.value_and_grad(scaled, has_aux=True)(
        params, *args)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) / scale, grads)
    finite = jnp.all(jnp.stack([
        jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)]))
    return (loss, aux), grads, finite


def dynamic_loss_scale(state: LossScaleState, finite: jax.Array, *,
                       growth_interval: int = 200, factor: float = 2.0,
                       min_scale: float = 1.0, max_scale: float = 2.0 ** 24):
    """Post-step scale adjustment. Returns (new_state, apply_update_flag)."""
    grown = jnp.where(
        (state.good_steps + 1) >= growth_interval,
        jnp.minimum(state.scale * factor, max_scale), state.scale)
    good = jnp.where((state.good_steps + 1) >= growth_interval,
                     0, state.good_steps + 1)
    new_scale = jnp.where(finite, grown,
                          jnp.maximum(state.scale / factor, min_scale))
    new_good = jnp.where(finite, good, 0)
    return LossScaleState(scale=new_scale, good_steps=new_good), finite
