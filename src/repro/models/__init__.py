from repro.models import decoder, encdec, layers, lm, moe, resnet, ssm  # noqa: F401
