"""Whisper-style encoder-decoder transformer backbone.

The mel-spectrogram + conv frontend is STUBBED per the assignment carve-out:
the encoder consumes precomputed frame embeddings [B, enc_seq, d_model]
(what the two conv layers would emit). Everything downstream — sinusoidal
encoder positions, encoder self-attention, decoder with causal self-attn +
cross-attn, learned decoder positions — is implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (
    apply_attention,
    apply_mlp,
    apply_norm,
    attention_axes,
    init_attention,
    init_mlp,
    init_norm,
    mlp_axes,
    norm_axes,
    project_kv,
    sinusoid_positions,
    _embed_init,
)

MAX_DEC_POS = 1 << 20  # learned decoder positions are tiled beyond this


def init_enc_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
            "ln2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}


def init_dec_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg), "self_attn": init_attention(ks[0], cfg),
            "ln2": init_norm(cfg), "cross_attn": init_attention(ks[1], cfg),
            "ln3": init_norm(cfg), "mlp": init_mlp(ks[2], cfg)}


def enc_block_axes(cfg):
    return {"ln1": norm_axes(cfg), "attn": attention_axes(cfg),
            "ln2": norm_axes(cfg), "mlp": mlp_axes(cfg)}


def dec_block_axes(cfg):
    return {"ln1": norm_axes(cfg), "self_attn": attention_axes(cfg),
            "ln2": norm_axes(cfg), "cross_attn": attention_axes(cfg),
            "ln3": norm_axes(cfg), "mlp": mlp_axes(cfg)}


def init_encdec(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: init_enc_block(k, cfg))(enc_keys),
        "enc_ln": init_norm(cfg),
        "dec_blocks": jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys),
        "dec_ln": init_norm(cfg),
        "dec_pos": _embed_init(ks[2], (4096, cfg.d_model)),  # learned, tiled
    }


def encdec_axes(cfg: ModelConfig):
    def stack(ax):
        return jax.tree.map(lambda t: ("layers",) + t, ax,
                            is_leaf=lambda t: isinstance(t, tuple))
    return {
        "enc_blocks": stack(enc_block_axes(cfg)),
        "enc_ln": norm_axes(cfg),
        "dec_blocks": stack(dec_block_axes(cfg)),
        "dec_ln": norm_axes(cfg),
        "dec_pos": (None, "embed"),
    }


def encode(p, cfg: ModelConfig, frames, *, remat: str = "none"):
    """frames: [B, enc_seq, D] stubbed conv features -> encoder output."""
    dt = frames.dtype
    s = frames.shape[1]
    x = frames + sinusoid_positions(s, cfg.d_model).astype(dt)
    positions = jnp.arange(s)

    def body(xc, layer_p):
        h = apply_norm(layer_p["ln1"], xc, cfg)
        a, _ = apply_attention(layer_p["attn"], cfg, h, positions=positions,
                               causal=False)
        xc = xc + a
        h = apply_norm(layer_p["ln2"], xc, cfg)
        xc = xc + apply_mlp(layer_p["mlp"], cfg, h)
        return xc, None

    if remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, p["enc_blocks"])
    return apply_norm(p["enc_ln"], x, cfg)


def _dec_positions_embed(p, positions, dt):
    idx = positions % p["dec_pos"].shape[0]
    return p["dec_pos"].astype(dt)[idx]


def decode_train(p, cfg: ModelConfig, tokens_emb, enc_out, positions,
                 want_cache=False, remat: str = "none"):
    """Teacher-forced decoder forward. tokens_emb: [B,S,D] (already embedded).
    Returns (hidden [B,S,D], caches or None)."""
    dt = tokens_emb.dtype
    x = tokens_emb + _dec_positions_embed(p, positions, dt)[None]
    enc_pos = jnp.arange(enc_out.shape[1])

    def body(xc, layer_p):
        h = apply_norm(layer_p["ln1"], xc, cfg)
        a, kv = apply_attention(layer_p["self_attn"], cfg, h,
                                positions=positions, causal=True)
        xc = xc + a
        h = apply_norm(layer_p["ln2"], xc, cfg)
        c, _ = apply_attention(layer_p["cross_attn"], cfg, h,
                               positions=positions, kv={"x": enc_out},
                               kv_positions=enc_pos, causal=False)
        xc = xc + c
        h = apply_norm(layer_p["ln3"], xc, cfg)
        xc = xc + apply_mlp(layer_p["mlp"], cfg, h)
        cache = None
        if want_cache:
            ck, cv = project_kv(layer_p["cross_attn"], cfg, enc_out, enc_pos)
            cache = {"k": kv[0], "v": kv[1], "cross_k": ck, "cross_v": cv}
        return xc, cache

    if remat == "full" and not want_cache:
        body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, p["dec_blocks"])
    return apply_norm(p["dec_ln"], x, cfg), caches


def build_cross_cache(p, cfg: ModelConfig, enc_out):
    """Precompute per-layer cross-attention K/V from encoder output."""
    enc_pos = jnp.arange(enc_out.shape[1])

    def body(_, layer_p):
        ck, cv = project_kv(layer_p["cross_attn"], cfg, enc_out, enc_pos)
        return None, (ck, cv)

    _, (ck, cv) = jax.lax.scan(body, None, p["dec_blocks"])
    return ck, cv  # [L,B,T_enc,Hk,Dh]


def decode_step(p, cfg: ModelConfig, x, caches, slots_state, *, window: int):
    """One decoder token. caches: stacked {"k","v","cross_k","cross_v"}."""
    pos = slots_state["pos"]
    pos_slots = slots_state["pos_slots"]
    slot = pos % window
    x = x + _dec_positions_embed(p, pos[None], x.dtype)[None]
    enc_pos = jnp.arange(caches["cross_k"].shape[2])

    def body(xc, inp):
        layer_p, lc = inp
        positions = pos[None]
        h = apply_norm(layer_p["ln1"], xc, cfg)
        k_new, v_new = project_kv(layer_p["self_attn"], cfg, h, positions)
        kc = lc["k"].at[:, slot].set(k_new[:, 0])
        vc = lc["v"].at[:, slot].set(v_new[:, 0])
        new_slots = pos_slots.at[slot].set(pos)
        a, _ = apply_attention(layer_p["self_attn"], cfg, h, positions=positions,
                               kv=(kc, vc), kv_positions=new_slots, causal=True)
        xc = xc + a
        h = apply_norm(layer_p["ln2"], xc, cfg)
        c, _ = apply_attention(layer_p["cross_attn"], cfg, h, positions=positions,
                               kv=(lc["cross_k"], lc["cross_v"]),
                               kv_positions=enc_pos, causal=False)
        xc = xc + c
        h = apply_norm(layer_p["ln3"], xc, cfg)
        xc = xc + apply_mlp(layer_p["mlp"], cfg, h)
        return xc, {"k": kc, "v": vc, "cross_k": lc["cross_k"],
                    "cross_v": lc["cross_v"]}

    x, new_caches = jax.lax.scan(body, x, (p["dec_blocks"], caches))
    x = apply_norm(p["dec_ln"], x, cfg)
    new_state = {"pos": pos + 1, "pos_slots": pos_slots.at[slot].set(pos)}
    return x, new_caches, new_state


def init_encdec_decode_cache(cfg: ModelConfig, batch: int, window: int, dtype):
    hk, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, window, hk, dh), dtype),
        "v": jnp.zeros((L, batch, window, hk, dh), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.enc_seq, hk, dh), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.enc_seq, hk, dh), dtype),
    }


def encdec_cache_axes(cfg: ModelConfig):
    ax = ("layers", "batch", "seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax, "cross_k": ax, "cross_v": ax}
