"""Decoder-layer stack for dense / moe / vlm / ssm / hybrid families.

Layers are homogeneous and SCANNED (params stacked on a leading ``layers``
axis) to bound HLO size at 61 layers × 512 devices. ``lax.scan`` also stacks
per-layer cache outputs for free during prefill.

Cache layout (leaves stacked [L, ...] by the layer scan):
  attn:   {"k": [B,W,Hk,Dh], "v": [B,W,Hk,Dh]}   (W = rotating window slots)
  ssm:    {"ssm_state": [B,H,N,P] fp32, "conv_state": [B,K-1,Dxbc]}
plus unstacked scalars: {"pos": int32 scalar, "pos_slots": [W] int32}.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    apply_attention,
    apply_mlp,
    apply_norm,
    attention_axes,
    init_attention,
    init_mlp,
    init_norm,
    mlp_axes,
    norm_axes,
    project_kv,
    rms_norm,
)

# ---------------------------------------------------------------------------
# per-layer init / axes
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    fam = cfg.family
    if fam == "ssm":
        return {"ln1": init_norm(cfg), "ssm": ssm_lib.init_ssm(ks[0], cfg)}
    p = {"ln1": init_norm(cfg), "attn": init_attention(ks[0], cfg),
         "ln2": init_norm(cfg)}
    if fam == "hybrid":
        p["ssm"] = ssm_lib.init_ssm(ks[1], cfg)
        p["fuse_attn"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["fuse_ssm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = init_mlp(ks[2], cfg)
    elif fam == "moe":
        p["moe"] = moe_lib.init_moe(ks[2], cfg)
    else:  # dense / vlm
        p["mlp"] = init_mlp(ks[2], cfg)
    return p


def block_axes(cfg: ModelConfig):
    fam = cfg.family
    if fam == "ssm":
        return {"ln1": norm_axes(cfg), "ssm": ssm_lib.ssm_axes(cfg)}
    a = {"ln1": norm_axes(cfg), "attn": attention_axes(cfg), "ln2": norm_axes(cfg)}
    if fam == "hybrid":
        a["ssm"] = ssm_lib.ssm_axes(cfg)
        a["fuse_attn"] = ("embed",)
        a["fuse_ssm"] = ("embed",)
        a["mlp"] = mlp_axes(cfg)
    elif fam == "moe":
        a["moe"] = moe_lib.moe_axes(cfg)
    else:
        a["mlp"] = mlp_axes(cfg)
    return a


def init_blocks(key, cfg: ModelConfig):
    """Stacked layer params [n_layers, ...] via vmap over layer keys."""
    keys = jax.random.split(key, cfg.n_layers)
    return jax.vmap(lambda k: init_block(k, cfg))(keys)


# ---------------------------------------------------------------------------
# train / prefill forward
# ---------------------------------------------------------------------------


def _mixer_forward(p, cfg: ModelConfig, x, positions, sharder):
    """Sequence-mixing sublayer (attn / ssm / parallel attn+ssm).
    Returns (mix_out, cache_out_dict)."""
    fam = cfg.family
    cache = {}
    if fam == "ssm":
        h = apply_norm(p["ln1"], x, cfg)
        out, ssm_cache = ssm_lib.apply_ssm(p["ssm"], cfg, h)
        cache.update(ssm_cache)
        return out, cache
    h = apply_norm(p["ln1"], x, cfg)
    attn_out, kv = apply_attention(
        p["attn"], cfg, h, positions=positions, causal=True,
        window=cfg.sliding_window,
    )
    cache["k"], cache["v"] = kv
    if fam == "hybrid":
        ssm_out, ssm_cache = ssm_lib.apply_ssm(p["ssm"], cfg, h)
        cache.update(ssm_cache)
        out = 0.5 * (rms_norm(attn_out) * p["fuse_attn"].astype(x.dtype)
                     + rms_norm(ssm_out) * p["fuse_ssm"].astype(x.dtype))
        return out, cache
    return attn_out, cache


def _block_forward(p, cfg: ModelConfig, x, positions, sharder):
    """Full block. Returns (x, aux, cache)."""
    sharder = sharder or (lambda a, ax: a)
    aux = jnp.zeros((), jnp.float32)
    mix, cache = _mixer_forward(p, cfg, x, positions, sharder)
    x = x + mix
    x = sharder(x, ("batch", "seq", "embed"))
    if cfg.family == "ssm":
        return x, aux, cache
    h = apply_norm(p["ln2"], x, cfg)
    if cfg.family == "moe":
        ff, aux = moe_lib.apply_moe(p["moe"], cfg, h, sharder=sharder)
    else:
        ff = apply_mlp(p["mlp"], cfg, h)
    x = x + ff
    x = sharder(x, ("batch", "seq", "embed"))
    return x, aux, cache


def apply_stack(blocks, cfg: ModelConfig, x, positions, *, sharder=None,
                remat: str = "none", want_cache: bool = False,
                cache_window: Optional[int] = None, param_sharder=None):
    """Run the layer stack. Returns (x, aux_total, caches or None).

    ``caches`` leaves are stacked [L, ...]; attention K/V are slot-compressed
    to ``cache_window`` rotating slots when given. ``param_sharder``
    re-constrains the per-layer param slice INSIDE the scan body (FSDP:
    forces the data-axis all-gather to happen per layer, not hoisted).
    """
    fwd = functools.partial(_block_forward, cfg=cfg, positions=positions,
                            sharder=sharder)

    def body(carry, layer_p):
        xc, aux = carry
        if param_sharder is not None:
            layer_p = param_sharder(layer_p)
        xo, a, cache = fwd(layer_p, x=xc)
        if not want_cache:
            cache = None
        elif cache_window is not None and "k" in cache:
            cache["k"], cache["v"] = _compress_kv(
                cache["k"], cache["v"], positions, cache_window)
        return (xo, aux + a), cache

    if remat == "full":
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux, caches


def _compress_kv(k, v, positions, window):
    """Keep the last min(S, window) entries, placed at slot pos % window."""
    b, s, hk, dh = k.shape
    w = min(s, window)
    k_tail, v_tail = k[:, s - w:], v[:, s - w:]
    if w == window and s >= window:
        slots = positions[s - w:] % window
        kc = jnp.zeros((b, window, hk, dh), k.dtype).at[:, slots].set(k_tail)
        vc = jnp.zeros((b, window, hk, dh), v.dtype).at[:, slots].set(v_tail)
        return kc, vc
    pad = window - w
    kc = jnp.pad(k_tail, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v_tail, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return kc, vc


def init_cache_slots(cfg: ModelConfig, window: int, prefill_positions=None):
    """pos / pos_slots bookkeeping shared by all layers."""
    if prefill_positions is None:
        return {"pos": jnp.zeros((), jnp.int32),
                "pos_slots": jnp.full((window,), -1, jnp.int32)}
    s = prefill_positions.shape[0]
    w = min(s, window)
    tail = prefill_positions[s - w:]
    slots = jnp.full((window,), -1, jnp.int32)
    slots = slots.at[tail % window].set(tail.astype(jnp.int32))
    return {"pos": prefill_positions[-1].astype(jnp.int32) + 1,
            "pos_slots": slots}


# ---------------------------------------------------------------------------
# decode (one token)
# ---------------------------------------------------------------------------


def _block_decode(p, cfg: ModelConfig, x, layer_cache, pos, pos_slots, slot):
    """x: [B,1,D]. Returns (x, new_layer_cache)."""
    fam = cfg.family
    new_cache = {}
    h = apply_norm(p["ln1"], x, cfg)
    positions = pos[None]  # [1]
    if fam == "ssm":
        out, sc = ssm_lib.apply_ssm_step(p["ssm"], cfg, h, layer_cache)
        return x + out, sc
    # attention over the rotating cache
    k_new, v_new = project_kv(p["attn"], cfg, h, positions)
    kc = layer_cache["k"].at[:, slot].set(k_new[:, 0])
    vc = layer_cache["v"].at[:, slot].set(v_new[:, 0])
    new_slots = pos_slots.at[slot].set(pos)
    attn_out, _ = apply_attention(
        p["attn"], cfg, h, positions=positions, kv=(kc, vc),
        kv_positions=new_slots, causal=True, window=cfg.sliding_window,
    )
    new_cache["k"], new_cache["v"] = kc, vc
    if fam == "hybrid":
        ssm_out, sc = ssm_lib.apply_ssm_step(
            p["ssm"], cfg, h, {k: layer_cache[k] for k in ("ssm_state", "conv_state")})
        new_cache.update(sc)
        mix = 0.5 * (rms_norm(attn_out) * p["fuse_attn"].astype(x.dtype)
                     + rms_norm(ssm_out) * p["fuse_ssm"].astype(x.dtype))
    else:
        mix = attn_out
    x = x + mix
    h2 = apply_norm(p["ln2"], x, cfg)
    if fam == "moe":
        ff, _ = moe_lib.apply_moe(p["moe"], cfg, h2)
    else:
        ff = apply_mlp(p["mlp"], cfg, h2)
    return x + ff, new_cache


def decode_stack(blocks, cfg: ModelConfig, x, caches, slots_state, *,
                 window: int, param_sharder=None):
    """One decode step through all layers.

    caches: stacked [L, ...] pytree; slots_state: {"pos", "pos_slots"}.
    Returns (x, new_caches, new_slots_state).
    """
    pos = slots_state["pos"]
    pos_slots = slots_state["pos_slots"]
    slot = pos % window

    def body(xc, inp):
        layer_p, layer_cache = inp
        if param_sharder is not None:
            layer_p = param_sharder(layer_p)
        xo, new_cache = _block_decode(layer_p, cfg, xc, layer_cache, pos,
                                      pos_slots, slot)
        return xo, new_cache

    x, new_caches = jax.lax.scan(body, x, (blocks, caches))
    new_state = {"pos": pos + 1, "pos_slots": pos_slots.at[slot].set(pos)}
    return x, new_caches, new_state


def init_decode_cache(cfg: ModelConfig, batch: int, window: int, dtype):
    """Fresh (empty) stacked cache for ``decode``-mode dry-runs/serving."""
    fam = cfg.family
    hk = cfg.n_kv_heads
    dh = cfg.resolved_head_dim if fam != "ssm" else 0

    def one_layer():
        c = {}
        if fam != "ssm":
            c["k"] = jnp.zeros((batch, window, hk, dh), dtype)
            c["v"] = jnp.zeros((batch, window, hk, dh), dtype)
        if fam in ("ssm", "hybrid"):
            c.update(ssm_lib.init_ssm_cache(cfg, batch, dtype))
        return c

    layer = one_layer()
    stacked = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), layer)
    return stacked


def cache_axes(cfg: ModelConfig):
    """Logical axes for stacked cache leaves (leading 'layers')."""
    fam = cfg.family
    c = {}
    if fam != "ssm":
        c["k"] = ("layers", "batch", "seq", "kv_heads", "head_dim")
        c["v"] = ("layers", "batch", "seq", "kv_heads", "head_dim")
    if fam in ("ssm", "hybrid"):
        sa = ssm_lib.ssm_cache_axes(cfg)
        c["ssm_state"] = ("layers",) + sa["ssm_state"]
        c["conv_state"] = ("layers",) + sa["conv_state"]
    return c
