"""ResNet-v1.5-style CNN feature extractor — the paper's own FE trunk
(ResNet-50, D=512 embedding). Implemented in JAX (not stubbed).

BatchNorm -> GroupNorm adaptation (DESIGN.md §2): the paper's data-parallel
trunk keeps BN in fp32 and syncs nothing across devices; GroupNorm gives the
same "no cross-device batch statistics" property without train/eval mode
state, which suits a pure-functional pjit trainer. The trunk is *data
parallel* exactly as in the paper — every conv kernel's logical axes are
replicated (None).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

STAGES_50 = ((64, 3), (128, 4), (256, 6), (512, 3))
STAGES_REDUCED = ((32, 1), (64, 1))


def stages_for(cfg: ModelConfig):
    return STAGES_50 if cfg.n_layers >= 50 else STAGES_REDUCED


def _conv_init(key, shape):
    fan_in = shape[0] * shape[1] * shape[2]
    return jax.random.normal(key, shape) / jnp.sqrt(fan_in / 2)


def _gn_params(c):
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def group_norm(p, x, groups=8, eps=1e-5):
    b, h, w, c = x.shape
    g = min(groups, c)
    xf = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = jnp.mean(xf, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xf, axis=(1, 2, 4), keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    xf = xf.reshape(b, h, w, c)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


def conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def init_bottleneck(key, c_in, c_mid, stride):
    ks = jax.random.split(key, 4)
    c_out = c_mid * 4
    p = {
        "conv1": _conv_init(ks[0], (1, 1, c_in, c_mid)), "gn1": _gn_params(c_mid),
        "conv2": _conv_init(ks[1], (3, 3, c_mid, c_mid)), "gn2": _gn_params(c_mid),
        "conv3": _conv_init(ks[2], (1, 1, c_mid, c_out)), "gn3": _gn_params(c_out),
    }
    if stride != 1 or c_in != c_out:
        p["proj"] = _conv_init(ks[3], (1, 1, c_in, c_out))
        p["gn_proj"] = _gn_params(c_out)
    return p


def apply_bottleneck(p, x, stride):
    h = jax.nn.relu(group_norm(p["gn1"], conv(x, p["conv1"])))
    h = jax.nn.relu(group_norm(p["gn2"], conv(h, p["conv2"], stride)))
    h = group_norm(p["gn3"], conv(h, p["conv3"]))
    if "proj" in p:
        x = group_norm(p["gn_proj"], conv(x, p["proj"], stride))
    return jax.nn.relu(x + h)


def init_resnet(key, cfg: ModelConfig):
    stages = stages_for(cfg)
    ks = jax.random.split(key, 2 + sum(n for _, n in stages))
    p = {"stem": _conv_init(ks[0], (7, 7, 3, 64)), "gn_stem": _gn_params(64),
         "blocks": [], "head_w": None}
    c_in = 64
    ki = 1
    blocks = []
    for si, (c_mid, n_blocks) in enumerate(stages):
        for bi in range(n_blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            blocks.append(init_bottleneck(ks[ki], c_in, c_mid, stride))
            c_in = c_mid * 4
            ki += 1
    p["blocks"] = blocks
    p["head_w"] = jax.random.normal(ks[ki], (c_in, cfg.d_model)) / jnp.sqrt(c_in)
    return p


def resnet_axes(cfg: ModelConfig):
    """Fully replicated (data-parallel trunk, as in the paper)."""
    return None  # interpreted as replicate-all by the launcher


def apply_resnet(p, cfg: ModelConfig, images):
    """images: [B, H, W, 3] -> features [B, 1, d_model]."""
    stages = stages_for(cfg)
    dt = images.dtype
    x = jax.nn.relu(group_norm(p["gn_stem"], conv(images, p["stem"], 2)))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    bi = 0
    for si, (c_mid, n_blocks) in enumerate(stages):
        for j in range(n_blocks):
            stride = 2 if (si > 0 and j == 0) else 1
            x = apply_bottleneck(p["blocks"][bi], x, stride)
            bi += 1
    feat = jnp.mean(x, axis=(1, 2))  # global average pool
    feat = feat @ p["head_w"].astype(dt)
    return feat[:, None, :]
