"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Training/prefill uses the chunked SSD algorithm: intra-chunk attention-like
matmuls (MXU friendly) + an inter-chunk state recurrence via lax.scan, fp32
state. Decode is the O(1) recurrent step over a carried (conv, ssm) cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    d_xbc = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, d_xbc


def init_ssm(key, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, n_heads, d_xbc = ssm_dims(cfg)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    return {
        "in_proj": _dense_init(ks[0], (cfg.d_model, d_in_proj), in_axis=0),
        "conv_w": _dense_init(ks[1], (s.d_conv, d_xbc), in_axis=0) * 0.1,
        "conv_b": jnp.zeros((d_xbc,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 1e-1, n_heads))).astype(jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), jnp.float32),
        "out_proj": _dense_init(ks[3], (d_inner, cfg.d_model), in_axis=0),
    }


def ssm_axes(cfg: ModelConfig):
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "dt_bias": ("heads",),
        "A_log": ("heads",),
        "D": ("heads",),
        "norm_scale": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _split_in_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_inner, n_heads, d_xbc = ssm_dims(cfg)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + d_xbc]
    dt = zxbcdt[..., d_inner + d_xbc:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d. xbc: [B,S,C]; w: [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return out + b


def _segsum_cumsum(dtA_c):
    """dtA_c: [b,nc,l,h] -> within-chunk inclusive cumsum [b,nc,l,h] (fp32)."""
    return jnp.cumsum(dtA_c.astype(jnp.float32), axis=2)


def ssd_chunked(x, dt, A, B, C, chunk, init_state=None):
    """Chunked SSD scan.

    x: [b,s,h,p] dt: [b,s,h] (post-softplus, fp32) A: [h] (negative fp32)
    B, C: [b,s,g,n] (g groups broadcast over heads)
    Returns (y [b,s,h,p], final_state [b,h,n,p] fp32).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    nc, l = s // chunk, chunk
    rep = h // g

    xc = x.reshape(b, nc, l, h, p)
    dtc = dt.reshape(b, nc, l, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, l, g, n)
    Cc = C.reshape(b, nc, l, g, n)
    dtA = dtc * A  # [b,nc,l,h] negative
    cums = jnp.cumsum(dtA, axis=2)  # inclusive

    # intra-chunk ("diagonal") term -------------------------------------
    # L[i,j] = exp(cums_i - cums_j) for i>=j else 0
    Ldec = jnp.exp(cums[:, :, :, None, :] - cums[:, :, None, :, :])  # [b,nc,i,j,h]
    tri = jnp.tril(jnp.ones((l, l), bool))
    Ldec = jnp.where(tri[None, None, :, :, None], Ldec, 0.0)
    CB = jnp.einsum("bclgn,bcmgn->bclmg", Cc.astype(jnp.float32), Bc.astype(jnp.float32))
    CB = jnp.repeat(CB, rep, axis=-1)  # [b,nc,i,j,h]
    M = CB * Ldec
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # [b,nc,l,h,p]
    y_diag = jnp.einsum("bclmh,bcmhp->bclhp", M, xdt)

    # chunk-final states ---------------------------------------------------
    decay_states = jnp.exp(cums[:, :, -1:, :] - cums)  # [b,nc,l,h]
    Bh = jnp.repeat(Bc, rep, axis=3)  # [b,nc,l,h,n]
    states = jnp.einsum(
        "bclhn,bclh,bclhp->bchnp", Bh.astype(jnp.float32), decay_states * dtc, xc.astype(jnp.float32)
    )  # [b,nc,h,n,p]

    # inter-chunk recurrence ----------------------------------------------
    chunk_decay = jnp.exp(cums[:, :, -1, :])  # [b,nc,h]
    s0 = jnp.zeros((b, h, n, p), jnp.float32) if init_state is None else init_state

    def step(prev, inp):
        st, dec = inp  # [b,h,n,p], [b,h]
        new = st + dec[:, :, None, None] * prev
        return new, prev  # emit the state *entering* this chunk

    final_state, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [b,nc,h,n,p]

    # inter-chunk ("off-diagonal") contribution ----------------------------
    Ch = jnp.repeat(Cc, rep, axis=3)  # [b,nc,l,h,n]
    y_off = jnp.einsum(
        "bclhn,bclh,bchnp->bclhp", Ch.astype(jnp.float32), jnp.exp(cums), prev_states
    )
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final_state


def apply_ssm(p, cfg: ModelConfig, x, init_state=None):
    """Train/prefill forward. x: [B,S,D] -> (y [B,S,D], cache_out)."""
    s_cfg = cfg.ssm
    d_inner, n_heads, d_xbc = ssm_dims(cfg)
    dt_ = x.dtype
    zxbcdt = x @ p["in_proj"].astype(dt_)
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_)))
    x_ssm = xbc[..., :d_inner]
    B = xbc[..., d_inner:d_inner + s_cfg.n_groups * s_cfg.d_state]
    C = xbc[..., d_inner + s_cfg.n_groups * s_cfg.d_state:]
    b, s, _ = x.shape
    B = B.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    C = C.reshape(b, s, s_cfg.n_groups, s_cfg.d_state)
    xh = x_ssm.reshape(b, s, n_heads, s_cfg.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    # pad seq to a chunk multiple; padded steps get dt=0 (decay=1, no input)
    # so they are exact no-ops on the state.
    s_pad = -s % s_cfg.chunk
    if s_pad:
        xh = jnp.pad(xh, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, s_pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, s_pad), (0, 0), (0, 0)))
    y, state = ssd_chunked(xh, dt, A, B, C, s_cfg.chunk, init_state)
    if s_pad:
        y = y[:, :s]
        xh = xh[:, :s]
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(dt_)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_scale"]).astype(dt_)
    out = y @ p["out_proj"].astype(dt_)
    # conv tail for seamless decode continuation
    conv_tail = _conv_tail_from_prefill(p, cfg, x)
    return out, {"ssm_state": state, "conv_state": conv_tail}


def _conv_tail_from_prefill(p, cfg, x):
    """Last (d_conv-1) pre-conv xBC rows, for decode continuation."""
    d_inner, _, d_xbc = ssm_dims(cfg)
    k = cfg.ssm.d_conv
    zxbcdt = x[:, -(k - 1):, :] @ p["in_proj"].astype(x.dtype)
    _, xbc, _ = _split_in_proj(cfg, zxbcdt)
    b = x.shape[0]
    if xbc.shape[1] < k - 1:  # short prefill: left-pad zeros
        padlen = k - 1 - xbc.shape[1]
        xbc = jnp.concatenate([jnp.zeros((b, padlen, d_xbc), xbc.dtype), xbc], axis=1)
    return xbc


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype):
    s = cfg.ssm
    d_inner, n_heads, d_xbc = ssm_dims(cfg)
    return {
        "ssm_state": jnp.zeros((batch, n_heads, s.d_state, s.head_dim), jnp.float32),
        "conv_state": jnp.zeros((batch, s.d_conv - 1, d_xbc), dtype),
    }


def ssm_cache_axes(cfg: ModelConfig):
    return {"ssm_state": ("batch", "heads", None, None),
            "conv_state": ("batch", None, "inner")}


def apply_ssm_step(p, cfg: ModelConfig, x, cache):
    """Single-token decode. x: [B,1,D] -> (y [B,1,D], new cache)."""
    s_cfg = cfg.ssm
    d_inner, n_heads, d_xbc = ssm_dims(cfg)
    dt_ = x.dtype
    b = x.shape[0]
    zxbcdt = x[:, 0, :] @ p["in_proj"].astype(dt_)  # [B, ...]
    z, xbc, dt = _split_in_proj(cfg, zxbcdt)
    window = jnp.concatenate([cache["conv_state"], xbc[:, None, :]], axis=1)  # [B,K,dxbc]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(dt_)) + p["conv_b"].astype(dt_)
    xbc_t = jax.nn.silu(conv_out)
    x_ssm = xbc_t[..., :d_inner]
    B = xbc_t[..., d_inner:d_inner + s_cfg.n_groups * s_cfg.d_state]
    C = xbc_t[..., d_inner + s_cfg.n_groups * s_cfg.d_state:]
    B = B.reshape(b, s_cfg.n_groups, s_cfg.d_state)
    C = C.reshape(b, s_cfg.n_groups, s_cfg.d_state)
    rep = n_heads // s_cfg.n_groups
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)  # [B,H,N]
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    xh = x_ssm.reshape(b, n_heads, s_cfg.head_dim).astype(jnp.float32)  # [B,H,P]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])  # [H]
    decay = jnp.exp(dt * A)  # [B,H]
    state = cache["ssm_state"]  # [B,H,N,P] fp32
    state = decay[:, :, None, None] * state + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bh, dt, xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state) + p["D"][None, :, None] * xh
    y = y.reshape(b, d_inner).astype(dt_)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["norm_scale"]).astype(dt_)
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    new_cache = {"ssm_state": state, "conv_state": window[:, 1:, :]}
    return out, new_cache
