"""Shared model primitives: norms, RoPE, GQA attention (qk-norm, sliding
window, q-block-scanned flash-style softmax), gated MLPs, embeddings.

Conventions
-----------
* Params are nested dicts of jnp arrays; every ``init_*`` has a matching
  ``*_axes`` pytree of *logical axis names* used by the launcher to build
  PartitionSpecs (MaxText-style logical->mesh rules in ParallelConfig).
* Compute dtype is ``cfg.dtype`` (bf16 on TPU); reductions (softmax, norm
  statistics, attention logits) run in fp32.
* All sequence ops take absolute positions so the same code serves train,
  prefill and rotating-buffer decode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    """LeCun-normal-ish fan-in init."""
    fan_in = shape[in_axis] if len(shape) > 1 else shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def _embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    dim = dim or cfg.d_model
    p = {"scale": jnp.ones((dim,), jnp.float32)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), jnp.float32)
    return p


def norm_axes(cfg: ModelConfig):
    a = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        a["bias"] = ("embed",)
    return a


def apply_norm(p, x, cfg: ModelConfig):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(dt)


def rms_norm(x, eps=1e-6):
    """Scale-free RMS norm (used for qk-norm-less fusions)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(ms + eps)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: [..., S, H, Dh]; positions: [..., S] absolute token positions."""
    if theta <= 0:
        return x
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoid_positions(length: int, dim: int):
    """Whisper-style fixed sinusoidal embeddings [length, dim]."""
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv: int
    head_dim: int


def init_attention(key, cfg: ModelConfig, dims: Optional[AttnDims] = None):
    d = dims or AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, d.n_heads, d.head_dim), in_axis=0),
        "wk": _dense_init(ks[1], (cfg.d_model, d.n_kv, d.head_dim), in_axis=0),
        "wv": _dense_init(ks[2], (cfg.d_model, d.n_kv, d.head_dim), in_axis=0),
        "wo": _dense_init(ks[3], (d.n_heads, d.head_dim, cfg.d_model), in_axis=1),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((d.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((d.head_dim,), jnp.float32)
    return p


def attention_axes(cfg: ModelConfig):
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qk_norm:
        a["q_norm"] = ("head_dim",)
        a["k_norm"] = ("head_dim",)
    return a


def _qk_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(ms + eps) * scale).astype(dt)


def _attn_scores_block(q, k, q_pos, k_pos, scale, causal, window):
    """q: [B,Hq,Sq,Dh] k: [B,Hk,T,Dh] (Hq multiple of Hk) -> probs fp32."""
    b, hq, sq, dh = q.shape
    hk = k.shape[1]
    group = hq // hk
    qg = q.reshape(b, hk, group, sq, dh)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    qp = q_pos[:, None]  # [Sq,1]
    kp = k_pos[None, :]  # [1,T]
    valid = kp >= 0
    if causal:
        valid &= kp <= qp
    if window is not None and window > 0:
        valid &= kp > qp - window
    scores = jnp.where(valid[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (can happen for padding) -> zeros, not NaN
    probs = jnp.where(jnp.any(valid, axis=-1)[None, None, None, :, None], probs, 0.0)
    return probs  # [B,Hk,G,Sq,T] fp32


import os as _os

# §Perf knob: store flash-attention probabilities in bf16 at XLA fusion
# boundaries (the dominant HBM term of the pure-JAX flash path). Max/sum
# statistics stay fp32; only the [qb, kvb] prob tile narrows.
FLASH_PROBS_BF16 = _os.environ.get("REPRO_FLASH_PROBS_BF16", "0") == "1"


def _flash_qblock(qg, kT, vT, qpos, k_positions, scale, causal, window,
                  kv_block: int):
    """Online-softmax over kv blocks for one q block.
    qg: [B,Hk,G,qb,Dh]; kT/vT: [B,Hk,T,Dh]. Returns [B,Hk,G,qb,Dh] fp32."""
    b, hk, g, qb, dh = qg.shape
    t = kT.shape[2]
    nkv = t // kv_block
    assert t % kv_block == 0, f"T {t} % kv_block {kv_block} != 0"
    kblocks = kT.reshape(b, hk, nkv, kv_block, dh).transpose(2, 0, 1, 3, 4)
    vblocks = vT.reshape(b, hk, nkv, kv_block, dh).transpose(2, 0, 1, 3, 4)
    pblocks = k_positions.reshape(nkv, kv_block)
    qp = qpos[:, None]  # [qb, 1]

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, kp = inp
        s = jnp.einsum("bkgsd,bktd->bkgst", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        valid = kp[None, :] >= 0
        if causal:
            valid &= kp[None, :] <= qp
        if window is not None and window > 0:
            valid &= kp[None, :] > qp - window
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # rows still all-masked keep m=-inf; guard exp of (-inf) - (-inf)
        safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(jnp.where(valid[None, None, None], s - safe_m[..., None],
                              -jnp.inf))
        if FLASH_PROBS_BF16:
            p = p.astype(jnp.bfloat16)  # narrow the HBM boundary tile
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
        l = l * corr + jnp.sum(p.astype(jnp.float32), axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgst,bktd->bkgsd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hk, g, qb), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hk, g, qb), jnp.float32)
    a0 = jnp.zeros((b, hk, g, qb, dh), jnp.float32)
    # checkpoint the kv step: the scan VJP must NOT save per-block prob
    # tensors (that would re-materialize the full [Sq,T] matrix) — flash
    # backward recomputes them per block instead.
    step_ckpt = jax.checkpoint(step, prevent_cse=False)
    (m, l, acc), _ = jax.lax.scan(step_ckpt, (m0, l0, a0),
                                  (kblocks, vblocks, pblocks))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def multihead_attention(
    q, k, v, *, q_positions, k_positions, causal=True, window=None,
    q_block: int = 512, kv_block: int = 1024,
):
    """GQA attention over absolute positions.

    q: [B,Sq,Hq,Dh]; k,v: [B,T,Hk,Dh]; q_positions [Sq]; k_positions [T]
    (entries < 0 mark invalid cache slots).

    Long sequences run a two-level flash scan (q blocks outer, kv blocks
    inner, online softmax in fp32) so no [Sq,T] tensor ever materializes —
    the pure-JAX analogue of the TPU flash kernel; short/decode paths score
    directly.
    """
    b, sq, hq, dh = q.shape
    t = k.shape[1]
    hk = k.shape[2]
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qT = q.transpose(0, 2, 1, 3)          # [B,Hq,Sq,Dh]
    kT = k.transpose(0, 2, 1, 3)          # [B,Hk,T,Dh]
    vT = v.transpose(0, 2, 1, 3)

    if sq * t <= q_block * kv_block * 2 or t % kv_block:
        probs = _attn_scores_block(qT, kT, q_positions, k_positions, scale,
                                   causal, window)
        out = jnp.einsum("bkgst,bktd->bkgsd", probs.astype(v.dtype), vT,
                         preferred_element_type=jnp.float32)
        out = out.reshape(b, hq, sq, dh)
        return out.astype(q.dtype).transpose(0, 2, 1, 3)

    g = hq // hk
    qg4 = qT.reshape(b, hk, g, sq, dh)
    if sq <= q_block:
        out = _flash_qblock(qg4, kT, vT, q_positions, k_positions, scale,
                            causal, window, kv_block)
        out = out.reshape(b, hq, sq, dh)
    else:
        assert sq % q_block == 0, f"seq {sq} not divisible by q_block {q_block}"
        nb = sq // q_block
        qblocks = qg4.reshape(b, hk, g, nb, q_block, dh).transpose(
            3, 0, 1, 2, 4, 5)
        pblocks = q_positions.reshape(nb, q_block)

        def step(_, inp):
            qb_, pp = inp
            return None, _flash_qblock(qb_, kT, vT, pp, k_positions, scale,
                                       causal, window, kv_block)

        _, outs = jax.lax.scan(step, None, (qblocks, pblocks))
        # outs: [nb, B, Hk, G, qb, Dh] -> [B, Hq, Sq, Dh]
        out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, sq, dh)
    return out.astype(q.dtype).transpose(0, 2, 1, 3)  # [B,Sq,Hq,Dh]


def project_kv(p, cfg: ModelConfig, x, positions):
    """Project (and qk-norm + rope) K/V of x for self-attention/caching."""
    dt = x.dtype
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta > 0:
        k = rope(k, positions, cfg.rope_theta)
    return k, v


def apply_attention(
    p, cfg: ModelConfig, x, *, positions, kv=None, kv_positions=None,
    causal=True, window=None, dims: Optional[AttnDims] = None,
):
    """Full attention sublayer. ``kv``/(kv_positions) overrides K/V source:
    - None: self-attention over x
    - (k_cache, v_cache): pre-projected cache [B,T,Hk,Dh]
    - {"x": enc_out}: cross-attention (project enc_out)
    Returns (out [B,S,D], (k_new, v_new) projected K/V of x for cache updates).
    """
    d = dims or AttnDims(cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if isinstance(kv, dict):  # cross attention
        src = kv["x"]
        k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(dt))
        k_pos = kv_positions
    elif kv is None:
        k, v = project_kv(p, cfg, x, positions)
        k_pos = positions
    else:
        k, v = kv  # pre-projected (and pre-roped) cache
        k_pos = kv_positions
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        if isinstance(kv, dict):
            k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta > 0 and not isinstance(kv, dict):
        q = rope(q, positions, cfg.rope_theta)
    out = multihead_attention(
        q, k, v, q_positions=positions, k_positions=k_pos,
        causal=causal, window=window,
    )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    if kv is None:
        return out, (k, v)
    return out, None


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "wi_gate": _dense_init(ks[0], (cfg.d_model, d_ff), in_axis=0),
            "wi_up": _dense_init(ks[1], (cfg.d_model, d_ff), in_axis=0),
            "wo": _dense_init(ks[2], (d_ff, cfg.d_model), in_axis=0),
        }
    return {  # plain gelu MLP (whisper)
        "wi": _dense_init(ks[0], (cfg.d_model, d_ff), in_axis=0),
        "bi": jnp.zeros((d_ff,), jnp.float32),
        "wo": _dense_init(ks[2], (d_ff, cfg.d_model), in_axis=0),
        "bo": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def mlp_axes(cfg: ModelConfig):
    if cfg.activation in ("swiglu", "geglu"):
        return {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
                "wo": ("mlp", "embed")}
    return {"wi": ("embed", "mlp"), "bi": ("mlp",),
            "wo": ("mlp", "embed"), "bo": ("embed",)}


def apply_mlp(p, cfg: ModelConfig, x):
    dt = x.dtype
    if cfg.activation in ("swiglu", "geglu"):
        g = x @ p["wi_gate"].astype(dt)
        u = x @ p["wi_up"].astype(dt)
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
        return (act * u) @ p["wo"].astype(dt)
    h = jax.nn.gelu(x @ p["wi"].astype(dt) + p["bi"].astype(dt))
    return h @ p["wo"].astype(dt) + p["bo"].astype(dt)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, cfg: ModelConfig):
    return {"table": _embed_init(key, (cfg.vocab_size, cfg.d_model))}


def embedding_axes(cfg: ModelConfig):
    return {"table": ("vocab", "embed")}


def apply_embedding(p, cfg: ModelConfig, tokens):
    return jnp.take(p["table"].astype(jnp.dtype(cfg.dtype)), tokens, axis=0)
