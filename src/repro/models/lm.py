"""Model assembly: embedding + family backbone + (tied) classification head.

This is the public model API the trainer / server / dry-run all use:

  init_model(key, cfg)                  -> params
  model_axes(cfg)                       -> logical-axis pytree (params)
  backbone(params, cfg, inputs, ...)    -> (hidden [B,S,D], aux, caches)
  head_weight(params, cfg)              -> W [V, D] (the extreme-classn head)
  decode(params, cfg, inputs, caches, slots, window) -> (hidden, caches, slots)
  input_example / input_specs           -> concrete / ShapeDtypeStruct inputs

The head weight is consumed by ``repro.core`` (hybrid-parallel full/KNN/
selective/MACH softmax) — the paper's technique is a head-side module shared
by every architecture (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import decoder as dec_lib
from repro.models import encdec as encdec_lib
from repro.models import resnet as resnet_lib
from repro.models.layers import (
    _dense_init,
    apply_embedding,
    apply_norm,
    embedding_axes,
    init_embedding,
    init_norm,
    norm_axes,
)

# ---------------------------------------------------------------------------
# init / axes
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    if cfg.family == "feats":
        # head-only mode: inputs are precomputed features (benchmarks that
        # isolate the softmax stage, paper §4.1/§4.3 style)
        return {"head": _dense_init(ks[1], (cfg.vocab_size, cfg.d_model),
                                    in_axis=1)}
    if cfg.family == "cnn":
        p = {"trunk": resnet_lib.init_resnet(ks[0], cfg),
             "head": _dense_init(ks[1], (cfg.vocab_size, cfg.d_model), in_axis=1)}
        return p
    p = {"embed": init_embedding(ks[0], cfg)}
    if cfg.family == "encdec":
        p["encdec"] = encdec_lib.init_encdec(ks[1], cfg)
    else:
        p["blocks"] = dec_lib.init_blocks(ks[1], cfg)
        p["ln_f"] = init_norm(cfg)
    if not cfg.tie_embeddings:
        p["head"] = _dense_init(ks[2], (cfg.vocab_size, cfg.d_model), in_axis=1)
    return p


def _stack_axes(ax):
    return jax.tree.map(lambda t: ("layers",) + t, ax,
                        is_leaf=lambda t: isinstance(t, tuple))


def model_axes(cfg: ModelConfig):
    if cfg.family == "feats":
        return {"head": ("vocab", "embed")}
    if cfg.family == "cnn":
        return {"trunk": None, "head": ("vocab", "embed")}
    a = {"embed": embedding_axes(cfg)}
    if cfg.family == "encdec":
        a["encdec"] = encdec_lib.encdec_axes(cfg)
    else:
        a["blocks"] = _stack_axes(dec_lib.block_axes(cfg))
        a["ln_f"] = norm_axes(cfg)
    if not cfg.tie_embeddings:
        a["head"] = ("vocab", "embed")
    return a


def head_weight(params, cfg: ModelConfig):
    """The extreme-classification head W [V, D] (paper's 'big fc')."""
    if cfg.family == "cnn" or not cfg.tie_embeddings:
        return params["head"]
    return params["embed"]["table"]


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def backbone(params, cfg: ModelConfig, inputs, *, sharder=None,
             remat: str = "none", want_cache: bool = False,
             cache_window: Optional[int] = None, param_sharder=None):
    """-> (hidden [B,S,D], aux scalar, caches or None)."""
    dt = jnp.dtype(cfg.dtype)
    zero = jnp.zeros((), jnp.float32)
    if cfg.family == "feats":
        return inputs["features"].astype(dt)[:, None, :], zero, None
    if cfg.family == "cnn":
        feat = resnet_lib.apply_resnet(params["trunk"], cfg,
                                       inputs["images"].astype(dt))
        return feat, zero, None
    if cfg.family == "encdec":
        frames = inputs["frames"].astype(dt)
        tokens = inputs["tokens"]
        positions = jnp.arange(tokens.shape[1])
        enc_out = encdec_lib.encode(params["encdec"], cfg, frames,
                                    remat=remat)
        emb = apply_embedding(params["embed"], cfg, tokens)
        hidden, caches = encdec_lib.decode_train(
            params["encdec"], cfg, emb, enc_out, positions,
            want_cache=want_cache, remat=remat)
        return hidden, zero, caches
    tokens = inputs["tokens"]
    positions = jnp.arange(tokens.shape[1])
    x = apply_embedding(params["embed"], cfg, tokens)
    if sharder is not None:
        x = sharder(x, ("batch", "seq", "embed"))
    win = cache_window or (cfg.sliding_window or tokens.shape[1])
    x, aux, caches = dec_lib.apply_stack(
        params["blocks"], cfg, x, positions, sharder=sharder, remat=remat,
        want_cache=want_cache, cache_window=win if want_cache else None,
        param_sharder=param_sharder)
    x = apply_norm(params["ln_f"], x, cfg)
    return x, aux, caches


# ---------------------------------------------------------------------------
# decode (single token)
# ---------------------------------------------------------------------------


def decode(params, cfg: ModelConfig, inputs, caches, slots_state, *,
           window: int, param_sharder=None):
    """One-token decode. inputs: {"token": [B,1]}.
    -> (hidden [B,1,D], new caches, new slots_state)."""
    tok = inputs["token"]
    x = apply_embedding(params["embed"], cfg, tok)
    if cfg.family == "encdec":
        x, caches, slots_state = encdec_lib.decode_step(
            params["encdec"], cfg, x, caches, slots_state, window=window)
        return x, caches, slots_state
    x, caches, slots_state = dec_lib.decode_stack(
        params["blocks"], cfg, x, caches, slots_state, window=window,
        param_sharder=param_sharder)
    x = apply_norm(params["ln_f"], x, cfg)
    return x, caches, slots_state


def decode_window(cfg: ModelConfig, seq_len: int) -> int:
    """KV-cache slot count for a decode shape: full seq unless windowed."""
    if cfg.family == "ssm":
        return 1  # no KV cache at all (state only); window unused
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_decode_state(cfg: ModelConfig, batch: int, seq_len: int):
    """Fresh caches + slot bookkeeping for a decode-mode step at seq_len."""
    dt = jnp.dtype(cfg.dtype)
    window = decode_window(cfg, seq_len)
    if cfg.family == "encdec":
        caches = encdec_lib.init_encdec_decode_cache(cfg, batch, window, dt)
    else:
        caches = dec_lib.init_decode_cache(cfg, batch, window, dt)
    slots = dec_lib.init_cache_slots(cfg, max(window, 1))
    return caches, slots, window


def cache_logical_axes(cfg: ModelConfig):
    if cfg.family == "encdec":
        return encdec_lib.encdec_cache_axes(cfg)
    return dec_lib.cache_axes(cfg)


# ---------------------------------------------------------------------------
# inputs: concrete examples (smoke) and ShapeDtypeStructs (dry-run)
# ---------------------------------------------------------------------------


def _token_shape(cfg: ModelConfig, shape: InputShape):
    return (shape.global_batch, shape.seq_len)


def input_example(cfg: ModelConfig, shape: InputShape, key=None):
    """Concrete inputs for CPU smoke tests (reduced configs)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.mode == "decode":
        return {"token": jax.random.randint(key, (b, 1), 0, cfg.vocab_size)}
    if cfg.family == "cnn":
        return {"images": jax.random.normal(key, (b, 32, 32, 3), dt),
                "labels": jax.random.randint(key, (b,), 0, cfg.vocab_size)}
    if cfg.family == "encdec":
        k1, k2 = jax.random.split(key)
        toks = jax.random.randint(k2, (b, s + 1), 0, cfg.vocab_size)
        out = {"frames": jax.random.normal(k1, (b, cfg.enc_seq, cfg.d_model),
                                           dt),
               "tokens": toks[:, :s]}
        if shape.mode == "train":
            out["labels"] = toks[:, 1:]
        return out
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    out = {"tokens": toks[:, :s]}
    if shape.mode == "train":
        out["labels"] = toks[:, 1:]  # next-token targets
    return out


def input_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStruct stand-ins (no allocation) for lower()/compile().

    train/prefill: token (or image/frame) batch [+ labels for train].
    decode: one token [B,1]; caches/slots come from ``decode_state_specs``.
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32
    if shape.mode == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "cnn":
        specs = {"images": jax.ShapeDtypeStruct((b, 224, 224, 3), dt)}
        if shape.mode == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b,), i32)
        return specs
    specs = {}
    if cfg.family == "encdec":
        specs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_seq, cfg.d_model), dt)
    specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    if shape.mode == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs


def decode_state_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStructs for (caches, slots_state) of a decode step."""
    caches, slots, window = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len)[:2]
    ) + (decode_window(cfg, shape.seq_len),)
    return caches, slots, window
