"""Token-choice top-k MoE with capacity-based sort dispatch + expert parallel.

Dispatch is expressed with fixed shapes (sort + rank + scatter-with-drop) so
it lowers cleanly under GSPMD: the [E, C, D] expert buffer is sharded on the
expert axis over "model"; since token activations are replicated along
"model", dispatch gathers are local and the combine is a single all-reduce —
the TPU analogue of the all-to-all return path (DESIGN.md §2).

Includes the standard load-balance auxiliary loss and optional shared
(always-active) experts (Kimi-K2 / DeepSeek style).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import _dense_init

Sharder = Callable[[jax.Array, tuple], jax.Array]


def _identity_sharder(x, axes):
    return x


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (cfg.d_model, m.n_experts), in_axis=0),
        "wi_gate": _dense_init(ks[1], (m.n_experts, cfg.d_model, m.d_ff), in_axis=1),
        "wi_up": _dense_init(ks[2], (m.n_experts, cfg.d_model, m.d_ff), in_axis=1),
        "wo": _dense_init(ks[3], (m.n_experts, m.d_ff, cfg.d_model), in_axis=1),
    }
    if m.n_shared_experts > 0:
        d_sh = m.d_ff * m.n_shared_experts
        sks = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi_gate": _dense_init(sks[0], (cfg.d_model, d_sh), in_axis=0),
            "wi_up": _dense_init(sks[1], (cfg.d_model, d_sh), in_axis=0),
            "wo": _dense_init(sks[2], (d_sh, cfg.d_model), in_axis=0),
        }
    return p


def moe_axes(cfg: ModelConfig):
    a = {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "expert_mlp"),
        "wi_up": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }
    if cfg.moe.n_shared_experts > 0:
        a["shared"] = {"wi_gate": ("embed", "mlp"), "wi_up": ("embed", "mlp"),
                       "wo": ("mlp", "embed")}
    return a


def capacity_for(n_tokens: int, cfg: ModelConfig,
                 capacity_factor: Optional[float] = None) -> int:
    m = cfg.moe
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    c = int(n_tokens * m.top_k * cf / m.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch_group(xg, top_i, top_p, cap: int, n_experts: int, k: int):
    """Sort-based dispatch of ONE group (sequence). xg [t,d]; returns
    (buf [E, cap, d], combine metadata)."""
    t, d = xg.shape
    flat_e = top_i.reshape(-1)                       # [t*k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    rank = jnp.arange(t * k) - starts[sorted_e]      # position within expert
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, n_experts * cap)  # OOB drop
    src_token = order // k
    buf = jnp.zeros((n_experts * cap, d), xg.dtype)
    buf = buf.at[dest].set(xg[src_token], mode="drop")
    return buf.reshape(n_experts, cap, d), (dest, src_token, keep, order)


def _combine_group(eo, meta, top_p, t: int, k: int):
    """eo [E, cap, d] -> out [t, d] weighted scatter-add."""
    dest, src_token, keep, order = meta
    d = eo.shape[-1]
    eo_flat = eo.reshape(-1, d)
    back = jnp.where(keep[:, None],
                     eo_flat[jnp.where(keep, dest, 0)], 0.0)
    w = top_p.reshape(-1)[order]
    out = jnp.zeros((t, d), jnp.float32).at[src_token].add(
        back.astype(jnp.float32) * w[:, None])
    return out


def apply_moe(p, cfg: ModelConfig, x, *, sharder: Optional[Sharder] = None,
              capacity_factor: Optional[float] = None):
    """x: [B,S,D] -> (out [B,S,D], aux_loss scalar fp32).

    GROUP-WISE dispatch (GShard/MaxText style): each batch row is its own
    dispatch group, so sorts/ranks are vmapped per row and never cross the
    batch sharding — under GSPMD the only cross-device traffic is the expert
    GEMM's all-gather/reduce along the expert-sharded axis (the TPU analogue
    of the all-to-all; DESIGN.md §2)."""
    sharder = sharder or _identity_sharder
    m = cfg.moe
    b, s, d = x.shape
    if s == 1 and b > 1:
        # decode: per-sequence groups would pad every (token, expert) pair
        # to the minimum capacity (E x cap slots PER TOKEN — catastrophic
        # overcompute, found by the §Perf roofline). One global group.
        out, aux = apply_moe(p, cfg, x.reshape(1, b, d), sharder=sharder,
                             capacity_factor=capacity_factor)
        return out.reshape(b, s, d), aux
    k = m.top_k
    dt_ = x.dtype

    logits = (x @ p["router"].astype(dt_)).astype(jnp.float32)   # [B,S,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                        # [B,S,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))
    onehot_counts = jnp.zeros((m.n_experts,), jnp.float32).at[
        top_i.reshape(-1)].add(1.0)
    fe = onehot_counts / (b * s * k)
    aux = m.n_experts * jnp.sum(fe * me) * m.router_aux_coef

    cap = capacity_for(s, cfg, capacity_factor)

    buf, meta = jax.vmap(
        lambda xg, ti, tp: _dispatch_group(xg, ti, tp, cap, m.n_experts, k)
    )(x, top_i, top_p)                                # buf [B, E, cap, D]
    buf = sharder(buf, ("batch", "experts", None, "embed"))

    g = jnp.einsum("becd,edf->becf", buf, p["wi_gate"].astype(dt_))
    u = jnp.einsum("becd,edf->becf", buf, p["wi_up"].astype(dt_))
    act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
    eo = jnp.einsum("becf,efd->becd", act * u, p["wo"].astype(dt_))
    eo = sharder(eo, ("batch", "experts", None, "embed"))

    out = jax.vmap(
        lambda e, mt, tp: _combine_group(e, mt, tp, s, k)
    )(eo, meta, top_p).astype(dt_)

    if m.n_shared_experts > 0:
        sp = p["shared"]
        sg = x @ sp["wi_gate"].astype(dt_)
        su = x @ sp["wi_up"].astype(dt_)
        sact = jax.nn.silu(sg) if cfg.activation == "swiglu" else jax.nn.gelu(sg)
        out = out + (sact * su) @ sp["wo"].astype(dt_)

    return out, aux


def moe_ref_dense(p, cfg: ModelConfig, x):
    """Oracle: every token through its top-k experts via dense masking.
    O(T*E) — test-scale only."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d).astype(jnp.float32)
    logits = xf @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    gate = jnp.zeros((t, m.n_experts), jnp.float32)
    gate = gate.at[jnp.arange(t)[:, None], top_i].set(top_p)
    g = jnp.einsum("td,edf->tef", xf, p["wi_gate"].astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", xf, p["wi_up"].astype(jnp.float32))
    act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
    eo = jnp.einsum("tef,efd->ted", act * u, p["wo"].astype(jnp.float32))
    out = jnp.einsum("ted,te->td", eo, gate)
    if m.n_shared_experts > 0:
        sp = p["shared"]
        sg = xf @ sp["wi_gate"].astype(jnp.float32)
        su = xf @ sp["wi_up"].astype(jnp.float32)
        sact = jax.nn.silu(sg) if cfg.activation == "swiglu" else jax.nn.gelu(sg)
        out = out + (sact * su) @ sp["wo"].astype(jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype)
