"""GSPMD trainer/server for the architecture zoo (beyond-paper scale-out).

The paper's trunk (ResNet-50) replicates on every device; the assigned zoo
includes 1T-param MoEs that cannot, so the trunk here is tensor/expert-
parallel over "model" (+ FSDP over "data" for the big configs) via logical-
axis rules, while the *head keeps the paper's explicit hybrid-parallel
algorithm* — a shard_map over "model" whose body is ANY registered
``repro.api.SoftmaxHead`` strategy (full / knn / selective / mach / sampled
/ csoft), the same registry the faithful trainer uses. Batch is sharded
over ("pod","data"); per-head aux state (KNN graph, LSH tables, bucket
hashes) and head-owned trainable params travel as head-provided pytrees
(``make_head_train_step``). Legacy full/knn entry points remain as shims.
``HeadConfig.backend="pallas"`` works unchanged here too — the head body
carries the fused-kernel route (docs/kernels.md), so the zoo trainer
accepts it without a single branch in this module.

Provides the step builders the dry-run lowers for every
(arch × input-shape): train_step, prefill_step, serve_step (one decode token
through the KV/SSM cache + sharded-vocab argmax).
"""
from __future__ import annotations

import functools
from typing import TYPE_CHECKING, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import (
    HeadConfig,
    InputShape,
    ModelConfig,
    ParallelConfig,
    TrainConfig,
    effective_vocab,
)
from repro.core.sharded_softmax import serve_logits_local
from repro.models import lm
from repro.optim import apply_updates, make_optimizer

if TYPE_CHECKING:  # registry imported lazily inside the builders
    from repro.api.heads import SoftmaxHead  # noqa: F401


# ---------------------------------------------------------------------------
# logical axes -> PartitionSpecs
# ---------------------------------------------------------------------------


def pspec_of(axes: Optional[tuple], par: ParallelConfig) -> P:
    if axes is None:
        return P()
    return P(*(par.mesh_axis_for(a) if a is not None else None for a in axes))


def _mesh_sizes(par: ParallelConfig):
    return dict(zip(par.axis_names, par.mesh_shape))


def _entry_size(entry, sizes) -> int:
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for a in entry:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(entry, 1)


def fit_spec(spec: P, shape, par: ParallelConfig) -> P:
    """Drop mesh axes on dims they don't divide (MQA kv=1, batch=1, 3 heads
    on a 4-way axis, ...) — the dim falls back to replicated. Also drops a
    mesh axis that already appeared on an earlier dim (FSDP rules can collide
    with TP rules on some tensors)."""
    sizes = _mesh_sizes(par)
    used: set = set()
    out = []
    for i, entry in enumerate(tuple(spec) + (None,) * (len(shape) - len(spec))):
        names = (entry,) if isinstance(entry, str) else (entry or ())
        if any(a in used for a in names):
            out.append(None)
            continue
        n = _entry_size(entry, sizes)
        keep = entry if (n == 1 or shape[i] % n == 0) else None
        if keep is not None:
            used.update((keep,) if isinstance(keep, str) else keep)
        out.append(keep)
    return P(*out)


def _pspec_of_param(axes: Optional[tuple], par: ParallelConfig) -> P:
    if axes is None:
        return P()
    return P(*(par.mesh_axis_for_param(a) if a is not None else None
               for a in axes))


def param_pspecs(model_cfg: ModelConfig, par: ParallelConfig):
    """Parameter PartitionSpecs via par.param_rules (FSDP-aware)."""
    axes = lm.model_axes(model_cfg)
    params_shape = jax.eval_shape(
        lambda: lm.init_model(jax.random.PRNGKey(0), model_cfg))

    def walk(ax, shape_tree):
        if ax is None or isinstance(ax, tuple):
            base = ax if isinstance(ax, tuple) else None
            return jax.tree.map(
                lambda leaf: fit_spec(_pspec_of_param(base, par), leaf.shape,
                                      par),
                shape_tree)
        return {k: walk(ax.get(k), shape_tree[k]) for k in shape_tree}

    return walk(axes, params_shape)


def param_shardings(model_cfg: ModelConfig, par: ParallelConfig, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(model_cfg, par),
                        is_leaf=lambda x: isinstance(x, P))


def make_sharder(mesh, par: ParallelConfig):
    def sharder(x, axes):
        spec = fit_spec(pspec_of(axes, par), x.shape, par)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return sharder


def make_layer_param_sharder(model_cfg: ModelConfig, par: ParallelConfig,
                             mesh):
    """In-scan-body constraint on the per-layer param slice: TP sharding
    only (activation rules, no FSDP axis). When params are FSDP-sharded this
    forces GSPMD to all-gather each layer's weights inside the loop body
    instead of hoisting a whole-stack gather (per-layer working set).
    Returns None when FSDP is off (constraint would be a no-op)."""
    if par.param_rules is None:
        return None
    from repro.models import decoder as dec_lib
    if model_cfg.family in ("cnn", "feats", "encdec"):
        return None
    axes_tree = dec_lib.block_axes(model_cfg)

    def shard_layer(layer_p):
        def one(ax, leaf):
            spec = fit_spec(pspec_of(ax, par), leaf.shape, par)
            return jax.lax.with_sharding_constraint(
                leaf, NamedSharding(mesh, spec))
        return jax.tree.map(one, axes_tree, layer_p,
                            is_leaf=lambda t: isinstance(t, tuple))

    return shard_layer


def batch_pspec(par: ParallelConfig):
    return P(par.batch_axes)


# ---------------------------------------------------------------------------
# loss assembly — routed through the repro.api head registry
# ---------------------------------------------------------------------------


def vocab_axes(par: ParallelConfig):
    """(model_axis, vocab-axis tuple, residual batch axes) for the head
    shard_map. The vocab may be sharded over one axis ("model") or several
    (the paper's 1-D layout: every chip an fc shard — rule override
    vocab=data,model)."""
    vocab_ax = par.mesh_axis_for("vocab") or par.model_axis
    vax = vocab_ax if isinstance(vocab_ax, tuple) else (vocab_ax,)
    baxes = tuple(a for a in par.batch_axes if a not in vax)
    return vocab_ax, vax, baxes


def n_vocab_shards(par: ParallelConfig) -> int:
    _, vax, _ = vocab_axes(par)
    sizes = _mesh_sizes(par)
    n = 1
    for a in vax:
        n *= sizes.get(a, 1)
    return n


def make_head_loss_fn(model_cfg: ModelConfig, head_cfg: HeadConfig,
                      par: ParallelConfig, mesh, *, global_tokens: int,
                      head: Optional["SoftmaxHead"] = None):
    """Zoo loss through any registered ``repro.api.SoftmaxHead``.

    Returns ``loss_fn(params, head_params, head_aux, inputs, step=None)``.
    For W-heads (``head.params_are_class_weights``) the class matrix comes
    from the model itself (``lm.head_weight`` — tied embedding or
    ``params["head"]``) and ``head_params`` is ignored (pass ``()``); for
    sketch heads (mach / csoft) ``head_params`` is the head-owned trainable
    pytree. ``head_aux`` is the head-provided aux pytree (KNN graph, LSH
    tables, ...) placed with ``head.aux_spec``.
    """
    from repro.api.heads import make_head
    head = head or make_head(model_cfg, head_cfg)
    sharder = make_sharder(mesh, par)
    maxis, _, baxes = vocab_axes(par)
    param_sharder = make_layer_param_sharder(model_cfg, par, mesh)
    hp_spec = head.params_spec(maxis)
    aux_spec = head.aux_spec(maxis)
    metrics_spec = dict(head.metrics_spec())

    def loss_fn(params, head_params, head_aux, inputs, step=None):
        h, aux_l, _ = lm.backbone(params, model_cfg, inputs, sharder=sharder,
                                  remat=par.remat,
                                  param_sharder=param_sharder)
        f = h.reshape(-1, h.shape[-1])
        labels = inputs["labels"].reshape(-1)
        f = sharder(f, ("batch", "embed"))
        hp = (lm.head_weight(params, model_cfg)
              if head.params_are_class_weights else head_params)
        if step is None:
            step = jnp.zeros((), jnp.int32)

        def body(f_loc, y_loc, hp_loc, aux_loc, step_no):
            return head.loss_local(
                f_loc, y_loc, hp_loc, aux_loc, model_axis=maxis,
                batch_axes=baxes, global_batch=global_tokens, step=step_no)

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(baxes or None, None), P(baxes or None), hp_spec,
                      aux_spec, P()),
            out_specs=(P(), metrics_spec), check_vma=False)
        loss, metrics = fn(f, labels, hp, head_aux, step)
        return loss + aux_l, metrics

    return loss_fn


def make_loss_fn(model_cfg: ModelConfig, head_cfg: HeadConfig,
                 par: ParallelConfig, mesh, *, global_tokens: int,
                 use_knn: bool = False, m_local: int = 0):
    """Back-compat full/knn zoo loss: ``loss_fn(params, inputs, graph=None)``
    with the knn graph threaded by the caller. A thin shim over
    ``make_head_loss_fn`` — ``use_knn`` forces the knn head and ``m_local``
    is accepted but unused (the head derives it from ``active_frac``). The
    historical zoo numerics are preserved: raw logits for the full softmax
    on LM trunks, cosine logits for knn and cnn/feats trunks."""
    import dataclasses
    impl = "knn" if (use_knn or head_cfg.softmax_impl == "knn") else "full"
    cosine = (16.0 if (impl == "knn" or model_cfg.family in ("cnn", "feats"))
              else 0.0)
    hcfg = dataclasses.replace(head_cfg, softmax_impl=impl,
                               cosine_scale=cosine)
    inner = make_head_loss_fn(model_cfg, hcfg, par, mesh,
                              global_tokens=global_tokens)

    def loss_fn(params, inputs, graph=None):
        aux = tuple(graph) if graph is not None else ()
        return inner(params, (), aux, inputs)

    return loss_fn


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def auto_micro_batches(model_cfg: ModelConfig, par: ParallelConfig,
                       shape: InputShape, *, target_tokens_per_dev: int = 8192
                       ) -> int:
    """Micro-batch count for the paper's §3.3.1 pipeline: bound per-device
    per-microbatch tokens to ~target (remat working set and per-µbatch
    feature all-gather size scale with it). Must divide the per-data-shard
    batch; powers of two only."""
    sizes = _mesh_sizes(par)
    shards = 1
    for a in par.batch_axes:
        shards *= sizes.get(a, 1)
    per_shard_b = max(1, shape.global_batch // shards)
    seq = 1 if model_cfg.family == "cnn" else shape.seq_len
    per_dev_tokens = per_shard_b * seq
    n = 1
    while (n < per_shard_b and per_dev_tokens // n > target_tokens_per_dev
           and per_shard_b % (n * 2) == 0):
        n *= 2
    return n


def _step_tokens(model_cfg: ModelConfig, shape: InputShape) -> int:
    return shape.global_batch * (1 if model_cfg.family == "cnn"
                                 else shape.seq_len)


def make_head_train_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                         par: ParallelConfig, train_cfg: TrainConfig, mesh,
                         shape: InputShape, *,
                         head: Optional["SoftmaxHead"] = None,
                         n_micro: Optional[int] = None):
    """Registry-routed zoo train step for ANY registered softmax head:

        step(params, head_state, opt_state, inputs, lr)
            -> (params, head_state, opt_state, loss, metrics)

    ``head_state`` is a ``repro.api.HeadState``: ``params`` is the
    head-owned trainable pytree (``()`` for W-heads, whose class matrix
    lives in the model params) and ``aux`` the non-trainable pytree (KNN
    graph, LSH tables, bucket hashes). The optimizer state must be built
    over ``(params, head_state.params)``; aux is carried through unchanged
    (rebuilds happen outside the step via ``head.refresh``).
    """
    from repro.api.heads import HeadState, make_head
    from repro.core.pipeline import microbatched_value_and_grad

    head = head or make_head(model_cfg, head_cfg)
    if n_micro is None:
        n_micro = (train_cfg.micro_batch
                   or auto_micro_batches(model_cfg, par, shape))
    tokens = _step_tokens(model_cfg, shape)
    loss_fn = make_head_loss_fn(model_cfg, head_cfg, par, mesh,
                                global_tokens=tokens // n_micro, head=head)
    opt = make_optimizer(train_cfg)

    def train_step(params, head_state, opt_state, inputs, lr):
        step_no = opt_state.step
        (loss, metrics), grads = microbatched_value_and_grad(
            lambda p, x: loss_fn(p[0], p[1], head_state.aux, x, step=step_no),
            (params, head_state.params), inputs, n_micro)
        updates, opt_state = opt.update(grads, opt_state,
                                        (params, head_state.params), lr)
        params, hp = apply_updates((params, head_state.params), updates)
        return (params, HeadState(hp, head_state.aux), opt_state, loss,
                metrics)

    return train_step


def make_head_eval_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                        par: ParallelConfig, mesh, *,
                        head: Optional["SoftmaxHead"] = None):
    """Deploy-style distributed top-1 accuracy through the head's own
    ``eval_logits_local`` (§4.5 retrieval for W-heads, hashed-bucket decode
    for the sketch heads) — the zoo counterpart of
    ``hybrid.make_eval_step``. Returns
    ``eval_fn(params, head_params, head_aux, inputs) -> accuracy``."""
    from repro.api.heads import make_head
    head = head or make_head(model_cfg, head_cfg)
    sharder = make_sharder(mesh, par)
    maxis, _, baxes = vocab_axes(par)
    param_sharder = make_layer_param_sharder(model_cfg, par, mesh)
    hp_spec = head.params_spec(maxis)
    aux_spec = head.aux_spec(maxis)

    def eval_fn(params, head_params, head_aux, inputs):
        h, _, _ = lm.backbone(params, model_cfg, inputs, sharder=sharder,
                              remat=par.remat, param_sharder=param_sharder)
        f = h.reshape(-1, h.shape[-1])
        labels = inputs["labels"].reshape(-1)
        f = sharder(f, ("batch", "embed"))
        hp = (lm.head_weight(params, model_cfg)
              if head.params_are_class_weights else head_params)

        def body(f_loc, y_loc, hp_loc, aux_loc):
            pred, _ = head.eval_logits_local(f_loc, hp_loc, aux_loc,
                                             model_axis=maxis)
            correct = jnp.mean((pred == y_loc).astype(jnp.float32))
            return jax.lax.pmean(correct, baxes) if baxes else correct

        fn = jax.shard_map(
            body, mesh=mesh,
            in_specs=(P(baxes or None, None), P(baxes or None), hp_spec,
                      aux_spec),
            out_specs=P(), check_vma=False)
        return fn(f, labels, hp, head_aux)

    return eval_fn


def make_feature_serve_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                            par: ParallelConfig, mesh, *,
                            top_k: Optional[int] = None,
                            head: Optional["SoftmaxHead"] = None,
                            donate: bool = True):
    """Zoo entry for the serving tier (``repro.serving``): classify
    pre-computed backbone features against the model's class matrix.

    Queries arrive as a PADDED fixed-shape micro-batch [b_pad, D]
    replicated across the mesh, with only the first ``n_queries`` rows
    real (a traced scalar — one compile per padding bucket). Returns
    ``(params, head_params, head_aux, queries, n_queries) ->``
    pred [b_pad] int32 (``top_k=None``; any registry head, via its own
    ``eval_logits_local``) or (vals [b_pad, k], gids [b_pad, k])
    (``top_k=k``; W-heads only). Padded rows come back -1 / (-inf, -1).
    """
    from repro.api.heads import make_head
    from repro.core.sharded_softmax import (_normalize, mask_padded_rows,
                                            serve_topk_batched_local)
    head = head or make_head(model_cfg, head_cfg)
    if top_k is not None and not head.params_are_class_weights:
        raise NotImplementedError(
            f"top-k serving retrieves against the [V, D] class matrix, "
            f"which the {head.name!r} head does not train; use a W-head "
            f"(full/knn/selective/sampled)")
    maxis, _, _ = vocab_axes(par)
    hp_spec = head.params_spec(maxis)
    aux_spec = head.aux_spec(maxis)

    def body(hp_loc, aux_loc, queries, n_queries):
        if top_k is None:
            pred, _ = head.eval_logits_local(queries, hp_loc, aux_loc,
                                             model_axis=maxis)
            return mask_padded_rows(pred.astype(jnp.int32), n_queries, -1)
        f = queries.astype(jnp.float32)
        w = hp_loc.astype(jnp.float32)
        if head_cfg.cosine_scale > 0:
            f, w = _normalize(f), _normalize(w)
        return serve_topk_batched_local(
            f, w, top_k, n_queries, model_axis=maxis, n_valid=head.n_valid,
            backend=head.backend)

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(hp_spec, aux_spec, P(), P()),
                       out_specs=P(), check_vma=False)

    def step(params, head_params, head_aux, queries, n_queries):
        hp = (lm.head_weight(params, model_cfg)
              if head.params_are_class_weights else head_params)
        return fn(hp, head_aux, queries, n_queries)

    return jax.jit(step, donate_argnums=(3,)) if donate else jax.jit(step)


def make_feature_ivf_serve_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                                par: ParallelConfig, mesh, top_k: int, *,
                                nprobe: int,
                                head: Optional["SoftmaxHead"] = None,
                                donate: bool = True):
    """Zoo sublinear top-k through an ``IVFIndex`` (mirrors
    ``make_feature_serve_step``'s top-k contract): ``(params, head_params,
    head_aux, centroids [P, C, D], members [P, C, cap], queries [b_pad, D],
    n_queries) -> (vals [b_pad, k], gids [b_pad, k])``. Each vocab shard
    probes its ``nprobe`` nearest centroids and reranks only their member
    rows (``serve_topk_ivf_batched_local``; pallas backend = the fused
    ``ops.ivf_rerank`` kernel). W-heads only."""
    from repro.api.heads import make_head
    from repro.core.sharded_softmax import (_normalize,
                                            serve_topk_ivf_batched_local)
    head = head or make_head(model_cfg, head_cfg)
    if not head.params_are_class_weights:
        raise NotImplementedError(
            f"top-k serving retrieves against the [V, D] class matrix, "
            f"which the {head.name!r} head does not train; use a W-head "
            f"(full/knn/selective/sampled)")
    maxis, _, _ = vocab_axes(par)
    hp_spec = head.params_spec(maxis)

    def body(hp_loc, cent, members, queries, n_queries):
        f = queries.astype(jnp.float32)
        w = hp_loc.astype(jnp.float32)
        if head_cfg.cosine_scale > 0:
            f, w = _normalize(f), _normalize(w)
        return serve_topk_ivf_batched_local(
            f, w, cent[0], members[0], top_k, nprobe, n_queries,
            model_axis=maxis, backend=head.backend, block_a=head.block_a)

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(hp_spec, P(maxis, None, None),
                                 P(maxis, None, None), P(), P()),
                       out_specs=P(), check_vma=False)

    def step(params, head_params, head_aux, centroids, members, queries,
             n_queries):
        del head_aux
        hp = (lm.head_weight(params, model_cfg)
              if head.params_are_class_weights else head_params)
        return fn(hp, centroids, members, queries, n_queries)

    return jax.jit(step, donate_argnums=(5,)) if donate else jax.jit(step)


def make_train_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                    par: ParallelConfig, train_cfg: TrainConfig, mesh,
                    shape: InputShape, *, use_knn: bool = False,
                    n_micro: Optional[int] = None):
    """Back-compat full/knn zoo step (shim over the registry path):
    ``step(params, opt_state, inputs[, graph], lr)`` — the knn graph is a
    positional argument when ``use_knn`` (or the head config) selects knn.
    New code should use ``make_head_train_step``."""
    from repro.core.pipeline import microbatched_value_and_grad

    use_knn = use_knn or head_cfg.softmax_impl == "knn"
    if n_micro is None:
        n_micro = (train_cfg.micro_batch
                   or auto_micro_batches(model_cfg, par, shape))
    tokens = _step_tokens(model_cfg, shape)
    loss_fn = make_loss_fn(model_cfg, head_cfg, par, mesh,
                           global_tokens=tokens // n_micro, use_knn=use_knn)
    opt = make_optimizer(train_cfg)

    if use_knn:
        def train_step(params, opt_state, inputs, graph, lr):
            (loss, metrics), grads = microbatched_value_and_grad(
                lambda p, x: loss_fn(p, x, graph), params, inputs, n_micro)
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return params, opt_state, loss, metrics
    else:
        def train_step(params, opt_state, inputs, lr):
            (loss, metrics), grads = microbatched_value_and_grad(
                loss_fn, params, inputs, n_micro)
            updates, opt_state = opt.update(grads, opt_state, params, lr)
            params = apply_updates(params, updates)
            return params, opt_state, loss, metrics

    return train_step


def make_prefill_step(model_cfg: ModelConfig, par: ParallelConfig, mesh,
                      shape: InputShape):
    """Prefill: full forward + caches + last-position greedy token."""
    sharder = make_sharder(mesh, par)
    maxis = par.model_axis

    param_sharder = make_layer_param_sharder(model_cfg, par, mesh)

    def prefill_step(params, inputs):
        window = lm.decode_window(model_cfg, shape.seq_len)
        h, _, caches = lm.backbone(params, model_cfg, inputs, sharder=sharder,
                                   remat=par.remat, want_cache=True,
                                   cache_window=window,
                                   param_sharder=param_sharder)
        f = h[:, -1, :]
        w = lm.head_weight(params, model_cfg)
        n_valid = (effective_vocab(model_cfg)
                   if model_cfg.real_vocab_size else 0)
        bax = fit_spec(P(par.batch_axes), (shape.global_batch,), par)[0]
        fn = jax.shard_map(
            functools.partial(serve_logits_local, model_axis=maxis,
                              n_valid=n_valid),
            mesh=mesh,
            in_specs=(P(bax, None), P(maxis, None)),
            out_specs=(P(bax), P(bax, maxis)),
            check_vma=False)
        token, _ = fn(f, w)
        return token, caches

    return prefill_step


def make_serve_step(model_cfg: ModelConfig, par: ParallelConfig, mesh,
                    shape: InputShape):
    """One decode token through the cache + sharded-vocab greedy sample."""
    maxis = par.model_axis
    window = lm.decode_window(model_cfg, shape.seq_len)

    param_sharder = make_layer_param_sharder(model_cfg, par, mesh)

    def serve_step(params, caches, slots, token):
        h, caches, slots = lm.decode(params, model_cfg, {"token": token},
                                     caches, slots, window=window,
                                     param_sharder=param_sharder)
        f = h[:, 0, :]
        w = lm.head_weight(params, model_cfg)
        n_valid = (effective_vocab(model_cfg)
                   if model_cfg.real_vocab_size else 0)
        bax = fit_spec(P(par.batch_axes), (shape.global_batch,), par)[0]
        fn = jax.shard_map(
            functools.partial(serve_logits_local, model_axis=maxis,
                              n_valid=n_valid),
            mesh=mesh,
            in_specs=(P(bax, None), P(maxis, None)),
            out_specs=(P(bax), P(bax, maxis)),
            check_vma=False)
        next_token, _ = fn(f, w)
        return next_token[:, None], caches, slots

    return serve_step


# ---------------------------------------------------------------------------
# sharding trees for the dry-run
# ---------------------------------------------------------------------------


def cache_pspecs(model_cfg: ModelConfig, par: ParallelConfig, shape: InputShape):
    ax = lm.cache_logical_axes(model_cfg)
    caches, slots, _ = lm.decode_state_specs(model_cfg, shape)

    def one(t, leaf):
        return fit_spec(pspec_of(t, par), leaf.shape, par)

    cache_specs = jax.tree.map(one, ax, caches,
                               is_leaf=lambda t: isinstance(t, tuple))
    slot_specs = jax.tree.map(lambda _: P(), slots)
    return cache_specs, slot_specs


def input_pspecs(model_cfg: ModelConfig, shape: InputShape,
                 par: ParallelConfig):
    specs = lm.input_specs(model_cfg, shape)
    return jax.tree.map(
        lambda s: fit_spec(batch_pspec(par), s.shape, par), specs)
