"""FCCS-driven training loop for the paper system (hybrid trainer).

Orchestrates: warm-up LR, continuous batch growth via gradient accumulation
(quantized to powers of two so at most log2(64) step variants compile), the
head's periodic refresh (KNN graph rebuild / LSH table rebuild — training
"suspended", as the paper does at epoch boundaries), periodic checkpoints
and eval.

The softmax head is whatever ``head_cfg.softmax_impl`` names in the
``repro.api`` registry; the trainer never branches on the head kind — it
only honors the head's ``refresh_every`` cadence.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax

from repro import checkpoint as ckpt_lib
from repro.api.heads import make_head
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.core import fccs
from repro.train import hybrid


def _pow2_quantize(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclass
class PaperTrainer:
    model_cfg: ModelConfig
    head_cfg: HeadConfig
    train_cfg: TrainConfig
    mesh: object
    data_fn: Callable[[int, int], dict]     # (step, global_batch) -> inputs
    hw_batch: int                           # per-update device-limited batch
    use_knn: bool = False                   # deprecated alias for
                                            # head_cfg.softmax_impl="knn"
    lr_fn: Optional[Callable[[int], float]] = None  # default: FCCS policy
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    log_every: int = 10
    seed: int = 0
    history: list = field(default_factory=list)

    def __post_init__(self):
        if self.use_knn and self.head_cfg.softmax_impl == "full":
            import dataclasses
            self.head_cfg = dataclasses.replace(self.head_cfg,
                                                softmax_impl="knn")
        n_dev = self.mesh.shape[hybrid.AXIS]
        self.n_dev = n_dev
        self.head = make_head(self.model_cfg, self.head_cfg)
        self.state = hybrid.init_state(
            jax.random.PRNGKey(self.seed), self.model_cfg, self.head_cfg,
            self.train_cfg, n_dev, head=self.head)
        self._steps = {}
        # initial refresh: heads with derived aux state (KNN graph, LSH
        # tables) build it from the freshly-initialized weights; a no-op
        # for heads without periodic work.
        self.refresh_head()
        self.eval_step = hybrid.make_eval_step(
            self.model_cfg, self.head_cfg, self.mesh, self.state,
            head=self.head)

    def _get_step(self, n_micro: int):
        if n_micro not in self._steps:
            self._steps[n_micro] = hybrid.make_train_step(
                self.model_cfg, self.head_cfg, self.train_cfg, self.mesh,
                n_micro=n_micro, head=self.head, state_template=self.state)
        return self._steps[n_micro]

    def refresh_head(self):
        """Paper §3.2.2: suspend training, rebuild the head's aux state on
        the training devices, resume. Returns the wall-clock spent."""
        t0 = time.perf_counter()
        self.state = hybrid.refresh_head_state(self.head, self.mesh,
                                               self.state)
        return time.perf_counter() - t0

    # back-compat name (pre-registry API)
    rebuild_graph = refresh_head

    def run(self, total_steps: int, *, use_fccs_batch: bool = True):
        fcfg = self.train_cfg.fccs
        refresh_every = self.head.refresh_every
        with jax.set_mesh(self.mesh):
            for t in range(total_steps):
                lr = (self.lr_fn(t) if self.lr_fn is not None
                      else fccs.learning_rate(t, fcfg))
                n = (_pow2_quantize(fccs.accum_steps(t, fcfg, self.hw_batch))
                     if use_fccs_batch else 1)
                inputs = self.data_fn(t, self.hw_batch * n)
                step = self._get_step(n)
                self.state, loss, metrics = step(self.state, inputs, lr)
                if refresh_every and (t + 1) % refresh_every == 0:
                    self.refresh_head()
                if self.ckpt_dir and self.ckpt_every and \
                        (t + 1) % self.ckpt_every == 0:
                    ckpt_lib.save(self.ckpt_dir,
                                  {"fe": self.state.fe_params,
                                   "head": self.state.head_params},
                                  step=t + 1)
                row = {"step": t, "lr": lr, "batch": self.hw_batch * n,
                       "loss": float(loss),
                       "acc": float(metrics["accuracy"])}
                self.history.append(row)
                if self.log_every and t % self.log_every == 0:
                    print(f"[train] step={t} lr={lr:.4f} B={row['batch']} "
                          f"loss={row['loss']:.4f} acc={row['acc']:.3f}")
        return self.history

    def evaluate(self, eval_inputs) -> float:
        with jax.set_mesh(self.mesh):
            return float(self.eval_step(self.state, eval_inputs))
