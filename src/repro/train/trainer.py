"""FCCS-driven training loop for the paper system (hybrid trainer).

Orchestrates: warm-up LR, continuous batch growth via gradient accumulation
(quantized to powers of two so at most log2(64) step variants compile), the
head's periodic refresh (KNN graph rebuild / LSH table rebuild — training
"suspended", as the paper does at epoch boundaries), periodic checkpoints
and eval.

The softmax head is whatever ``head_cfg.softmax_impl`` names in the
``repro.api`` registry; the trainer never branches on the head kind — it
only honors the head's ``refresh_every`` cadence.

Checkpoints are FULL-state snapshots (docs/resilience.md): FE params, head
params AND head aux (KNN graph / LSH tables / sketch hashes), optimizer
moments, DGC error-feedback buffers, and the data cursor / step counter —
everything a killed run needs for ``restore_checkpoint`` to continue
step-for-step equivalent to an uninterrupted run. The FCCS schedule and
the synthetic data stream are pure functions of the cursor, so saving the
cursor IS saving the schedule state. ``run`` resumes from the cursor, and
``step_hook`` is the fault-injection seam (``repro.resilience``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.api.heads import HeadState, make_head
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.core import fccs
from repro.core import sparsify as sp
from repro.telemetry import NULL_TRACER
from repro.train import hybrid


def _pow2_quantize(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclass
class PaperTrainer:
    model_cfg: ModelConfig
    head_cfg: HeadConfig
    train_cfg: TrainConfig
    mesh: object
    data_fn: Callable[[int, int], dict]     # (step, global_batch) -> inputs
    hw_batch: int                           # per-update device-limited batch
    use_knn: bool = False                   # deprecated alias for
                                            # head_cfg.softmax_impl="knn"
    lr_fn: Optional[Callable[[int], float]] = None  # default: FCCS policy
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 0
    ckpt_keep: int = 0                      # 0 = retain every checkpoint
    log_every: int = 10
    seed: int = 0
    history: list = field(default_factory=list)
    telemetry: object = None                # Tracer, or None = NULL_TRACER

    def __post_init__(self):
        if self.use_knn and self.head_cfg.softmax_impl == "full":
            import dataclasses
            self.head_cfg = dataclasses.replace(self.head_cfg,
                                                softmax_impl="knn")
        n_dev = self.mesh.shape[hybrid.AXIS]
        self.n_dev = n_dev
        self.head = make_head(self.model_cfg, self.head_cfg)
        self.state = hybrid.init_state(
            jax.random.PRNGKey(self.seed), self.model_cfg, self.head_cfg,
            self.train_cfg, n_dev, head=self.head)
        self._steps = {}
        self._t = 0          # data cursor: next step index run() will take
        self.restores = 0    # bumped on every restore (serving-cache probe)
        self.last_reshard = None   # stats dict of the last elastic restore
        # initial refresh: heads with derived aux state (KNN graph, LSH
        # tables) build it from the freshly-initialized weights; a no-op
        # for heads without periodic work.
        self.refresh_head()
        self.eval_step = hybrid.make_eval_step(
            self.model_cfg, self.head_cfg, self.mesh, self.state,
            head=self.head)

    def _get_step(self, n_micro: int):
        if n_micro not in self._steps:
            self._steps[n_micro] = hybrid.make_train_step(
                self.model_cfg, self.head_cfg, self.train_cfg, self.mesh,
                n_micro=n_micro, head=self.head, state_template=self.state)
        return self._steps[n_micro]

    def refresh_head(self):
        """Paper §3.2.2: suspend training, rebuild the head's aux state on
        the training devices, resume. Returns the wall-clock spent."""
        tr = self.telemetry or NULL_TRACER
        t0 = time.perf_counter()
        with tr.span("train.refresh"):
            self.state = hybrid.refresh_head_state(self.head, self.mesh,
                                                   self.state)
        tr.count("train.refreshes")
        return time.perf_counter() - t0

    # back-compat name (pre-registry API)
    rebuild_graph = refresh_head

    # -- full-state checkpoint / restore ----------------------------------

    def _snapshot(self):
        """The checkpoint pytree: EVERYTHING the step function consumes,
        plus the cursor the outer loop consumes. Same structure every
        save, so any snapshot restores into any fresh trainer of the same
        config (leaf shapes may differ — the checkpoint stores them)."""
        st = self.state
        tree = {
            "fe": st.fe_params,
            "head": self.head.state_to_save(
                HeadState(st.head_params, st.head_aux)),
            "opt": st.opt_state,
            "extra": {"t": jnp.asarray(self._t, jnp.int32),
                      "step": jnp.asarray(st.step, jnp.int32),
                      "seed": jnp.asarray(self.seed, jnp.int32)},
        }
        if st.dgc is not None:
            tree["dgc"] = {"u": st.dgc.u, "v": st.dgc.v}
        return tree

    def geometry(self):
        """This trainer's ``repro.elastic.MeshGeometry`` (the hybrid ring
        is both the model and the data axis)."""
        from repro.elastic import MeshGeometry
        return MeshGeometry(n_model=self.n_dev, n_data=self.n_dev,
                            n_classes=self.model_cfg.vocab_size)

    def save_checkpoint(self) -> str:
        """Atomic full-state snapshot at the current cursor. The mesh
        geometry rides along as checkpoint meta so a restore on a
        different ring is caught up front (or resharded — repro.elastic)."""
        assert self.ckpt_dir, "trainer has no ckpt_dir"
        meta = {"system": "paper", **self.geometry().meta()}
        return ckpt_lib.save(self.ckpt_dir, self._snapshot(), step=self._t,
                             keep=self.ckpt_keep or None, meta=meta)

    def restore_checkpoint(self, step: Optional[int] = None, *,
                           reshard: bool = False) -> int:
        """Refill the FULL trainer state from ``ckpt_dir`` (latest step by
        default) and move the data cursor so the next ``run`` continues the
        killed run step-for-step. ``reshard=True`` accepts a checkpoint
        written on a DIFFERENT ring size and re-shards it onto this one
        (repro.elastic); without it a mesh mismatch raises ``ReshardError``
        before any leaf is decoded. Returns the restored step."""
        assert self.ckpt_dir, "trainer has no ckpt_dir"
        from jax.sharding import NamedSharding

        tr = self.telemetry or NULL_TRACER
        with tr.span("train.restore"):
            return self._restore_checkpoint(step, NamedSharding, tr,
                                            reshard)

    def _restore_checkpoint(self, step, NamedSharding, tr, reshard) -> int:
        from repro import elastic
        dst = self.geometry()
        src = ckpt_lib.validate_restore(self.ckpt_dir, dst, step,
                                        reshard=reshard)
        tree, step = ckpt_lib.restore(self.ckpt_dir, self._snapshot(), step)
        specs = hybrid.state_specs(self.state, self.head)
        mesh = self.mesh

        needs_refresh, plan = False, None
        if src.n_model != dst.n_model:
            t0 = time.perf_counter()
            with tr.span("train.reshard",
                         attrs={"src": src.describe(),
                                "dst": dst.describe()}):
                tree, needs_refresh, led = elastic.reshard_paper_snapshot(
                    tree, self.head, src, dst)
                plan = elastic.plan_reshard(src, dst)
                if not plan.aligned and self.head.params_are_class_weights:
                    # host-staged chunked placement of the dense rows (the
                    # aligned case device_puts gather-free below)
                    tree["head"]["params"] = elastic.place_row_sharded(
                        tree["head"]["params"], mesh, hybrid.AXIS, plan)
            bytes_moved = led.total_bytes()
            tr.count("reshard.bytes_moved", bytes_moved)
            self.last_reshard = {
                "src": src, "dst": dst, "plan": plan.describe(),
                "bytes_moved": bytes_moved, "ledger": led,
                "seconds": time.perf_counter() - t0}

        def put(subtree, spec_tree):
            return jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                subtree, spec_tree)

        fe = put(tree["fe"], specs.fe_params)
        hs = self.head.state_from_restore(tree["head"], mesh,
                                          model_axis=hybrid.AXIS)
        opt = put(tree["opt"], specs.opt_state)
        dgc = None
        if self.state.dgc is not None:
            dgc = sp.DGCState(u=put(tree["dgc"]["u"], specs.dgc.u),
                              v=put(tree["dgc"]["v"], specs.dgc.v))
        self.state = hybrid.HybridState(
            fe, hs.params, hs.aux, opt, dgc,
            jnp.asarray(tree["extra"]["step"], jnp.int32))
        self._t = int(tree["extra"]["t"])
        self.restores += 1
        tr.count("train.restores")
        if needs_refresh:
            # the head had aux with no exact re-pack rule: run its own
            # refresh path on the dst mesh (the tentpole's rebuild leg)
            self.refresh_head()
        return step

    # -- the loop ----------------------------------------------------------

    def run(self, total_steps: int, *, use_fccs_batch: bool = True,
            step_hook: Optional[Callable[[int], None]] = None):
        """Run ``total_steps`` MORE steps from the current cursor (0 for a
        fresh trainer; the restored step after ``restore_checkpoint``).
        ``step_hook(t)`` fires before each step — the fault-injection seam
        (``repro.resilience.faults``); whatever it raises propagates after
        any due checkpoint of the previous step was already written."""
        fcfg = self.train_cfg.fccs
        refresh_every = self.head.refresh_every
        start = self._t
        tr = self.telemetry or NULL_TRACER
        with jax.set_mesh(self.mesh):
            for t in range(start, start + total_steps):
                if step_hook is not None:
                    step_hook(t)
                lr = (self.lr_fn(t) if self.lr_fn is not None
                      else fccs.learning_rate(t, fcfg))
                n = (_pow2_quantize(fccs.accum_steps(t, fcfg, self.hw_batch))
                     if use_fccs_batch else 1)
                with tr.span("train.data"):
                    inputs = self.data_fn(t, self.hw_batch * n)
                    step = self._get_step(n)
                with tr.span("train.step"):
                    self.state, loss, metrics = step(self.state, inputs, lr)
                    if tr.enabled:
                        # async dispatch would end the span at launch time;
                        # only a live tracer pays for the sync
                        jax.block_until_ready(loss)
                tr.count("train.steps")
                self._t = t + 1
                if refresh_every and (t + 1) % refresh_every == 0:
                    self.refresh_head()
                if self.ckpt_dir and self.ckpt_every and \
                        (t + 1) % self.ckpt_every == 0:
                    with tr.span("train.checkpoint"):
                        self.save_checkpoint()
                    tr.count("train.checkpoints")
                row = {"step": t, "lr": lr, "batch": self.hw_batch * n,
                       "loss": float(loss),
                       "acc": float(metrics["accuracy"])}
                self.history.append(row)
                tr.log_metrics(row)
                if self.log_every and t % self.log_every == 0:
                    print(f"[train] step={t} lr={lr:.4f} B={row['batch']} "
                          f"loss={row['loss']:.4f} acc={row['acc']:.3f}")
        tr.record_peak_memory()
        return self.history

    def evaluate(self, eval_inputs) -> float:
        with jax.set_mesh(self.mesh):
            return float(self.eval_step(self.state, eval_inputs))
