from repro.train import gspmd, hybrid  # noqa: F401
from repro.train.trainer import PaperTrainer  # noqa: F401
