"""The paper's hybrid-parallel trainer (faithful reproduction, §3.1-§3.4).

Layout = the paper's exactly, generalized to a 1-D device ring ("hybrid"
axis over all chips): every device is BOTH a data-parallel FE replica (FE
params replicated; batch sharded over the ring) AND a model-parallel fc
shard (W row-sharded over the ring). Per (micro-)batch:

  FE local forward -> all-gather features along the ring -> each device
  scores the whole (micro-)batch against its class shard -> distributed
  softmax (pmax/psum) -> backward; fc grads STAY LOCAL; FE grads cross the
  ring once per step — dense psum or DGC top-k sparsified (§3.3.2).

Micro-batching (§3.3.1) runs as a lax.scan whose per-iteration all-gather the
XLA latency-hiding scheduler overlaps with the next iteration's FE compute;
it is also FCCS's gradient-accumulation mechanism (n× batch growth).

Everything is a single shard_map over the full mesh — all collectives
explicit, nothing left to GSPMD — so the HLO *is* the paper's Fig. 2/4.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.core import knn_graph as kg
from repro.core import sparsify as sp
from repro.core.knn_softmax import knn_softmax_local
from repro.core.pipeline import microbatched_value_and_grad
from repro.core.sharded_softmax import full_softmax_local, serve_logits_local
from repro.models import lm
from repro.optim import apply_updates, make_optimizer

AXIS = "hybrid"

FULL_METRICS = {"accuracy": P(), "logz": P()}
KNN_METRICS = {"accuracy": P(), "logz": P(), "active_frac": P(),
               "label_recall": P()}


def make_hybrid_mesh(n_dev: Optional[int] = None):
    n = n_dev or len(jax.devices())
    return jax.make_mesh((n,), (AXIS,),
                         axis_types=(jax.sharding.AxisType.Auto,))


class HybridState(NamedTuple):
    fe_params: dict        # replicated
    w_head: jax.Array      # [V, D] sharded over AXIS (rows)
    opt_state: object
    dgc: Optional[sp.DGCState]   # leaves carry leading [n_dev] axis
    step: jax.Array


def init_state(key, model_cfg: ModelConfig, head_cfg: HeadConfig,
               train_cfg: TrainConfig, n_dev: int) -> HybridState:
    k1, k2 = jax.random.split(key)
    fe_params = lm.init_model(k1, model_cfg)
    fe_params.pop("head", None)   # the fc lives separately, sharded
    w_head = (jax.random.normal(k2, (model_cfg.vocab_size, model_cfg.d_model))
              / jnp.sqrt(model_cfg.d_model)).astype(jnp.float32)
    opt = make_optimizer(train_cfg)
    opt_state = opt.init((fe_params, w_head))
    dgc = None
    if train_cfg.dgc.enabled:
        z = sp.init_dgc_state(fe_params)
        dgc = sp.DGCState(
            u=jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_dev,) + a.shape), z.u),
            v=jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_dev,) + a.shape), z.v),
        )
    return HybridState(fe_params, w_head, opt_state, dgc,
                       jnp.zeros((), jnp.int32))


def state_specs(state: HybridState):
    fe_spec = jax.tree.map(lambda _: P(), state.fe_params)
    w_spec = P(AXIS, None)
    opt_spec = jax.tree.map(lambda _: P(), state.opt_state)
    # opt moments mirror the (fe, w) tuple: redo specs for mu/nu leaves
    def moment_spec(tree):
        if tree is None:
            return None
        fe_m = jax.tree.map(lambda _: P(), tree[0])
        return (fe_m, w_spec)
    opt_spec = type(state.opt_state)(
        step=P(), mu=moment_spec(state.opt_state.mu),
        nu=moment_spec(getattr(state.opt_state, "nu", None)))
    dgc_spec = None
    if state.dgc is not None:
        dgc_spec = sp.DGCState(
            u=jax.tree.map(lambda _: P(AXIS), state.dgc.u),
            v=jax.tree.map(lambda _: P(AXIS), state.dgc.v))
    return HybridState(fe_spec, w_spec, opt_spec, dgc_spec, P())


def _flat_features_and_labels(model_cfg, fe_params, micro_inputs):
    """Local FE forward -> flat [t_loc, D] features + [t_loc] labels."""
    if model_cfg.family == "feats":
        return (micro_inputs["features"].astype(jnp.dtype(model_cfg.dtype)),
                micro_inputs["labels"], jnp.zeros((), jnp.float32))
    h, aux, _ = lm.backbone(fe_params, model_cfg, micro_inputs)
    d = h.shape[-1]
    f = h.reshape(-1, d)
    labels = micro_inputs["labels"].reshape(-1)
    return f, labels, aux


def make_train_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                    train_cfg: TrainConfig, mesh, *, n_micro: int = 1,
                    use_knn: bool = False, state_template: HybridState = None):
    """Returns jitted step(state, inputs, graph, lr) -> (state, loss, metrics).

    inputs are GLOBAL arrays batch-sharded over the ring; ``graph`` is the
    sharded CompressedGraph (ignored unless use_knn).
    """
    n_dev = mesh.shape[AXIS]
    opt = make_optimizer(train_cfg)
    dcfg = train_cfg.dgc
    m_local = 0
    if use_knn:
        v_loc = model_cfg.vocab_size // n_dev
        m_local = max(8, int(v_loc * head_cfg.active_frac))

    def body(fe_params, w_head, opt_state, dgc_u, dgc_v, offsets, neighbors,
             ranks, inputs_loc, lr):
        def loss_fn(params, micro_inputs):
            fe_p, w = params
            f, y, aux = _flat_features_and_labels(model_cfg, fe_p, micro_inputs)
            # hybrid parallel: gather every replica's features along the ring
            f_all = jax.lax.all_gather(f, AXIS, axis=0, tiled=True)
            y_all = jax.lax.all_gather(y, AXIS, axis=0, tiled=True)
            gb = f_all.shape[0]
            if use_knn:
                loss, metrics = knn_softmax_local(
                    f_all, y_all, w, offsets, neighbors, ranks,
                    model_axis=AXIS, batch_axes=(), global_batch=gb,
                    m_local=m_local, k_cap=head_cfg.knn_k, cosine_scale=16.0)
            else:
                loss, metrics = full_softmax_local(
                    f_all, y_all, w, model_axis=AXIS, batch_axes=(),
                    global_batch=gb, cosine_scale=16.0)
            return loss + aux, metrics

        (loss, metrics), grads = microbatched_value_and_grad(
            loss_fn, (fe_params, w_head), inputs_loc, n_micro)
        g_fe, g_w = grads

        info = {"wire_bytes": jnp.zeros((), jnp.float32),
                "dense_bytes": jnp.zeros((), jnp.float32)}
        new_u, new_v = dgc_u, dgc_v
        if dcfg.enabled:
            st = sp.DGCState(
                u=jax.tree.map(lambda a: a[0], dgc_u),
                v=jax.tree.map(lambda a: a[0], dgc_v))
            g_fe, st, dinfo = sp.dgc_exchange(
                g_fe, st, dcfg, batch_axes=(AXIS,), n_workers=n_dev)
            info.update(dinfo)
            new_u = jax.tree.map(lambda a: a[None], st.u)
            new_v = jax.tree.map(lambda a: a[None], st.v)
        else:
            g_fe = sp.dense_exchange(g_fe, batch_axes=(AXIS,), n_workers=n_dev)
            info["dense_bytes"] = jnp.asarray(
                sum(leaf.size * 4 for leaf in jax.tree.leaves(g_fe)),
                jnp.float32)
        # fc gradient: LOCAL — never crosses devices (paper §3.1 step 6)

        updates, opt_state = opt.update((g_fe, g_w), opt_state,
                                        (fe_params, w_head), lr)
        fe_params, w_head = apply_updates((fe_params, w_head), updates)
        metrics = dict(metrics)
        metrics["comm_wire_bytes"] = info.get("wire_bytes", jnp.zeros((), jnp.float32))
        metrics["comm_dense_bytes"] = info["dense_bytes"]
        return fe_params, w_head, opt_state, new_u, new_v, loss, metrics

    tmpl = state_template
    specs = state_specs(tmpl)
    dgc_u_spec = specs.dgc.u if specs.dgc is not None else None
    dgc_v_spec = specs.dgc.v if specs.dgc is not None else None
    if tmpl.dgc is None:
        # pass small dummies with replicated spec
        dgc_u_spec = jax.tree.map(lambda _: P(), tmpl.fe_params)
        dgc_v_spec = dgc_u_spec
    metrics_spec = dict(KNN_METRICS if use_knn else FULL_METRICS)
    metrics_spec["comm_wire_bytes"] = P()
    metrics_spec["comm_dense_bytes"] = P()
    input_spec = jax.tree.map(lambda _: P(AXIS), _input_structure(model_cfg))
    graph_spec = (P(AXIS, None),) * 3

    shmapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(specs.fe_params, specs.w_head, specs.opt_state,
                  dgc_u_spec, dgc_v_spec, graph_spec[0], graph_spec[1],
                  graph_spec[2], input_spec, P()),
        out_specs=(specs.fe_params, specs.w_head, specs.opt_state,
                   dgc_u_spec, dgc_v_spec, P(), metrics_spec),
        check_vma=False,
    )

    @jax.jit
    def step(state: HybridState, inputs, graph, lr):
        dgc_u = state.dgc.u if state.dgc is not None else state.fe_params
        dgc_v = state.dgc.v if state.dgc is not None else state.fe_params
        offsets, neighbors, ranks = graph
        fe, w, opt_state, nu_, nv_, loss, metrics = shmapped(
            state.fe_params, state.w_head, state.opt_state, dgc_u, dgc_v,
            offsets, neighbors, ranks, inputs, lr)
        dgc = sp.DGCState(u=nu_, v=nv_) if state.dgc is not None else None
        return (HybridState(fe, w, opt_state, dgc, state.step + 1),
                loss, metrics)

    return step


def _input_structure(model_cfg: ModelConfig):
    if model_cfg.family == "feats":
        return {"features": 0, "labels": 0}
    if model_cfg.family == "cnn":
        return {"images": 0, "labels": 0}
    if model_cfg.family == "encdec":
        return {"frames": 0, "tokens": 0, "labels": 0}
    return {"tokens": 0, "labels": 0}


def dummy_graph(n_dev: int):
    """Placeholder CompressedGraph when KNN is off (structure must be static)."""
    return (jnp.zeros((n_dev, 2), jnp.int32),
            jnp.zeros((n_dev, 2), jnp.int32),
            jnp.zeros((n_dev, 2), jnp.int32))


# ---------------------------------------------------------------------------
# graph rebuild (paper: suspend training, rebuild on the training devices)
# ---------------------------------------------------------------------------


def rebuild_graph(mesh, w_head, *, k: int, kprime: int):
    """Ring-build the exact KNN graph of the CURRENT class weights and
    compress it per shard. Host round-trip for CSR packing (offline step)."""
    import numpy as np
    n_dev = mesh.shape[AXIS]
    graph = kg.build_graph_distributed(mesh, w_head, k=k, kprime=kprime,
                                       model_axis=AXIS)
    cg = kg.compress_graph(np.asarray(jax.device_get(graph)), n_dev)
    from jax.sharding import NamedSharding
    sh = NamedSharding(mesh, P(AXIS, None))
    return (jax.device_put(cg.offsets, sh), jax.device_put(cg.neighbors, sh),
            jax.device_put(cg.ranks, sh))


# ---------------------------------------------------------------------------
# evaluation / serving
# ---------------------------------------------------------------------------


def make_eval_step(model_cfg: ModelConfig, mesh, state_template: HybridState):
    """Distributed top-1 accuracy with the full softmax (deploy-style:
    nearest class weight — paper §4.5 retrieval equivalence)."""
    specs = state_specs(state_template)

    def body(fe_params, w_head, inputs_loc):
        f, y, _ = _flat_features_and_labels(model_cfg, fe_params, inputs_loc)
        f_all = jax.lax.all_gather(f, AXIS, axis=0, tiled=True)
        y_all = jax.lax.all_gather(y, AXIS, axis=0, tiled=True)
        fn = f_all / (jnp.linalg.norm(f_all.astype(jnp.float32), axis=-1,
                                      keepdims=True) + 1e-12).astype(f_all.dtype)
        wn = w_head / (jnp.linalg.norm(w_head, axis=-1, keepdims=True) + 1e-12)
        pred, _ = serve_logits_local(fn, wn, model_axis=AXIS)
        acc = jnp.mean((pred == y_all).astype(jnp.float32))
        return acc

    input_spec = jax.tree.map(lambda _: P(AXIS), _input_structure(model_cfg))
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(specs.fe_params, specs.w_head, input_spec),
                       out_specs=P(), check_vma=False)
    return jax.jit(lambda state, inputs: fn(state.fe_params, state.w_head,
                                            inputs))
