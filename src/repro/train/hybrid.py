"""The paper's hybrid-parallel trainer (faithful reproduction, §3.1-§3.4).

Layout = the paper's exactly, generalized to a 1-D device ring ("hybrid"
axis over all chips): every device is BOTH a data-parallel FE replica (FE
params replicated; batch sharded over the ring) AND a model-parallel fc
shard (head params sharded over the ring). Per (micro-)batch:

  FE local forward -> all-gather features along the ring -> each device
  scores the whole (micro-)batch against its head shard -> distributed
  softmax (pmax/psum) -> backward; head grads STAY LOCAL; FE grads cross the
  ring once per step — dense psum or DGC top-k sparsified (§3.3.2).

Micro-batching (§3.3.1) runs as a lax.scan whose per-iteration all-gather the
XLA latency-hiding scheduler overlaps with the next iteration's FE compute;
it is also FCCS's gradient-accumulation mechanism (n× batch growth).

The softmax head is a pluggable ``repro.api.SoftmaxHead`` strategy (full /
knn / selective / mach / ...): the head owns its trainable params, its aux
state (graphs, hash tables), the PartitionSpecs that place both on the ring,
and its shard_map loss body. The step builders below are head-agnostic —
no ``use_knn`` booleans, no head-specific branches — and that includes the
compute backend: ``HeadConfig.backend="pallas"`` swaps the head bodies onto
the fused kernels (docs/kernels.md) with zero trainer changes.

Everything is a single shard_map over the full mesh — all collectives
explicit, nothing left to GSPMD — so the HLO *is* the paper's Fig. 2/4.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.api.heads import HeadState, SoftmaxHead, make_head
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.core import sparsify as sp
from repro.core.pipeline import microbatched_value_and_grad
from repro.models import lm
from repro.optim import apply_updates, make_optimizer

AXIS = "hybrid"


def make_hybrid_mesh(n_dev: Optional[int] = None):
    n = n_dev or len(jax.devices())
    return jax.make_mesh((n,), (AXIS,),
                         axis_types=(jax.sharding.AxisType.Auto,))


class HybridState(NamedTuple):
    fe_params: dict        # replicated
    head_params: Any       # head-owned trainable pytree, sharded by the head
    head_aux: Any          # head-owned non-trainable pytree (graph/tables)
    opt_state: object
    dgc: Optional[sp.DGCState]   # leaves carry leading [n_dev] axis
    step: jax.Array

    @property
    def w_head(self):
        """The [V, D] class-weight matrix, for heads whose params are one
        array (full/knn/selective/sampled). Deploy/eval code reads this."""
        return self.head_params


def init_state(key, model_cfg: ModelConfig, head_cfg: HeadConfig,
               train_cfg: TrainConfig, n_dev: int, *,
               head: Optional[SoftmaxHead] = None) -> HybridState:
    head = head or make_head(model_cfg, head_cfg)
    k1, k2 = jax.random.split(key)
    fe_params = lm.init_model(k1, model_cfg)
    fe_params.pop("head", None)   # the fc lives separately, sharded
    hs = head.init(k2, n_dev)
    opt = make_optimizer(train_cfg)
    opt_state = opt.init((fe_params, hs.params))
    dgc = None
    if train_cfg.dgc.enabled:
        z = sp.init_dgc_state(fe_params)
        dgc = sp.DGCState(
            u=jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_dev,) + a.shape), z.u),
            v=jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n_dev,) + a.shape), z.v),
        )
    return HybridState(fe_params, hs.params, hs.aux, opt_state, dgc,
                       jnp.zeros((), jnp.int32))


def refresh_head_state(head: SoftmaxHead, mesh,
                       state: HybridState) -> HybridState:
    """Run the head's periodic work (graph/table rebuild) on the current
    params; no-op for heads without any."""
    hs = head.refresh(mesh, HeadState(state.head_params, state.head_aux),
                      model_axis=AXIS)
    return state._replace(head_params=hs.params, head_aux=hs.aux)


def state_specs(state: HybridState, head: SoftmaxHead):
    fe_spec = jax.tree.map(lambda _: P(), state.fe_params)
    hp_spec = head.params_spec(AXIS)
    opt_spec = jax.tree.map(lambda _: P(), state.opt_state)
    # opt moments mirror the (fe, head_params) tuple: redo specs for mu/nu
    def moment_spec(tree):
        if tree is None:
            return None
        fe_m = jax.tree.map(lambda _: P(), tree[0])
        return (fe_m, hp_spec)
    opt_spec = type(state.opt_state)(
        step=P(), mu=moment_spec(state.opt_state.mu),
        nu=moment_spec(getattr(state.opt_state, "nu", None)))
    dgc_spec = None
    if state.dgc is not None:
        dgc_spec = sp.DGCState(
            u=jax.tree.map(lambda _: P(AXIS), state.dgc.u),
            v=jax.tree.map(lambda _: P(AXIS), state.dgc.v))
    return HybridState(fe_spec, hp_spec, head.aux_spec(AXIS), opt_spec,
                       dgc_spec, P())


def _flat_features_and_labels(model_cfg, fe_params, micro_inputs):
    """Local FE forward -> flat [t_loc, D] features + [t_loc] labels."""
    if model_cfg.family == "feats":
        return (micro_inputs["features"].astype(jnp.dtype(model_cfg.dtype)),
                micro_inputs["labels"], jnp.zeros((), jnp.float32))
    h, aux, _ = lm.backbone(fe_params, model_cfg, micro_inputs)
    d = h.shape[-1]
    f = h.reshape(-1, d)
    labels = micro_inputs["labels"].reshape(-1)
    return f, labels, aux


def _flat_features(model_cfg, fe_params, micro_inputs):
    """Label-free FE forward (serving): flat [t_loc, D] features."""
    if model_cfg.family == "feats":
        return micro_inputs["features"].astype(jnp.dtype(model_cfg.dtype))
    h, _, _ = lm.backbone(fe_params, model_cfg, micro_inputs)
    return h.reshape(-1, h.shape[-1])


def make_train_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                    train_cfg: TrainConfig, mesh, *, n_micro: int = 1,
                    head: Optional[SoftmaxHead] = None,
                    state_template: HybridState = None):
    """Returns jitted step(state, inputs, lr) -> (state, loss, metrics).

    inputs are GLOBAL arrays batch-sharded over the ring; the head's aux
    state (graph/tables) travels inside ``state`` with head-provided specs.
    """
    head = head or make_head(model_cfg, head_cfg)
    n_dev = mesh.shape[AXIS]
    opt = make_optimizer(train_cfg)
    dcfg = train_cfg.dgc

    def body(fe_params, head_params, head_aux, opt_state, dgc_u, dgc_v,
             inputs_loc, lr, step_no):
        def loss_fn(params, micro_inputs):
            fe_p, hp = params
            f, y, aux = _flat_features_and_labels(model_cfg, fe_p, micro_inputs)
            # hybrid parallel: gather every replica's features along the ring
            f_all = jax.lax.all_gather(f, AXIS, axis=0, tiled=True)
            y_all = jax.lax.all_gather(y, AXIS, axis=0, tiled=True)
            loss, metrics = head.loss_local(
                f_all, y_all, hp, head_aux, model_axis=AXIS, batch_axes=(),
                global_batch=f_all.shape[0], step=step_no)
            return loss + aux, metrics

        (loss, metrics), grads = microbatched_value_and_grad(
            loss_fn, (fe_params, head_params), inputs_loc, n_micro)
        g_fe, g_hp = grads

        info = {"wire_bytes": jnp.zeros((), jnp.float32),
                "dense_bytes": jnp.zeros((), jnp.float32)}
        new_u, new_v = dgc_u, dgc_v
        if dcfg.enabled:
            st = sp.DGCState(
                u=jax.tree.map(lambda a: a[0], dgc_u),
                v=jax.tree.map(lambda a: a[0], dgc_v))
            g_fe, st, dinfo = sp.dgc_exchange(
                g_fe, st, dcfg, batch_axes=(AXIS,), n_workers=n_dev)
            info.update(dinfo)
            new_u = jax.tree.map(lambda a: a[None], st.u)
            new_v = jax.tree.map(lambda a: a[None], st.v)
        else:
            g_fe = sp.dense_exchange(g_fe, batch_axes=(AXIS,), n_workers=n_dev)
            info["dense_bytes"] = jnp.asarray(
                sum(leaf.size * 4 for leaf in jax.tree.leaves(g_fe)),
                jnp.float32)
        # head gradient: LOCAL — never crosses devices (paper §3.1 step 6)

        updates, opt_state = opt.update((g_fe, g_hp), opt_state,
                                        (fe_params, head_params), lr)
        fe_params, head_params = apply_updates((fe_params, head_params),
                                               updates)
        metrics = dict(metrics)
        metrics["comm_wire_bytes"] = info.get("wire_bytes", jnp.zeros((), jnp.float32))
        metrics["comm_dense_bytes"] = info["dense_bytes"]
        return fe_params, head_params, opt_state, new_u, new_v, loss, metrics

    tmpl = state_template
    specs = state_specs(tmpl, head)
    dgc_u_spec = specs.dgc.u if specs.dgc is not None else None
    dgc_v_spec = specs.dgc.v if specs.dgc is not None else None
    if tmpl.dgc is None:
        # pass small dummies with replicated spec
        dgc_u_spec = jax.tree.map(lambda _: P(), tmpl.fe_params)
        dgc_v_spec = dgc_u_spec
    metrics_spec = dict(head.metrics_spec())
    metrics_spec["comm_wire_bytes"] = P()
    metrics_spec["comm_dense_bytes"] = P()
    input_spec = jax.tree.map(lambda _: P(AXIS), _input_structure(model_cfg))

    shmapped = jax.shard_map(
        body, mesh=mesh,
        in_specs=(specs.fe_params, specs.head_params, specs.head_aux,
                  specs.opt_state, dgc_u_spec, dgc_v_spec, input_spec, P(),
                  P()),
        out_specs=(specs.fe_params, specs.head_params, specs.opt_state,
                   dgc_u_spec, dgc_v_spec, P(), metrics_spec),
        check_vma=False,
    )

    @jax.jit
    def step(state: HybridState, inputs, lr):
        dgc_u = state.dgc.u if state.dgc is not None else state.fe_params
        dgc_v = state.dgc.v if state.dgc is not None else state.fe_params
        fe, hp, opt_state, nu_, nv_, loss, metrics = shmapped(
            state.fe_params, state.head_params, state.head_aux,
            state.opt_state, dgc_u, dgc_v, inputs, lr, state.step)
        dgc = sp.DGCState(u=nu_, v=nv_) if state.dgc is not None else None
        return (HybridState(fe, hp, state.head_aux, opt_state, dgc,
                            state.step + 1),
                loss, metrics)

    return step


def _input_structure(model_cfg: ModelConfig):
    if model_cfg.family == "feats":
        return {"features": 0, "labels": 0}
    if model_cfg.family == "cnn":
        return {"images": 0, "labels": 0}
    if model_cfg.family == "encdec":
        return {"frames": 0, "tokens": 0, "labels": 0}
    return {"tokens": 0, "labels": 0}


# ---------------------------------------------------------------------------
# evaluation / serving
# ---------------------------------------------------------------------------


def _make_deploy_fn(model_cfg, mesh, state_template, head, body, structure):
    """Shared shard_map wiring for the deploy-style eval/serve steps."""
    specs = state_specs(state_template, head)
    input_spec = jax.tree.map(lambda _: P(AXIS), structure)
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(specs.fe_params, specs.head_params,
                                 specs.head_aux, input_spec),
                       out_specs=P(), check_vma=False)
    keys = tuple(structure)
    return jax.jit(lambda state, inputs: fn(
        state.fe_params, state.head_params, state.head_aux,
        {k: inputs[k] for k in keys}))


def make_eval_step(model_cfg: ModelConfig, head_cfg: HeadConfig, mesh,
                   state_template: HybridState, *,
                   head: Optional[SoftmaxHead] = None):
    """Distributed top-1 accuracy with the head's own deploy-style
    prediction (nearest class weight for W-heads — paper §4.5 retrieval
    equivalence; hashed-bucket vote for MACH)."""
    head = head or make_head(model_cfg, head_cfg)

    def body(fe_params, head_params, head_aux, inputs_loc):
        f, y, _ = _flat_features_and_labels(model_cfg, fe_params, inputs_loc)
        f_all = jax.lax.all_gather(f, AXIS, axis=0, tiled=True)
        y_all = jax.lax.all_gather(y, AXIS, axis=0, tiled=True)
        pred, _ = head.eval_logits_local(f_all, head_params, head_aux,
                                         model_axis=AXIS)
        return jnp.mean((pred == y_all).astype(jnp.float32))

    return _make_deploy_fn(model_cfg, mesh, state_template, head, body,
                           _input_structure(model_cfg))


def make_serve_step(model_cfg: ModelConfig, head_cfg: HeadConfig, mesh,
                    state_template: HybridState, *,
                    head: Optional[SoftmaxHead] = None):
    """Deploy-style retrieval (§4.5): (state, inputs) -> [b] predicted
    global class ids. Inputs need no "labels" key (any present is ignored);
    pure-inference batches serve directly."""
    head = head or make_head(model_cfg, head_cfg)

    def body(fe_params, head_params, head_aux, inputs_loc):
        f = _flat_features(model_cfg, fe_params, inputs_loc)
        f_all = jax.lax.all_gather(f, AXIS, axis=0, tiled=True)
        pred, _ = head.eval_logits_local(f_all, head_params, head_aux,
                                         model_axis=AXIS)
        return pred.astype(jnp.int32)

    structure = {k: v for k, v in _input_structure(model_cfg).items()
                 if k != "labels"}
    return _make_deploy_fn(model_cfg, mesh, state_template, head, body,
                           structure)


def _serve_query_key(model_cfg: ModelConfig) -> str:
    """The input key a serving-tier query fills (no labels at serve time)."""
    keys = [k for k in _input_structure(model_cfg) if k != "labels"]
    if len(keys) != 1:
        raise NotImplementedError(
            f"serving-tier queries need a single-input trunk; "
            f"{model_cfg.family!r} has inputs {keys}")
    return keys[0]


def _make_batched_deploy_fn(model_cfg, mesh, state_template, head, body,
                            donate: bool):
    """shard_map wiring for the serving tier's batched steps: queries are
    REPLICATED (every shard scores the full padded micro-batch — no ring
    all-gather on the serve path, and no batch-divisibility constraint),
    ``n_queries`` is a traced scalar (one compile per padding bucket, not
    per occupancy), and the padded query buffer is donated when the caller
    is done with it (``donate=True``, the serving engine's default)."""
    specs = state_specs(state_template, head)
    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(specs.fe_params, specs.head_params,
                                 specs.head_aux, P(), P()),
                       out_specs=P(), check_vma=False)

    def step(state, queries, n_queries):
        return fn(state.fe_params, state.head_params, state.head_aux,
                  queries, n_queries)

    return jax.jit(step, donate_argnums=(1,)) if donate else jax.jit(step)


def make_batched_serve_step(model_cfg: ModelConfig, head_cfg: HeadConfig,
                            mesh, state_template: HybridState, *,
                            head: Optional[SoftmaxHead] = None,
                            donate: bool = True):
    """Serving-tier greedy retrieval over a padded micro-batch.

    (state, queries [b_pad, ...], n_queries []) -> pred [b_pad] int32 with
    padding rows forced to -1. Works for EVERY registry head (the body is
    the head's own ``eval_logits_local`` — hashed-bucket decode included),
    and rows are scored independently, so results for real rows are
    bitwise-identical across padding buckets >= 2 (tests/test_serving.py).
    """
    from repro.core.sharded_softmax import mask_padded_rows

    head = head or make_head(model_cfg, head_cfg)
    key = _serve_query_key(model_cfg)

    def body(fe_params, head_params, head_aux, queries, n_queries):
        f = _flat_features(model_cfg, fe_params, {key: queries})
        pred, _ = head.eval_logits_local(f, head_params, head_aux,
                                         model_axis=AXIS)
        return mask_padded_rows(pred.astype(jnp.int32), n_queries, -1)

    return _make_batched_deploy_fn(model_cfg, mesh, state_template, head,
                                   body, donate)


def make_batched_topk_serve_step(model_cfg: ModelConfig,
                                 head_cfg: HeadConfig, mesh,
                                 state_template: HybridState, top_k: int, *,
                                 head: Optional[SoftmaxHead] = None,
                                 donate: bool = True):
    """Serving-tier top-k retrieval over a padded micro-batch.

    (state, queries [b_pad, ...], n_queries []) -> (vals [b_pad, k] desc,
    gids [b_pad, k]) with padding rows forced to (-inf, -1). W-heads only
    (same contract as ``make_topk_serve_step``); the multi-query body is
    ``core.sharded_softmax.serve_topk_batched_local``."""
    from repro.core.sharded_softmax import (_normalize,
                                            serve_topk_batched_local)

    head = head or make_head(model_cfg, head_cfg)
    if not head.params_are_class_weights:
        raise NotImplementedError(
            f"top-k serving retrieves against the [V, D] class matrix, "
            f"which the {head.name!r} head does not train; use a W-head "
            f"(full/knn/selective/sampled)")
    key = _serve_query_key(model_cfg)

    def body(fe_params, head_params, head_aux, queries, n_queries):
        f = _flat_features(model_cfg, fe_params, {key: queries})
        f = f.astype(jnp.float32)
        w = head_params.astype(jnp.float32)
        if head_cfg.cosine_scale > 0:
            f, w = _normalize(f), _normalize(w)
        return serve_topk_batched_local(
            f, w, top_k, n_queries, model_axis=AXIS, n_valid=head.n_valid,
            backend=head.backend)

    return _make_batched_deploy_fn(model_cfg, mesh, state_template, head,
                                   body, donate)


def make_batched_ivf_topk_serve_step(model_cfg: ModelConfig,
                                     head_cfg: HeadConfig, mesh,
                                     state_template: HybridState,
                                     top_k: int, *, nprobe: int,
                                     head: Optional[SoftmaxHead] = None,
                                     donate: bool = True):
    """Sublinear serving-tier top-k through an ``IVFIndex``.

    (state, centroids [P, C, D], members [P, C, cap], queries [b_pad, ...],
    n_queries []) -> (vals [b_pad, k] desc, gids [b_pad, k]), padding rows
    forced to (-inf, -1). Same contract and shard_map wiring as
    ``make_batched_topk_serve_step``, but each shard probes its own
    ``nprobe`` centroids and reranks only their member rows
    (``serve_topk_ivf_batched_local``; pallas backend = the fused
    ``ops.ivf_rerank`` kernel) instead of scanning the whole [V/n, D]
    shard. W-heads only — the index quantizes the trained class matrix."""
    from repro.core.sharded_softmax import (_normalize,
                                            serve_topk_ivf_batched_local)

    head = head or make_head(model_cfg, head_cfg)
    if not head.params_are_class_weights:
        raise NotImplementedError(
            f"top-k serving retrieves against the [V, D] class matrix, "
            f"which the {head.name!r} head does not train; use a W-head "
            f"(full/knn/selective/sampled)")
    key = _serve_query_key(model_cfg)
    specs = state_specs(state_template, head)

    def body(fe_params, head_params, head_aux, cent, members, queries,
             n_queries):
        f = _flat_features(model_cfg, fe_params, {key: queries})
        f = f.astype(jnp.float32)
        w = head_params.astype(jnp.float32)
        if head_cfg.cosine_scale > 0:
            f, w = _normalize(f), _normalize(w)
        return serve_topk_ivf_batched_local(
            f, w, cent[0], members[0], top_k, nprobe, n_queries,
            model_axis=AXIS, backend=head.backend,
            block_a=head.block_a)

    fn = jax.shard_map(body, mesh=mesh,
                       in_specs=(specs.fe_params, specs.head_params,
                                 specs.head_aux, P(AXIS, None, None),
                                 P(AXIS, None, None), P(), P()),
                       out_specs=P(), check_vma=False)

    def step(state, centroids, members, queries, n_queries):
        return fn(state.fe_params, state.head_params, state.head_aux,
                  centroids, members, queries, n_queries)

    return jax.jit(step, donate_argnums=(3,)) if donate else jax.jit(step)


def make_topk_serve_step(model_cfg: ModelConfig, head_cfg: HeadConfig, mesh,
                         state_template: HybridState, top_k: int, *,
                         head: Optional[SoftmaxHead] = None):
    """Top-k retrieval with scores (ROADMAP "serving beyond greedy argmax"):
    (state, inputs) -> (scores [b, k] desc, global class ids [b, k]).

    W-heads only (the [V, D] retrieval index IS the trained head); each
    shard's local top-k is selected by ``lax.top_k`` (ref backend) or the
    row-wise divide-and-conquer selector ``kernels.ops.topk_rows`` (pallas
    stage-1 kernel), then merged with one all-gather along the ring."""
    from repro.core.sharded_softmax import _normalize, serve_topk_local

    head = head or make_head(model_cfg, head_cfg)
    if not head.params_are_class_weights:
        raise NotImplementedError(
            f"top-k serving retrieves against the [V, D] class matrix, "
            f"which the {head.name!r} head does not train; use a W-head "
            f"(full/knn/selective/sampled)")

    def body(fe_params, head_params, head_aux, inputs_loc):
        f = _flat_features(model_cfg, fe_params, inputs_loc)
        f_all = jax.lax.all_gather(f, AXIS, axis=0, tiled=True)
        f_all = f_all.astype(jnp.float32)
        w = head_params.astype(jnp.float32)
        if head_cfg.cosine_scale > 0:
            f_all, w = _normalize(f_all), _normalize(w)
        return serve_topk_local(
            f_all, w, top_k, model_axis=AXIS, n_valid=head.n_valid,
            backend=head.backend)

    structure = {k: v for k, v in _input_structure(model_cfg).items()
                 if k != "labels"}
    return _make_deploy_fn(model_cfg, mesh, state_template, head, body,
                           structure)
