from repro import compat  # noqa: F401  (jax forward-compat aliases)
