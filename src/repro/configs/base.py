"""Config system: dataclasses for model / head / parallelism / training.

Every assigned architecture gets a module in this package defining
``config() -> ModelConfig`` with the exact published hyper-parameters (source
cited in ``source``) and ``reduced() -> ModelConfig`` — the smoke-test variant
(<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    router_aux_coef: float = 0.01  # load-balance auxiliary loss
    n_shared_experts: int = 0      # dense experts always active (deepseek/kimi style)
    capacity_factor: float = 1.25  # token-dropping capacity (GShard-style)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64             # mamba2 P (channels per SSM head)
    chunk: int = 64                # SSD chunk length for training scan
    n_groups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None         # default d_model // n_heads
    activation: str = "swiglu"             # swiglu | geglu | gelu
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    qk_norm: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # None = full causal attention
    tie_embeddings: bool = True
    # encoder-decoder (whisper) --------------------------------------------
    n_enc_layers: int = 0
    enc_seq: int = 0                       # fixed encoder length (1500 frames)
    # feature dims of the stubbed frontend equal d_model
    # subconfigs -----------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"                # activation/compute dtype
    param_dtype: str = "float32"           # master params
    # vocab padding (Megatron-style): when the published vocab does not
    # divide the model axis, pad W/embedding rows and mask padded logits.
    real_vocab_size: Optional[int] = None  # set by pad_vocab()
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def with_sliding_window(self, window: int = 4096) -> "ModelConfig":
        return replace(self, sliding_window=window)


@dataclass(frozen=True)
class HeadConfig:
    """The paper's contribution: hybrid-parallel extreme-classification head.

    ``softmax_impl`` selects a registered ``repro.api.SoftmaxHead`` strategy
    (validated against the registry at construction time); ``rebuild_every``
    is the head's ``refresh`` cadence (graph rebuild for knn, LSH-table
    rebuild for selective; a no-op for heads without periodic work).

    ``backend`` selects the compute backend for the head's hot path
    (``loss_local`` / ``eval_logits_local``): ``"ref"`` is the plain-XLA
    reference implementation; ``"pallas"`` streams the softmax stage through
    the fused Pallas kernels (``repro.kernels``) so the dense ``[B, V_local]``
    logit tensor never reaches HBM — the paper's §3.2 hotspot. Both backends
    compute the same loss and gradients to fp32 tolerance (see
    tests/test_backend_parity.py and docs/kernels.md)."""
    softmax_impl: str = "full"     # full|knn|selective|mach|sampled|csoft
    backend: str = "ref"           # ref (XLA) | pallas (fused kernels)
    pallas_block_v: int = 512      # fused-CE vocab tile rows (VMEM blocking)
    pallas_block_a: int = 128      # sparse-CE active-set tile (VMEM blocking)
    cosine_scale: float = 16.0     # normalized-logit scale (§3.2.1); 0 = raw
    # KNN softmax (paper §3.2)
    knn_k: int = 16                # neighbors per class in the graph
    knn_kprime: int = 32           # recall k' > k in bf16 pass, re-rank fp32
    active_frac: float = 0.10      # M = active_frac * N (paper: "10% active classes")
    rebuild_every: int = 0         # steps between refreshes (0 = never/manual)
    knn_pad_random: bool = True    # paper line 7 random filler classes
    # selective softmax baseline (HF-A)
    selective_n_hash: int = 4
    selective_n_bits: int = 8
    selective_cap: int = 32        # per-bucket candidate gather cap
    # MACH baseline
    mach_b: int = 64               # buckets
    mach_r: int = 4                # repetitions
    # sampled softmax baseline [Jean et al.'15]
    sampled_n: int = 2048          # negatives per step (across class shards)
    sampled_dist: str = "uniform"  # uniform (stratified, w/o replacement)
    #                              # | log_uniform (Zipf, with replacement)
    sampled_seed: int = 17         # base PRNG seed for the negative sampler
    # CSoft count-min-sketch head [Medini et al.'19 lineage]
    csoft_b: int = 64              # buckets per hash row
    csoft_r: int = 4               # independent hash rows
    csoft_agg: str = "min"         # decode aggregation: min (count-min) | mean
    label_smoothing: float = 0.0
    z_loss: float = 0.0            # beyond-paper stabilizer, off by default

    def __post_init__(self):
        if self.backend not in ("ref", "pallas"):
            raise ValueError(
                f"backend must be 'ref' or 'pallas', got {self.backend!r}")
        if self.sampled_dist not in ("uniform", "log_uniform"):
            raise ValueError(
                f"sampled_dist must be 'uniform' or 'log_uniform', got "
                f"{self.sampled_dist!r}")
        if self.csoft_agg not in ("min", "mean"):
            raise ValueError(
                f"csoft_agg must be 'min' or 'mean', got {self.csoft_agg!r}")
        try:  # lazy: repro.api.heads imports this module at its own top
            from repro.api.heads import HEAD_REGISTRY
        except ImportError:
            return
        if HEAD_REGISTRY and self.softmax_impl not in HEAD_REGISTRY:
            raise ValueError(
                f"unknown softmax_impl {self.softmax_impl!r}; registered "
                f"heads: {sorted(HEAD_REGISTRY)}")


@dataclass(frozen=True)
class ParallelConfig:
    mesh_shape: tuple = (16, 16)
    axis_names: tuple = ("data", "model")
    # logical axis -> mesh axis rules (MaxText-style)
    rules: tuple = (
        ("batch", ("pod", "data")),
        ("vocab", "model"),
        ("heads", "model"),
        ("kv_heads", "model"),
        ("mlp", "model"),
        ("experts", "model"),
        ("expert_mlp", None),
        ("head_dim", None),
        ("inner", "model"),        # ssm d_inner
        ("embed", None),
        ("seq", None),
        ("layers", None),
    )
    remat: str = "none"            # none | full — activation checkpointing policy
    # FSDP/ZeRO: separate rules for PARAMETERS (and optimizer moments).
    # None -> params follow `rules`. Production configs prepend
    # ("embed", "data") so weight matrices shard their embed dim over the
    # data axis (per-layer all-gather in fwd, reduce-scatter in bwd).
    param_rules: Optional[tuple] = None

    @property
    def batch_axes(self) -> tuple:
        return tuple(a for a in ("pod", "data") if a in self.axis_names)

    @property
    def model_axis(self) -> str:
        return "model"

    def _lookup(self, rules, logical: str):
        for k, v in rules:
            if k == logical:
                if isinstance(v, tuple):
                    return tuple(a for a in v if a in self.axis_names) or None
                if v is not None and v not in self.axis_names:
                    return None
                return v
        return None

    def mesh_axis_for(self, logical: str):
        return self._lookup(self.rules, logical)

    def mesh_axis_for_param(self, logical: str):
        return self._lookup(self.param_rules or self.rules, logical)


@dataclass(frozen=True)
class FCCSConfig:
    """Fast continuous convergence strategy (paper §3.4)."""
    eta0: float = 0.4
    t_warm: int = 100              # warm-up iterations
    b0: int = 4096                 # initial (accumulated) global batch
    b_min: int = 4096              # B^1_min
    b_max: int = 262144            # B^1_max = 64 * B^1_min (paper)
    t_ini: int = 100               # start of the cosine growth stage
    t_final: int = 2000            # end of the cosine growth stage


@dataclass(frozen=True)
class DGCConfig:
    """Layer-wise top-k gradient sparsification (paper §3.3.2 / DGC)."""
    enabled: bool = False
    sparsity: float = 0.999        # keep-fraction = 1 - sparsity
    momentum: float = 0.9
    factor_masking: bool = True
    chunk: int = 2048              # divide-and-conquer chunk size
    group_bytes: int = 1 << 22     # tensor-grouping target bucket size
    backend: str = "ref"           # threshold selection: ref (jnp sort path)
    #                              # | pallas (kernels.ops.topk_threshold)

    def __post_init__(self):
        if self.backend not in ("ref", "pallas"):
            raise ValueError(
                f"DGC backend must be 'ref' or 'pallas', got "
                f"{self.backend!r}")


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "lars"        # sgd | lars | adam
    weight_decay: float = 1e-4
    momentum: float = 0.9
    micro_batch: int = 0           # 0 = no microbatching (one shot)
    grad_accum: int = 1
    loss_scale: float = 0.0        # 0 = off; >0 static; <0 dynamic
    fccs: FCCSConfig = field(default_factory=FCCSConfig)
    dgc: DGCConfig = field(default_factory=DGCConfig)
    seed: int = 0
    steps: int = 200


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,   32, "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",  524_288,    1, "decode"),
}

ARCH_IDS = [
    "mamba2_370m", "kimi_k2_1t_a32b", "qwen3_moe_30b_a3b", "phi3_mini_3_8b",
    "qwen3_1_7b", "gemma_2b", "whisper_tiny", "chameleon_34b", "smollm_135m",
    "hymba_1_5b",
]

# long_500k applicability (DESIGN.md §3): ssm/hybrid natively; dense/moe/vlm via
# the sliding-window variant; whisper (enc-dec, 448-ctx decoder) skipped.
LONG_CONTEXT_SKIP = {"whisper_tiny"}


def normalize_arch_id(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_model_config(arch: str, reduced: bool = False) -> ModelConfig:
    arch = normalize_arch_id(arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    cfg = mod.reduced() if reduced else mod.config()
    return cfg


def pad_vocab(cfg: ModelConfig, multiple: int = 128) -> ModelConfig:
    """Pad vocab to a multiple (model-axis divisibility + lane alignment).
    Labels stay < real_vocab_size; padded logits are masked in the loss."""
    if cfg.vocab_size % multiple == 0:
        return cfg
    padded = -(-cfg.vocab_size // multiple) * multiple
    return replace(cfg, vocab_size=padded,
                   real_vocab_size=cfg.real_vocab_size or cfg.vocab_size)


def effective_vocab(cfg: ModelConfig) -> int:
    return cfg.real_vocab_size or cfg.vocab_size


def for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Adapt a model config to an input shape (sliding window for long ctx)."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        return cfg.with_sliding_window(4096)
    return cfg


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
