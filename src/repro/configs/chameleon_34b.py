"""chameleon-34b — early-fusion VLM, VQ image tokens [arXiv:2405.09818].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text+image tokens in
one early-fused vocabulary). qk-norm per the paper. The VQ-VAE image tokenizer
is STUBBED: input_specs() provides interleaved token ids + modality mask.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=65536,
        activation="swiglu",
        qk_norm=True,              # chameleon's training-stability fix
        rope_theta=10000.0,
        tie_embeddings=False,
        source="arXiv:2405.09818 (Chameleon 34B)",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="chameleon-34b-reduced",
        family="vlm",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        qk_norm=True,
        source="reduced smoke variant",
    )
