"""whisper-tiny — enc-dec, conv frontend stubbed [arXiv:2212.04356].

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865. The mel-spectrogram +
conv feature extractor is STUBBED per the assignment carve-out: input_specs()
provides precomputed frame embeddings [B, 1500, 384].
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=4,
        n_enc_layers=4,
        enc_seq=1500,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        activation="gelu",
        norm="layernorm",
        rope_theta=0.0,            # whisper uses learned/sinusoidal positions
        tie_embeddings=True,
        source="arXiv:2212.04356 (Whisper tiny)",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny-reduced",
        family="encdec",
        n_layers=2,
        n_enc_layers=2,
        enc_seq=64,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        activation="gelu",
        norm="layernorm",
        rope_theta=0.0,
        source="reduced smoke variant",
    )
