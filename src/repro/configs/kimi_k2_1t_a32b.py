"""kimi-k2-1t-a32b — trillion-param MoE [arXiv:2501.kimi2 paper-table].

61L d_model=7168 64H (GQA kv=8) per-expert d_ff=2048, vocab=163840,
MoE 384 experts top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=112,
        d_ff=2048,
        vocab_size=163840,
        activation="swiglu",
        qk_norm=False,
        rope_theta=50000.0,
        tie_embeddings=False,
        moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared_experts=1),
        source="arXiv:2501.kimi2 (Kimi K2 paper table)",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-reduced",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=64,
        vocab_size=512,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, n_shared_experts=1, capacity_factor=8.0),
        source="reduced smoke variant",
    )
