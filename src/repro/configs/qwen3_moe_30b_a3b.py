"""qwen3-moe-30b-a3b — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768, vocab=151936.
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        activation="swiglu",
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=False,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff=768),
        source="hf:Qwen/Qwen3-30B-A3B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-reduced",
        family="moe",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=64,
        vocab_size=512,
        qk_norm=True,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=8.0),
        source="reduced smoke variant",
    )
