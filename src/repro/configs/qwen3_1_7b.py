"""qwen3-1.7b — qk_norm, GQA [hf:Qwen/Qwen3-8B family].

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b",
        family="dense",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        head_dim=128,
        d_ff=6144,
        vocab_size=151936,
        activation="swiglu",
        qk_norm=True,
        rope_theta=1000000.0,
        tie_embeddings=True,
        source="hf:Qwen/Qwen3-1.7B",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b-reduced",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        qk_norm=True,
        source="reduced smoke variant",
    )
