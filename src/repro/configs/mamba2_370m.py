"""mamba2-370m — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=1024, attention-free, ssm_state=128, vocab=50280.
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        norm="rmsnorm",
        tie_embeddings=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
        source="arXiv:2405.21060 (Mamba-2 370m)",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m-reduced",
        family="ssm",
        n_layers=2,
        d_model=128,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=16),
        source="reduced smoke variant",
    )
