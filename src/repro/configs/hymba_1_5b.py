"""hymba-1.5b — hybrid parallel attn+mamba heads [arXiv:2411.13676].

32L d_model=1600 25H (GQA kv=5) d_ff=5504, ssm_state=16, vocab=32001.
Each block runs attention heads and Mamba (SSM) heads in PARALLEL on the same
input and fuses the normalized outputs (learned per-channel scaling). Hymba's
meta-tokens and partial-layer global attention are omitted (noted in
DESIGN.md); sliding-window attention is used as in the paper's local layers.
"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        activation="swiglu",
        rope_theta=10000.0,
        sliding_window=1024,        # hymba local attention layers
        tie_embeddings=True,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=1, head_dim=64, chunk=256),
        source="arXiv:2411.13676 (Hymba-1.5B)",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-reduced",
        family="hybrid",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        sliding_window=32,
        ssm=SSMConfig(d_state=8, d_conv=4, expand=1, head_dim=32, chunk=16),
        source="reduced smoke variant",
    )
