"""gemma-2b — GeGLU, head_dim=256, MQA [arXiv:2403.08295].

18L d_model=2048 8H (kv=1, MQA) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        n_layers=18,
        d_model=2048,
        n_heads=8,
        n_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        activation="geglu",
        rope_theta=10000.0,
        tie_embeddings=True,
        source="arXiv:2403.08295 (Gemma 2B)",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-reduced",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=1,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        activation="geglu",
        source="reduced smoke variant",
    )
