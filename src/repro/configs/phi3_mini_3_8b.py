"""phi3-mini-3.8b — RoPE SwiGLU GQA [arXiv:2404.14219].

32L d_model=3072 32H (kv=32, i.e. MHA) d_ff=8192 vocab=32064.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32064,
        activation="swiglu",
        rope_theta=10000.0,
        tie_embeddings=False,
        source="arXiv:2404.14219 (Phi-3-mini)",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-reduced",
        family="dense",
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        d_ff=256,
        vocab_size=512,
        source="reduced smoke variant",
    )
