"""The paper's own setting: ResNet-50-class CNN feature extractor (D=512
embedding) + an extreme-classification head (paper: N = 1M / 10M / 100M SKU
classes). Used by the paper-table benchmarks and the paper-shape dry-run.

``family="cnn"`` models consume images [B, H, W, 3]; the trunk is a
ResNet-v1.5-style network defined in models/resnet.py (implemented in JAX —
not stubbed; BatchNorm replaced by GroupNorm so the data-parallel trunk has no
cross-device batch statistics, noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig


def config(n_classes: int = 100_001_020) -> ModelConfig:
    return ModelConfig(
        name="sku100m-resnet50",
        family="cnn",
        n_layers=50,
        d_model=512,               # paper: feature dim 512
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=n_classes,      # classes == "vocab" for the shared head
        tie_embeddings=False,
        source="KDD'20 paper §4 (ResNet-50, D=512, SKU-100M)",
    )


def config_1m() -> ModelConfig:
    return config(1_020_250)


def config_10m() -> ModelConfig:
    return config(9_890_866)


def reduced(n_classes: int = 1024) -> ModelConfig:
    return ModelConfig(
        name="sku-resnet-reduced",
        family="cnn",
        n_layers=8,
        d_model=128,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=n_classes,
        tie_embeddings=False,
        source="reduced smoke variant",
    )
