"""smollm-135m — llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        n_layers=30,
        d_model=576,
        n_heads=9,
        n_kv_heads=3,
        d_ff=1536,
        vocab_size=49152,
        activation="swiglu",
        rope_theta=10000.0,
        tie_embeddings=True,
        source="hf:HuggingFaceTB/SmolLM-135M",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m-reduced",
        family="dense",
        n_layers=2,
        n_heads=3,
        n_kv_heads=3,
        d_model=96,
        d_ff=256,
        vocab_size=512,
        source="reduced smoke variant",
    )
