from repro.configs.base import (
    ARCH_IDS,
    DGCConfig,
    FCCSConfig,
    HeadConfig,
    INPUT_SHAPES,
    InputShape,
    LONG_CONTEXT_SKIP,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    SSMConfig,
    TrainConfig,
    for_shape,
    get_model_config,
    normalize_arch_id,
)

__all__ = [
    "ARCH_IDS", "DGCConfig", "FCCSConfig", "HeadConfig", "INPUT_SHAPES",
    "InputShape", "LONG_CONTEXT_SKIP", "ModelConfig", "MoEConfig",
    "ParallelConfig", "SSMConfig", "TrainConfig", "for_shape",
    "get_model_config", "normalize_arch_id",
]
