"""Training launcher.

Two entry modes:
  * ``--system paper`` — the faithful hybrid-parallel trainer (FE data
    parallel + fc model parallel on a 1-D ring) with KNN softmax / DGC /
    FCCS toggles. This is the paper's system end to end.
  * ``--system zoo`` — the GSPMD trainer for any assigned architecture
    (``--arch``), tensor/expert parallel on a (data, model) mesh.

On this CPU container use --devices N to get N fake devices (the flag must
be set before jax initializes, which this script does in main()).

Examples:
  PYTHONPATH=src python -m repro.launch.train --system paper --devices 8 \
      --classes 4096 --steps 200 --knn --fccs
  PYTHONPATH=src python -m repro.launch.train --system zoo --devices 8 \
      --arch smollm_135m --reduced --steps 20
"""
from __future__ import annotations

import argparse
import os
import sys


def parse_args(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=["paper", "zoo"], default="paper")
    p.add_argument("--devices", type=int, default=0,
                   help="fake host devices (CPU container)")
    # paper system
    p.add_argument("--classes", type=int, default=4096)
    p.add_argument("--feat-dim", type=int, default=64)
    p.add_argument("--knn", action="store_true")
    p.add_argument("--dgc", action="store_true")
    p.add_argument("--fccs", action="store_true")
    p.add_argument("--trunk", choices=["feats", "cnn"], default="feats")
    # zoo system
    p.add_argument("--arch", default="smollm_135m")
    p.add_argument("--reduced", action="store_true")
    # shared
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=2.0)
    p.add_argument("--optimizer", default="sgd")
    p.add_argument("--ckpt-dir", default="")
    return p.parse_args(argv)


def main(argv=None):
    args = parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    import jax  # noqa: E402  (after XLA_FLAGS)

    from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                    ModelConfig, TrainConfig,
                                    get_model_config, pad_vocab)
    from repro.data.synthetic import (ClassificationStream, lm_batch,
                                      sku_feature_batch, sku_image_batch)

    if args.system == "paper":
        from repro.train import hybrid
        from repro.train.trainer import PaperTrainer
        n_dev = len(jax.devices())
        mesh = hybrid.make_hybrid_mesh(n_dev)
        if args.trunk == "feats":
            mcfg = ModelConfig(name="paper-feats", family="feats", n_layers=0,
                               d_model=args.feat_dim, n_heads=0, n_kv_heads=0,
                               d_ff=0, vocab_size=args.classes, dtype="float32")
        else:
            from repro.configs import sku100m_resnet
            mcfg = sku100m_resnet.reduced(args.classes)
        hcfg = HeadConfig(softmax_impl="knn" if args.knn else "full",
                          knn_k=16, knn_kprime=32, active_frac=0.1,
                          rebuild_every=100)
        fcfg = FCCSConfig(eta0=args.lr, t_warm=max(1, args.steps // 10),
                          b0=args.batch, b_min=args.batch,
                          b_max=args.batch * 8,
                          t_ini=args.steps // 4, t_final=args.steps)
        tcfg = TrainConfig(optimizer=args.optimizer, fccs=fcfg,
                           dgc=DGCConfig(enabled=args.dgc, sparsity=0.99,
                                         chunk=2048))
        stream = ClassificationStream(args.classes, args.feat_dim)
        if args.trunk == "feats":
            data_fn = lambda t, b: sku_feature_batch(t, b, stream)
        else:
            data_fn = lambda t, b: sku_image_batch(t, b, args.classes)
        trainer = PaperTrainer(mcfg, hcfg, tcfg, mesh, data_fn,
                               hw_batch=args.batch, use_knn=args.knn,
                               ckpt_dir=args.ckpt_dir or None, ckpt_every=50)
        trainer.run(args.steps, use_fccs_batch=args.fccs)
        acc = trainer.evaluate(data_fn(10**6, args.batch * 4))
        print(f"[train] final eval accuracy: {acc:.4f}")
        return 0

    # ---- zoo ------------------------------------------------------------
    import dataclasses

    import jax.numpy as jnp
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_host_mesh, make_host_parallel_config
    from repro.models import lm
    from repro.optim import make_optimizer
    from repro.train import gspmd

    n_dev = len(jax.devices())
    n_model = min(4, n_dev)
    n_data = n_dev // n_model
    mesh = make_host_mesh(n_data, n_model)
    par = make_host_parallel_config(n_data, n_model)
    cfg = get_model_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    cfg = pad_vocab(cfg, n_model)
    shape = InputShape("cli", args.seq, args.batch, "train")
    hcfg = HeadConfig()
    tcfg = TrainConfig(optimizer=args.optimizer)
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    with jax.set_mesh(mesh):
        shards = gspmd.param_shardings(cfg, par, mesh)
        params = jax.tree.map(jax.device_put, params, shards)
        opt = make_optimizer(tcfg)
        opt_state = opt.init(params)
        step = jax.jit(gspmd.make_train_step(cfg, hcfg, par, tcfg, mesh, shape))
        for t in range(args.steps):
            inputs = lm_batch(t, args.batch, args.seq,
                              cfg.real_vocab_size or cfg.vocab_size)
            if cfg.family == "encdec":
                inputs["frames"] = jax.random.normal(
                    jax.random.PRNGKey(t), (args.batch, cfg.enc_seq,
                                            cfg.d_model), jnp.float32)
            params, opt_state, loss, metrics = step(params, opt_state,
                                                    inputs, args.lr)
            if t % 10 == 0:
                print(f"[zoo] step={t} loss={float(loss):.4f} "
                      f"acc={float(metrics['accuracy']):.3f}")
    if args.ckpt_dir:
        from repro import checkpoint as ckpt
        ckpt.save(args.ckpt_dir, params, step=args.steps)
        print(f"[zoo] checkpoint written to {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
