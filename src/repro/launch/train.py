"""Training launcher — a thin argparse shim over ``repro.api.Experiment``.

Two systems behind one entry point:
  * ``--system paper`` — the faithful hybrid-parallel trainer (FE data
    parallel + fc model parallel on a 1-D ring) with ANY registered softmax
    head (``--head full|knn|selective|mach|sampled|csoft``) plus DGC / FCCS
    toggles.
  * ``--system zoo`` — the GSPMD trainer for any assigned architecture
    (``--arch``), tensor/expert parallel on a (data, model) mesh, with the
    same ``--head`` choices routed through the head registry.

On this CPU container use --devices N to get N fake devices (the flag must
be set before jax initializes; ``ensure_host_devices`` handles that).

Examples:
  PYTHONPATH=src python -m repro.launch.train --system paper --devices 8 \
      --classes 4096 --steps 200 --head knn --fccs
  PYTHONPATH=src python -m repro.launch.train --system zoo --devices 8 \
      --arch smollm_135m --reduced --steps 20
"""
from __future__ import annotations

import argparse
import os
import sys


def parse_args(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=["paper", "zoo"], default="paper")
    p.add_argument("--devices", type=int, default=0,
                   help="fake host devices (CPU container)")
    # paper system
    p.add_argument("--classes", type=int, default=4096)
    p.add_argument("--feat-dim", type=int, default=64)
    p.add_argument("--head",
                   choices=["full", "knn", "selective", "mach", "sampled",
                            "csoft"],
                   default="full", help="softmax head strategy")
    p.add_argument("--backend", choices=["ref", "pallas"], default="ref",
                   help="head hot-path compute backend (pallas = fused "
                        "kernels, interpret mode on CPU)")
    p.add_argument("--knn", action="store_true",
                   help="back-compat alias for --head knn")
    p.add_argument("--dgc", action="store_true")
    p.add_argument("--fccs", action="store_true")
    p.add_argument("--trunk", choices=["feats", "cnn"], default="feats")
    # zoo system
    p.add_argument("--arch", default="smollm_135m")
    p.add_argument("--reduced", action="store_true")
    # shared
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=2.0)
    p.add_argument("--optimizer", default="sgd")
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=50,
                   help="full-state snapshot cadence (steps); both systems")
    p.add_argument("--ckpt-keep", type=int, default=None,
                   help="retain only the N newest checkpoints "
                        "(>= 1; omit to keep all)")
    p.add_argument("--resume", nargs="?", const=True, default=False,
                   metavar="CKPT",
                   help="restore the latest checkpoint and run only the "
                        "remaining steps (--steps is the TOTAL). With no "
                        "value, restores from --ckpt-dir; a value names a "
                        "checkpoint directory (or a .msgpack.zst file inside "
                        "one) and implies --ckpt-dir")
    p.add_argument("--resume-reshard", action="store_true",
                   help="allow --resume from a checkpoint written on a "
                        "DIFFERENT mesh shape: re-shards it onto this run's "
                        "--devices mesh (repro.elastic); implies --resume")
    p.add_argument("--trace-out", default="", metavar="PATH",
                   help="write a Chrome-trace/Perfetto JSON of the run's "
                        "telemetry spans (open at https://ui.perfetto.dev)")
    p.add_argument("--metrics-out", default="", metavar="PATH",
                   help="append per-step train metrics as JSONL")
    args = p.parse_args(argv)
    if args.resume_reshard and not args.resume:
        args.resume = True
    if isinstance(args.resume, str):
        # --resume CKPT names the checkpoint to restore from; accept either
        # the directory or one of its .msgpack.zst files
        path = args.resume
        if path.endswith(".msgpack.zst"):
            path = os.path.dirname(path) or "."
        if args.ckpt_dir and args.ckpt_dir != path:
            p.error(f"--resume {args.resume} conflicts with "
                    f"--ckpt-dir {args.ckpt_dir}")
        args.ckpt_dir = path
        args.resume = True
    if args.resume and not args.ckpt_dir:
        p.error("--resume requires --ckpt-dir (or --resume CKPT)")
    if args.ckpt_keep is not None and args.ckpt_keep <= 0:
        p.error("--ckpt-keep must be >= 1 (omit the flag to keep all)")
    if args.ckpt_every < 0:
        p.error("--ckpt-every must be >= 0")
    return args


def main(argv=None):
    args = parse_args(argv)
    from repro.api.bootstrap import ensure_host_devices
    ensure_host_devices(args.devices)

    from repro.api import Experiment
    from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                    TrainConfig)
    from repro.telemetry import Tracer

    telemetry = None
    if args.trace_out or args.metrics_out:
        telemetry = Tracer(metrics_path=args.metrics_out or None)

    def finish_telemetry():
        if telemetry is None:
            return
        telemetry.record_peak_memory()
        if args.trace_out:
            telemetry.write_chrome_trace(args.trace_out)
            st = telemetry.span_stats("train.step")
            print(f"[telemetry] {st['count']} train.step spans "
                  f"({st['total_s']:.2f}s) -> {args.trace_out}")
        if args.metrics_out:
            print(f"[telemetry] metrics -> {args.metrics_out}")
        telemetry.close()

    resume = "reshard" if args.resume_reshard else bool(args.resume)

    if args.system == "paper":
        # --knn is a back-compat alias; an explicit non-default --head wins
        impl = "knn" if (args.knn and args.head == "full") else args.head
        # sampled_n below the class count so the estimator path (partial
        # draw + logQ correction) is what actually runs, smoke included
        hcfg = HeadConfig(softmax_impl=impl, backend=args.backend, knn_k=16,
                          knn_kprime=32, active_frac=0.1, rebuild_every=100,
                          sampled_n=max(64, args.classes // 4))
        fcfg = FCCSConfig(eta0=args.lr, t_warm=max(1, args.steps // 10),
                          b0=args.batch, b_min=args.batch,
                          b_max=args.batch * 8,
                          t_ini=args.steps // 4, t_final=args.steps)
        tcfg = TrainConfig(optimizer=args.optimizer, fccs=fcfg,
                           dgc=DGCConfig(enabled=args.dgc, sparsity=0.99,
                                         chunk=2048, backend=args.backend))
        exp = Experiment.from_config(
            system="paper", trunk=args.trunk, classes=args.classes,
            feat_dim=args.feat_dim, batch=args.batch, head=hcfg, train=tcfg,
            ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
            ckpt_keep=args.ckpt_keep or 0)
        exp.fit(args.steps, use_fccs_batch=args.fccs, resume=resume,
                telemetry=telemetry)
        acc = exp.evaluate(eval_batch=args.batch * 4)
        print(f"[train] final eval accuracy: {acc:.4f}")
        finish_telemetry()
        return 0

    impl = "knn" if (args.knn and args.head == "full") else args.head
    exp = Experiment.from_config(
        system="zoo", arch=args.arch, reduced=args.reduced,
        batch=args.batch, seq=args.seq,
        head=HeadConfig(softmax_impl=impl, backend=args.backend, knn_k=16,
                        knn_kprime=32, active_frac=0.1, rebuild_every=100),
        train=TrainConfig(optimizer=args.optimizer),
        ckpt_dir=args.ckpt_dir or None, ckpt_every=args.ckpt_every,
        ckpt_keep=args.ckpt_keep or 0)
    exp.fit(args.steps, lr=args.lr, resume=resume, telemetry=telemetry)
    acc = exp.evaluate()
    print(f"[zoo] final next-token accuracy: {acc:.4f}")
    finish_telemetry()
    return 0


if __name__ == "__main__":
    sys.exit(main())
