"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first)."""
from __future__ import annotations

import jax

from repro.configs.base import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_parallel_config(*, multi_pod: bool = False, remat: str = "full",
                         fsdp: bool = True) -> ParallelConfig:
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    shape = (2, 16, 16) if multi_pod else (16, 16)
    cfg = ParallelConfig(mesh_shape=shape, axis_names=axes, remat=remat)
    if fsdp:
        # ZeRO-3-flavored param sharding: weight embed dims over "data"
        # (prepended -> takes precedence over the activation rules)
        cfg = ParallelConfig(
            mesh_shape=shape, axis_names=axes, remat=remat,
            param_rules=(("embed", "data"),) + cfg.rules)
    return cfg


def make_host_mesh(n_data: int = 2, n_model: int = 4):
    """Small CPU mesh for tests/examples on the fake-device host."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_host_parallel_config(n_data: int = 2, n_model: int = 4,
                              remat: str = "none") -> ParallelConfig:
    return ParallelConfig(mesh_shape=(n_data, n_model),
                          axis_names=("data", "model"), remat=remat)
