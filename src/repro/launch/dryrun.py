"""Multi-pod dry-run: lower + compile EVERY (architecture x input-shape) on
the production meshes and record memory/cost/collective statistics.

  single-pod : (16, 16)    ("data", "model")          256 chips
  multi-pod  : (2, 16, 16) ("pod", "data", "model")   512 chips

Per combo this lowers the step the shape dictates (train_step for train_4k,
prefill_step for prefill_32k, serve_step for decode_32k / long_500k),
compiles it, and appends a JSON line to the output file with:
  - memory_analysis (argument/output/temp/peak bytes; per-device)
  - cost_analysis flops / bytes accessed (per-device HLO program)
  - per-collective byte counts parsed from the compiled HLO
The roofline report (repro.roofline.analysis + EXPERIMENTS.md) reads this
file. Failures are recorded with the exception text — a failure here is a
sharding bug by definition.

``lower_paper_one`` is the PAPER-system counterpart (imported by
``benchmarks/table8_end2end.py`` for the simulated-100M dry run): it
shape-lowers the hybrid train step at an arbitrary class count on the
CURRENT devices and cross-checks the compiled HLO's collective bytes
against the analytic ``repro.telemetry`` comm ledger.

  PYTHONPATH=src python -m repro.launch.dryrun --out dryrun_results.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma_2b --shape train_4k --mesh both
"""

import os

if __name__ == "__main__":
    # MUST run before any jax import: the production meshes below need 512
    # placeholder host devices (2 pods x 16 x 16). Gated to the CLI so
    # importing ``lower_paper_one`` never mutates the caller's environment.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ARCH_IDS,
    HeadConfig,
    INPUT_SHAPES,
    LONG_CONTEXT_SKIP,
    TrainConfig,
    for_shape,
    get_model_config,
    normalize_arch_id,
    pad_vocab,
)
from repro.launch.mesh import make_parallel_config, make_production_mesh
from repro.models import lm
from repro.optim import make_optimizer
from repro.roofline.hlo import analyze as hlo_analyze
from repro.train import gspmd


def _sds_tree(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def _shardings_tree(mesh, pspec_tree):
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              use_knn: bool = False, remat: str = "full",
              extra_rules: tuple = (), extra_param_rules: tuple = (),
              fsdp: bool = True):
    """Lower+compile one combo. Returns a result dict (raises on failure).

    ``extra_rules`` / ``extra_param_rules`` PREPEND logical->mesh overrides
    (first match wins) — the §Perf hillclimb's experiment knobs.
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    par = make_parallel_config(multi_pod=multi_pod, remat=remat, fsdp=fsdp)
    if extra_rules:
        par = dataclasses.replace(par, rules=tuple(extra_rules) + par.rules)
    if extra_param_rules:
        base_pr = par.param_rules or par.rules
        par = dataclasses.replace(
            par, param_rules=tuple(extra_param_rules) + base_pr)
    cfg = get_model_config(arch)
    cfg = for_shape(cfg, shape)
    cfg = pad_vocab(cfg, 128 * mesh.shape[par.model_axis] // 16)
    hcfg = HeadConfig()
    tcfg = TrainConfig(optimizer="sgd")  # momentum SGD: paper's optimizer

    params_sds = jax.eval_shape(
        lambda: lm.init_model(jax.random.PRNGKey(0), cfg))
    if shape.mode != "train":
        # serving runs on inference-dtype weights, not fp32 masters
        inf_dt = jnp.dtype(cfg.dtype)
        params_sds = jax.tree.map(
            lambda l: (jax.ShapeDtypeStruct(l.shape, inf_dt)
                       if l.dtype == jnp.float32 else l), params_sds)
    pspecs = gspmd.param_pspecs(cfg, par)
    pshard = _shardings_tree(mesh, pspecs)
    input_sds = lm.input_specs(cfg, shape)
    in_shard = _shardings_tree(mesh, gspmd.input_pspecs(cfg, shape, par))

    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        if shape.mode == "train":
            opt = make_optimizer(tcfg)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            opt_shard = jax.tree.map(
                lambda l: NamedSharding(mesh, P()), opt_sds)
            # moments mirror param shardings
            opt_shard = type(opt_sds)(
                step=NamedSharding(mesh, P()),
                mu=pshard, nu=pshard if opt_sds.nu is not None else None)
            fn = gspmd.make_train_step(cfg, hcfg, par, tcfg, mesh, shape,
                                       use_knn=use_knn)
            args = (params_sds, opt_sds, input_sds,
                    jax.ShapeDtypeStruct((), jnp.float32))
            shardings = (pshard, opt_shard, in_shard, NamedSharding(mesh, P()))
            if use_knn:
                vocab_ax = par.mesh_axis_for("vocab") or par.model_axis
                vax = (vocab_ax if isinstance(vocab_ax, tuple)
                       else (vocab_ax,))
                n_model = 1
                for a in vax:
                    n_model *= mesh.shape[a]
                nnz_cap = cfg.vocab_size * hcfg.knn_k // n_model
                graph_sds = (jax.ShapeDtypeStruct((n_model, cfg.vocab_size + 1),
                                                  jnp.int32),
                             jax.ShapeDtypeStruct((n_model, nnz_cap), jnp.int32),
                             jax.ShapeDtypeStruct((n_model, nnz_cap), jnp.int32))
                gspec = P(vocab_ax if isinstance(vocab_ax, tuple) else vocab_ax,
                          None)
                gshard = (NamedSharding(mesh, gspec),) * 3
                args = args[:3] + (graph_sds,) + args[3:]
                shardings = shardings[:3] + (gshard,) + shardings[3:]
            lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        elif shape.mode == "prefill":
            fn = gspmd.make_prefill_step(cfg, par, mesh, shape)
            lowered = jax.jit(fn, in_shardings=(pshard, in_shard)).lower(
                params_sds, input_sds)
        else:  # decode
            caches_sds, slots_sds, window = lm.decode_state_specs(cfg, shape)
            cache_specs, slot_specs = gspmd.cache_pspecs(cfg, par, shape)
            cshard = _shardings_tree(mesh, cache_specs)
            sshard = _shardings_tree(mesh, slot_specs)
            tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            tok_shard = NamedSharding(
                mesh, gspmd.fit_spec(gspmd.batch_pspec(par), tok_sds.shape, par))
            fn = gspmd.make_serve_step(cfg, par, mesh, shape)
            # serving donates the cache buffers (in-place rotation)
            lowered = jax.jit(
                fn, in_shardings=(pshard, cshard, sshard, tok_shard),
                donate_argnums=(1, 2),
            ).lower(params_sds, caches_sds, slots_sds, tok_sds)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # jax <= 0.4.x wraps the dict in a 1-list
        cost = cost[0] if cost else {}
    hlo = hlo_analyze(compiled.as_text())  # loop-aware (see roofline/hlo.py)
    coll = hlo.collectives
    n_params = sum(l.size for l in jax.tree.leaves(params_sds))
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode, "knn": use_knn,
        "n_params": int(n_params),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "cost": {  # raw XLA numbers (loop bodies counted once — see hlo.py)
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "hlo": {  # loop-corrected per-device totals
            "flops": hlo.flops,
            "bytes": hlo.bytes,
        },
        "collectives": coll,
    }
    return result


def lower_paper_one(*, classes: int, head: str = "full",
                    backend: str = "ref", batch: int = 256,
                    feat_dim: int = 64, n_micro: int = 1,
                    n_dev: int = 0, knn_k: int = 16):
    """Shape-lower + compile ONE paper-system hybrid train step at an
    arbitrary class count (10**8 for the simulated-100M dry run) on the
    current devices, WITHOUT materializing any state: every input is a
    sharded ``ShapeDtypeStruct`` (the knn head's host-built warm-start
    graph is replaced by a same-shape spec at the post-refresh capacity
    ``classes * knn_k / n_dev``). Returns the same result-dict shape as
    ``lower_one`` plus the analytic ``repro.telemetry`` comm ledger and
    its divergence vs the compiled HLO."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from repro.api.experiment import paper_model_config
    from repro.api.heads import make_head
    from repro.optim import make_optimizer
    from repro.telemetry import train_step_ledger
    from repro.train import hybrid

    n_dev = n_dev or len(jax.devices())
    if classes % n_dev:
        raise ValueError(f"classes={classes} must divide over {n_dev} "
                         f"devices")
    if batch % n_dev or (batch // n_micro) % n_dev:
        raise ValueError(f"batch={batch} (n_micro={n_micro}) must divide "
                         f"over {n_dev} devices")
    mesh = hybrid.make_hybrid_mesh(n_dev)
    mcfg = paper_model_config("feats", classes, feat_dim)
    hcfg = HeadConfig(softmax_impl=head, backend=backend, knn_k=knn_k,
                      knn_kprime=2 * knn_k, active_frac=0.1)
    tcfg = TrainConfig(optimizer="sgd")
    h = make_head(mcfg, hcfg)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    w = sds((classes, feat_dim), jnp.float32, P(hybrid.AXIS, None))
    if head == "knn":
        nnz_cap = classes * knn_k // n_dev
        gspec = P(hybrid.AXIS, None)
        aux = (sds((n_dev, classes + 1), jnp.int32, gspec),
               sds((n_dev, nnz_cap), jnp.int32, gspec),
               sds((n_dev, nnz_cap), jnp.int32, gspec))
    elif head == "full":
        aux = ()
    else:
        raise ValueError(f"lower_paper_one models heads ('full', 'knn'), "
                         f"got {head!r}")
    # feats trunk: the FE has no trainable params (lm.init_model's 'head'
    # entry is what ``w`` above replaces)
    fe: dict = {}
    opt_tmpl = jax.eval_shape(make_optimizer(tcfg).init, (fe, w))
    rep = lambda l: sds(l.shape, l.dtype, P())            # noqa: E731
    wsh = lambda l: sds(l.shape, l.dtype, P(hybrid.AXIS, None))  # noqa: E731
    opt_sds = type(opt_tmpl)(
        step=rep(opt_tmpl.step), mu=({}, wsh(opt_tmpl.mu[1])),
        nu=({}, wsh(opt_tmpl.nu[1])) if opt_tmpl.nu is not None else None)
    state = hybrid.HybridState(fe, w, aux, opt_sds, None,
                               rep(jax.ShapeDtypeStruct((), jnp.int32)))
    inputs = {
        "features": sds((batch, feat_dim), jnp.float32, P(hybrid.AXIS)),
        "labels": sds((batch,), jnp.int32, P(hybrid.AXIS)),
    }
    lr = rep(jax.ShapeDtypeStruct((), jnp.float32))

    t0 = time.perf_counter()
    with jax.set_mesh(mesh):
        step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh,
                                      n_micro=n_micro, head=h,
                                      state_template=state)
        lowered = step.lower(state, inputs, lr)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    hlo = hlo_analyze(compiled.as_text())
    ledger = train_step_ledger(n_dev=n_dev, rows=batch, feat_dim=feat_dim,
                               head=head, backend=backend, n_micro=n_micro)
    return {
        "arch": "paper-feats", "shape": f"B{batch}xD{feat_dim}",
        "mesh": f"{n_dev}", "mode": "train",
        "head": head, "backend": backend, "classes": classes,
        "n_micro": n_micro,
        "n_params": classes * feat_dim,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "cost": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
        },
        "hlo": {"flops": hlo.flops, "bytes": hlo.bytes},
        "collectives": hlo.collectives,
        "ledger": ledger.per_kind(),
        # exact at n_micro=1; the scan body's CSE merges one pmax above
        # that (see repro.telemetry.ledger) — hence the looser rtol
        "ledger_divergence": ledger.compare(
            hlo.collectives, rtol=0.02 if n_micro == 1 else 0.10),
    }


def iter_combos(args):
    archs = ([normalize_arch_id(args.arch)] if args.arch else ARCH_IDS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape_name in shapes:
            if shape_name == "long_500k" and arch in LONG_CONTEXT_SKIP:
                continue  # enc-dec 448-ctx decoder: skip noted in DESIGN.md
            for mp in meshes:
                yield arch, shape_name, mp


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="")
    p.add_argument("--shape", default="", choices=[""] + list(INPUT_SHAPES))
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--knn", action="store_true",
                   help="lower the KNN-softmax train step variant")
    p.add_argument("--remat", default="full", choices=["none", "full"])
    p.add_argument("--out", default="dryrun_results.jsonl")
    p.add_argument("--skip-done", action="store_true")
    args = p.parse_args(argv)

    done = set()
    if args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if "error" not in r:
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("knn", False)))

    n_ok = n_fail = 0
    with open(args.out, "a") as f:
        for arch, shape_name, mp in iter_combos(args):
            mesh_name = "2x16x16" if mp else "16x16"
            if (arch, shape_name, mesh_name, args.knn) in done:
                continue
            tag = f"{arch} x {shape_name} x {mesh_name}" + \
                  (" [knn]" if args.knn else "")
            try:
                res = lower_one(arch, shape_name, multi_pod=mp,
                                use_knn=args.knn, remat=args.remat)
                n_ok += 1
                mem = res["memory"]
                per_dev = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
                print(f"[dryrun] OK   {tag}: compile={res['compile_s']:.1f}s "
                      f"flops={res['cost']['flops']:.3e} "
                      f"arg+temp={per_dev:.2f} GiB/dev")
            except Exception as e:  # noqa: BLE001 — record and continue
                res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "knn": args.knn, "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
                n_fail += 1
                print(f"[dryrun] FAIL {tag}: {type(e).__name__}: {str(e)[:200]}")
            f.write(json.dumps(res) + "\n")
            f.flush()
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
