"""Serving launcher — a thin argparse shim over ``repro.api.Experiment``.

The paper deploys the trained 100M-class fc as a retrieval index (§4.5 —
nearest class weight); ``Experiment.serve`` on the paper system IS that
lookup, executed on the training mesh with whatever head is configured
(hashed-bucket decode for mach/csoft). On the zoo system it is standard batched
token serving: prefill once, then greedy decode steps through the KV/SSM
cache and the sharded-vocab argmax.

  PYTHONPATH=src python -m repro.launch.serve --devices 8 \
      --arch smollm_135m --reduced --prompt-len 32 --gen 16 --batch 8
  PYTHONPATH=src python -m repro.launch.serve --devices 8 --system paper \
      --classes 4096 --head knn --batch 64
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=["paper", "zoo"], default="zoo")
    p.add_argument("--devices", type=int, default=0)
    # zoo
    p.add_argument("--arch", default="smollm_135m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    # paper
    p.add_argument("--classes", type=int, default=4096)
    p.add_argument("--feat-dim", type=int, default=64)
    p.add_argument("--head",
                   choices=["full", "knn", "selective", "mach", "sampled",
                            "csoft"],
                   default="full")
    p.add_argument("--topk", type=int, default=0,
                   help="paper system: return the k best classes per query "
                        "with scores (0 = greedy argmax)")
    # shared
    p.add_argument("--backend", choices=["ref", "pallas"], default="ref",
                   help="head hot-path compute backend")
    p.add_argument("--batch", type=int, default=8)
    args = p.parse_args(argv)

    from repro.api.bootstrap import ensure_host_devices
    ensure_host_devices(args.devices)
    from repro.api import Experiment
    from repro.configs.base import HeadConfig

    if args.system == "paper":
        exp = Experiment.from_config(
            system="paper", classes=args.classes, feat_dim=args.feat_dim,
            batch=args.batch,
            head=HeadConfig(softmax_impl=args.head, backend=args.backend),
            log_every=0)
        t0 = time.perf_counter()
        if args.topk:
            ids, scores = exp.serve(batch=args.batch, top_k=args.topk,
                                    return_scores=True)
            dt = time.perf_counter() - t0
            print(f"[serve] {args.head}-head top-{args.topk} retrieval over "
                  f"{args.classes} classes ({args.backend}): "
                  f"{ids.shape[0]} queries in {dt*1e3:.1f} ms")
            print("[serve] first query ids:   ", ids[0].tolist())
            print("[serve] first query scores:",
                  [round(float(s), 3) for s in scores[0]])
            return 0
        preds = exp.serve(batch=args.batch)
        dt = time.perf_counter() - t0
        print(f"[serve] {args.head}-head retrieval over {args.classes} "
              f"classes: {preds.shape[0]} queries in {dt*1e3:.1f} ms")
        print("[serve] first predictions:", preds[:8].tolist())
        return 0

    exp = Experiment.from_config(system="zoo", arch=args.arch,
                                 reduced=args.reduced, batch=args.batch,
                                 seq=args.prompt_len + args.gen)
    try:
        t0 = time.perf_counter()
        gen = exp.serve(prompt_len=args.prompt_len, gen=args.gen,
                        batch=args.batch)
        dt = time.perf_counter() - t0
    except NotImplementedError as e:
        print(f"[serve] {e}")
        return 0
    print(f"[serve] generated {gen.shape} tokens in {dt*1e3:.1f} ms "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("[serve] first row:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
