"""Serving launcher — a thin argparse shim over ``repro.api.Experiment``.

The paper deploys the trained 100M-class fc as a retrieval index (§4.5 —
nearest class weight); ``Experiment.serve`` on the paper system IS that
lookup, executed on the training mesh with whatever head is configured
(hashed-bucket decode for mach/csoft). On the zoo system it is standard batched
token serving: prefill once, then greedy decode steps through the KV/SSM
cache and the sharded-vocab argmax.

``--replay SECONDS`` switches either system onto the ``repro.serving``
tier instead: single feature queries from a bursty Zipfian synthetic
trace are submitted to a ``ServingEngine`` (request coalescing into
padded micro-batches, ``--max-wait-ms`` flush deadline, optional
``--cache N`` LRU score cache) and the run reports p50/p95/p99 latency,
QPS, batch occupancy, and cache hit-rate. The full harness (trajectory
file, cached-vs-uncached sweep) lives in ``benchmarks/serve_replay.py``.

  PYTHONPATH=src python -m repro.launch.serve --devices 8 \
      --arch smollm_135m --reduced --prompt-len 32 --gen 16 --batch 8
  PYTHONPATH=src python -m repro.launch.serve --devices 8 --system paper \
      --classes 4096 --head knn --batch 64
  PYTHONPATH=src python -m repro.launch.serve --devices 8 --system paper \
      --classes 4096 --head full --topk 5 --replay 1.0 --cache 512 \
      --max-wait-ms 2
"""
from __future__ import annotations

import argparse
import sys


def _run_replay(exp, args, feat_dim: int, telemetry=None) -> int:
    """Trace-driven serving through the engine (both systems)."""
    import numpy as np

    from repro.serving import (ScoreCache, TraceConfig, VirtualClock,
                               generate_trace, latency_stats,
                               make_query_pool, replay_trace)

    tcfg = TraceConfig(duration=args.replay)
    times, qids = generate_trace(tcfg)
    pool = make_query_pool(args.classes, feat_dim, tcfg.pool)
    cache = ScoreCache(args.cache) if args.cache else None
    clock = VirtualClock()
    eng = exp.serving_engine(
        top_k=args.topk or None, max_batch=args.batch,
        max_wait_ms=args.max_wait_ms, cache=cache, clock=clock.now,
        index=args.index if args.index != "none" else None,
        nprobe=args.nprobe or None, telemetry=telemetry)
    eng.warmup(pool[0])
    done = replay_trace(eng, clock, times, qids, pool)
    lat = latency_stats(done)
    st = eng.stats()
    span = max(r.t_done for r in done) - min(r.t_submit for r in done)
    print(f"[serve] replayed {lat['n']} requests over {args.replay:.1f}s "
          f"of trace ({args.head} head, top-{args.topk or 1}): "
          f"p50={lat['p50_ms']:.2f}ms p95={lat['p95_ms']:.2f}ms "
          f"p99={lat['p99_ms']:.2f}ms qps={lat['n'] / max(span, 1e-9):.1f}")
    print(f"[serve] batches={st['n_batches']} "
          f"occupancy={st['mean_batch_occupancy']:.2f} "
          f"cache_hit_rate={st['cache_hit_rate']:.2f}")
    pred = done[0].ids
    print("[serve] first result ids:", np.atleast_1d(pred).tolist())
    return 0


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--system", choices=["paper", "zoo"], default="zoo")
    p.add_argument("--devices", type=int, default=0)
    # zoo
    p.add_argument("--arch", default="smollm_135m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    # paper
    p.add_argument("--classes", type=int, default=4096)
    p.add_argument("--feat-dim", type=int, default=64)
    p.add_argument("--head",
                   choices=["full", "knn", "selective", "mach", "sampled",
                            "csoft"],
                   default="full")
    p.add_argument("--topk", type=int, default=0,
                   help="paper system: return the k best classes per query "
                        "with scores (0 = greedy argmax)")
    p.add_argument("--index", choices=["none", "ivf"], default="none",
                   help="top-k serving index: 'ivf' probes nprobe k-means "
                        "centroids per class shard and reranks only their "
                        "member rows (sublinear in the class count)")
    p.add_argument("--nprobe", type=int, default=0,
                   help="--index ivf: centroids probed per shard "
                        "(0 = the index default, max(2, n_clusters/32))")
    # shared
    p.add_argument("--backend", choices=["ref", "pallas"], default="ref",
                   help="head hot-path compute backend")
    p.add_argument("--batch", type=int, default=8)
    # serving tier (repro.serving engine)
    p.add_argument("--replay", type=float, default=0.0, metavar="SECONDS",
                   help="replay a bursty Zipfian synthetic trace of this "
                        "many (virtual) seconds through the serving "
                        "engine instead of a one-shot batch")
    p.add_argument("--cache", type=int, default=0, metavar="N",
                   help="LRU hot-query score-cache capacity for --replay "
                        "(0 = no cache)")
    p.add_argument("--max-wait-ms", type=float, default=2.0,
                   help="coalescer flush deadline: max time a queued query "
                        "waits for batch-mates before a partial "
                        "micro-batch is cut")
    # telemetry (docs/telemetry.md)
    p.add_argument("--trace-out", default="", metavar="PATH",
                   help="write a Chrome-trace/Perfetto JSON of the serving "
                        "spans (open at https://ui.perfetto.dev)")
    p.add_argument("--metrics-out", default="", metavar="PATH",
                   help="append serving metrics as JSONL")
    args = p.parse_args(argv)

    # validate up front: a clear argparse error beats an opaque jit shape
    # failure out of the serving step
    if args.batch <= 0:
        p.error(f"--batch must be a positive query count, got {args.batch}")
    if args.topk < 0:
        p.error(f"--topk must be >= 0, got {args.topk}")
    if args.system == "paper" and args.topk > args.classes:
        p.error(f"--topk {args.topk} exceeds --classes {args.classes}: "
                f"retrieval cannot return more classes than exist")
    if args.index == "ivf" and not args.topk:
        p.error("--index ivf serves top-k retrieval; pass --topk K")
    if args.nprobe < 0:
        p.error(f"--nprobe must be >= 0, got {args.nprobe}")
    if args.nprobe and args.index != "ivf":
        p.error("--nprobe only applies with --index ivf")
    if args.cache < 0:
        p.error(f"--cache must be >= 0, got {args.cache}")
    if args.max_wait_ms < 0:
        p.error(f"--max-wait-ms must be >= 0, got {args.max_wait_ms}")

    from repro.api.bootstrap import ensure_host_devices
    ensure_host_devices(args.devices)
    from repro.telemetry import Tracer

    # one tracer for the whole run: the timings printed below are the
    # SAME engine/telemetry spans the benchmarks record (no second
    # hand-rolled perf_counter clock that can disagree on cache hits)
    tr = Tracer(metrics_path=args.metrics_out or None)
    try:
        return _serve(args, tr)
    finally:
        if args.trace_out:
            tr.write_chrome_trace(args.trace_out)
            print(f"[telemetry] trace -> {args.trace_out}")
        tr.close()


def _serve(args, tr) -> int:
    from repro.api import Experiment
    from repro.configs.base import HeadConfig

    def compute_ms() -> float:
        """Engine-measured compute wall-clock (ms) for this run's
        serve.compute spans — what the serving benchmarks also report."""
        return tr.span_stats("serve.compute")["total_s"] * 1e3

    if args.system == "paper":
        exp = Experiment.from_config(
            system="paper", classes=args.classes, feat_dim=args.feat_dim,
            batch=args.batch,
            head=HeadConfig(softmax_impl=args.head, backend=args.backend),
            log_every=0)
        if args.replay > 0:
            return _run_replay(exp, args, args.feat_dim, telemetry=tr)
        if args.topk:
            ids, scores = exp.serve(
                batch=args.batch, top_k=args.topk, return_scores=True,
                index=args.index if args.index != "none" else None,
                nprobe=args.nprobe or None, telemetry=tr)
            via = f" via {args.index}" if args.index != "none" else ""
            print(f"[serve] {args.head}-head top-{args.topk} retrieval over "
                  f"{args.classes} classes ({args.backend}{via}): "
                  f"{ids.shape[0]} queries in {compute_ms():.1f} ms")
            print("[serve] first query ids:   ", ids[0].tolist())
            print("[serve] first query scores:",
                  [round(float(s), 3) for s in scores[0]])
            return 0
        preds = exp.serve(batch=args.batch, telemetry=tr)
        print(f"[serve] {args.head}-head retrieval over {args.classes} "
              f"classes: {preds.shape[0]} queries in {compute_ms():.1f} ms")
        print("[serve] first predictions:", preds[:8].tolist())
        return 0

    exp = Experiment.from_config(system="zoo", arch=args.arch,
                                 reduced=args.reduced, batch=args.batch,
                                 seq=args.prompt_len + args.gen,
                                 head=HeadConfig(softmax_impl=args.head,
                                                 backend=args.backend))
    if args.replay > 0:
        # zoo replay serves FEATURE queries against the model's class
        # matrix (the classifier-as-retrieval path); token decoding stays
        # on the one-shot path below
        args = argparse.Namespace(**{**vars(args),
                                     "classes": exp.model_cfg.vocab_size})
        return _run_replay(exp, args, exp.model_cfg.d_model, telemetry=tr)
    if args.topk:
        # zoo feature retrieval against the model's class matrix (same
        # contract as the paper top-k path; token decoding stays below)
        try:
            ids, scores = exp.serve(
                batch=args.batch, top_k=args.topk, return_scores=True,
                index=args.index if args.index != "none" else None,
                nprobe=args.nprobe or None, telemetry=tr)
        except NotImplementedError as e:
            print(f"[serve] {e}")
            return 0
        via = f" via {args.index}" if args.index != "none" else ""
        print(f"[serve] zoo {args.head}-head top-{args.topk} retrieval over "
              f"{exp.model_cfg.vocab_size} classes ({args.backend}{via}): "
              f"{ids.shape[0]} queries in {compute_ms():.1f} ms")
        print("[serve] first query ids:   ", ids[0].tolist())
        print("[serve] first query scores:",
              [round(float(s), 3) for s in scores[0]])
        return 0
    try:
        gen = exp.serve(prompt_len=args.prompt_len, gen=args.gen,
                        batch=args.batch, telemetry=tr)
    except NotImplementedError as e:
        print(f"[serve] {e}")
        return 0
    prefill_ms = tr.span_stats("serve.prefill")["total_s"] * 1e3
    decode_s = tr.span_stats("serve.decode")["total_s"]
    print(f"[serve] generated {gen.shape} tokens: prefill {prefill_ms:.1f} ms"
          f" + decode {decode_s * 1e3:.1f} ms "
          f"({args.batch * args.gen / max(decode_s, 1e-9):.1f} tok/s)")
    print("[serve] first row:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
