"""Serving launcher: batched greedy decoding with the sharded-vocab head.

The paper deploys the trained 100M-class fc as a retrieval index (§4.5 —
nearest class weight). ``serve_logits_local``'s distributed argmax IS that
nearest-neighbor lookup, executed on the training mesh. For the LM zoo this
becomes standard batched token serving: prefill once, then decode steps.

  PYTHONPATH=src python -m repro.launch.serve --devices 8 \
      --arch smollm_135m --reduced --prompt-len 32 --gen 16 --batch 8
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--devices", type=int, default=0)
    p.add_argument("--arch", default="smollm_135m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--gen", type=int, default=16)
    args = p.parse_args(argv)
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}")
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs.base import InputShape, get_model_config, pad_vocab
    from repro.data.synthetic import lm_batch
    from repro.launch.mesh import make_host_mesh, make_host_parallel_config
    from repro.models import lm
    from repro.models import decoder as dec_lib
    from repro.train import gspmd

    n_dev = len(jax.devices())
    n_model = min(4, n_dev)
    mesh = make_host_mesh(n_dev // n_model, n_model)
    par = make_host_parallel_config(n_dev // n_model, n_model)
    cfg = get_model_config(args.arch, reduced=args.reduced)
    if args.reduced:
        cfg = dataclasses.replace(cfg, dtype="float32")
    cfg = pad_vocab(cfg, n_model)
    if cfg.family == "encdec":
        print("serve demo supports decoder-only archs; whisper decoding is "
              "exercised in tests/test_serving.py")
        return 0

    total = args.prompt_len + args.gen
    pshape = InputShape("serve-prefill", args.prompt_len, args.batch, "prefill")
    dshape = InputShape("serve-decode", total, args.batch, "decode")
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    with jax.set_mesh(mesh):
        shards = gspmd.param_shardings(cfg, par, mesh)
        params = jax.tree.map(jax.device_put, params, shards)
        prompts = lm_batch(0, args.batch, args.prompt_len,
                           cfg.real_vocab_size or cfg.vocab_size)
        window = lm.decode_window(cfg, total)
        prefill = jax.jit(gspmd.make_prefill_step(cfg, par, mesh, dshape))
        serve = jax.jit(gspmd.make_serve_step(cfg, par, mesh, dshape))
        t0 = time.perf_counter()
        tok, caches = prefill(params, {"tokens": prompts["tokens"]})
        # grow prefill caches (length prompt_len) into the decode window
        def grow(c):
            if c.ndim >= 3 and c.shape[2] == args.prompt_len:
                pad = [(0, 0)] * c.ndim
                pad[2] = (0, window - args.prompt_len)
                return jnp.pad(c, pad)
            return c
        if cfg.family != "ssm":
            caches = jax.tree.map(grow, caches)
        slots = dec_lib.init_cache_slots(
            cfg, window, prefill_positions=jnp.arange(args.prompt_len))
        out = [tok]
        tok = tok[:, None]
        for i in range(args.gen - 1):
            tok, caches, slots = serve(params, caches, slots, tok)
            out.append(tok[:, 0])
        dt = time.perf_counter() - t0
        gen = jnp.stack(out, axis=1)
        print(f"[serve] generated {gen.shape} tokens in {dt*1e3:.1f} ms "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print("[serve] first row:", gen[0].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
