"""Three-term roofline from the dry-run results (deliverable g).

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

HLO numbers are the loop-corrected per-device totals from roofline.hlo (the
dry-run records per-device SPMD programs, so 'chips x' is already folded in:
terms below use per-device values against per-chip peaks).

MODEL_FLOPS (the 'useful work') is analytic: 6*N*D for dense training
(N = params, D = tokens), 6*N_active*D for MoE, 2*N(+attn) for decode.
The ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is
useful — it surfaces remat recompute, replicated attention heads, dropped/
padded expert capacity, and the head's logits work.

Hardware constants (TPU v5e-class target, per chip):
    197 TFLOP/s bf16 | 819 GB/s HBM | ~50 GB/s/link ICI
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Optional

from repro.configs.base import (INPUT_SHAPES, ModelConfig, get_model_config,
                                normalize_arch_id)

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (per-device collective throughput)


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def active_params(cfg: ModelConfig) -> float:
    """Total params, counting only top-k (+shared) experts for MoE."""
    import jax

    from repro.models import lm
    sds = jax.eval_shape(lambda: lm.init_model(jax.random.PRNGKey(0), cfg))
    total = sum(l.size for l in jax.tree.leaves(sds))
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    expert_p = cfg.n_layers * 3 * cfg.d_model * m.d_ff * m.n_experts
    active_expert_p = expert_p * (m.top_k / m.n_experts)
    return float(total - expert_p + active_expert_p)


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Analytic useful FLOPs of one GLOBAL step (all chips together)."""
    shape = INPUT_SHAPES[shape_name]
    n_act = active_params(cfg)
    if shape.mode == "train":
        tokens = shape.global_batch * (1 if cfg.family == "cnn"
                                       else shape.seq_len)
        flops = 6.0 * n_act * tokens
        # causal attention score/context matmuls (not in 6ND)
        if cfg.n_heads and cfg.family != "cnn":
            hd = cfg.resolved_head_dim
            win = cfg.sliding_window or shape.seq_len
            eff = min(win, shape.seq_len)
            flops += (6.0 * 2.0 * shape.global_batch * cfg.n_layers
                      * cfg.n_heads * hd * shape.seq_len * eff / 2)
        return flops
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_act * tokens
        if cfg.n_heads and cfg.family != "cnn":
            hd = cfg.resolved_head_dim
            win = cfg.sliding_window or shape.seq_len
            eff = min(win, shape.seq_len)
            flops += (2.0 * 2.0 * shape.global_batch * cfg.n_layers
                      * cfg.n_heads * hd * shape.seq_len * eff / 2)
        return flops
    # decode: one token per sequence
    flops = 2.0 * n_act * shape.global_batch
    if cfg.n_heads and cfg.family != "ssm":
        hd = cfg.resolved_head_dim
        win = cfg.sliding_window or shape.seq_len
        kv_len = min(win, shape.seq_len)
        flops += (2.0 * 2.0 * shape.global_batch * cfg.n_layers
                  * cfg.n_heads * hd * kv_len)
    return flops


# ---------------------------------------------------------------------------
# the report
# ---------------------------------------------------------------------------


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_per_dev: float
    useful_ratio: float
    peak_gib: float
    fits: bool

    def terms(self):
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}


def analyze_record(rec: dict) -> Optional[RooflineRow]:
    if "error" in rec:
        return None
    n_chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = rec["hlo"]["flops"]
    bytes_dev = rec["hlo"]["bytes"]
    coll_dev = rec["collectives"]["total_bytes"]
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", coll_s)), key=lambda kv: kv[1])[0]
    cfg = get_model_config(normalize_arch_id(rec["arch"]))
    mf = model_flops(cfg, rec["shape"])
    useful = mf / max(flops_dev * n_chips, 1.0)
    mem = rec["memory"]
    per_dev = mem["argument_bytes"] + max(mem["temp_bytes"],
                                          mem.get("peak_bytes", 0))
    return RooflineRow(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        n_chips=n_chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=coll_s, dominant=dominant, model_flops=mf,
        hlo_flops_per_dev=flops_dev, useful_ratio=useful,
        peak_gib=per_dev / 2**30, fits=per_dev <= 16 * 2**30)


def load_rows(path: str, mesh: Optional[str] = None):
    rows = []
    seen = set()
    for line in open(path):
        rec = json.loads(line)
        key = (rec.get("arch"), rec.get("shape"), rec.get("mesh"),
               rec.get("knn", False))
        if key in seen:
            continue
        seen.add(key)
        if mesh and rec.get("mesh") != mesh:
            continue
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def bottleneck_sentence(row: RooflineRow) -> str:
    """One sentence on what would move the dominant term down."""
    if row.dominant == "collective":
        return ("collective-bound: cut cross-device bytes (KNN-softmax "
                "active classes shrink the feature all-gather + head work; "
                "DGC shrinks data-parallel grad traffic; larger microbatches "
                "amortize FSDP gathers)")
    if row.dominant == "memory":
        return ("HBM-bound: raise arithmetic intensity (fuse softmax-CE "
                "streaming kernel, larger attention kv blocks, bf16 "
                "activations end-to-end)")
    return ("compute-bound: good — push MFU via MXU-aligned tiles and drop "
            "redundant/replicated compute (replicated attention heads, "
            "padded expert capacity)")


def to_markdown(rows, hillclimbed=()) -> str:
    out = ["| arch | shape | mesh | compute(s) | memory(s) | collective(s) | "
           "dominant | MODEL_FLOPS | useful | peak GiB/dev | fits 16G |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.arch, r.shape, r.mesh)):
        mark = " **(hillclimbed)**" if (r.arch, r.shape) in hillclimbed else ""
        out.append(
            f"| {r.arch}{mark} | {r.shape} | {r.mesh} | {r.compute_s:.2e} | "
            f"{r.memory_s:.2e} | {r.collective_s:.2e} | {r.dominant} | "
            f"{r.model_flops:.2e} | {r.useful_ratio:.2f} | "
            f"{r.peak_gib:.1f} | {'yes' if r.fits else 'NO'} |")
    return "\n".join(out)
