"""CLI: render the roofline table from a dry-run results file.

  PYTHONPATH=src python -m repro.roofline.report dryrun_results.jsonl [mesh]
"""
from __future__ import annotations

import sys

from repro.roofline.analysis import bottleneck_sentence, load_rows, to_markdown


def main(argv=None):
    argv = argv if argv is not None else sys.argv[1:]
    path = argv[0] if argv else "dryrun_results.jsonl"
    mesh = argv[1] if len(argv) > 1 else None
    rows = load_rows(path, mesh=mesh)
    print(to_markdown(rows))
    print()
    doms = {}
    for r in rows:
        doms.setdefault(r.dominant, []).append(r)
    for dom, rs in sorted(doms.items()):
        print(f"{dom}-bound: {len(rs)} combos — e.g. "
              f"{rs[0].arch} x {rs[0].shape}: {bottleneck_sentence(rs[0])}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
