"""Loop-aware analysis of compiled (SPMD, per-device) HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE regardless of
trip count (verified on this toolchain: scan(4) and scan(8) of the same
matmul report identical flops), which under-counts scanned layers,
micro-batches and flash-attention block loops by orders of magnitude. This
module re-derives per-device statistics by parsing the HLO module, building
the computation call graph, and multiplying through
``backend_config={"known_trip_count": ...}``:

  * flops        — dot/convolution contractions (elementwise excluded; for
                   these models matmuls are >98% of compute)
  * bytes        — operand+output sizes of top-level (post-fusion) ops, the
                   same HBM-traffic proxy cost_analysis uses
  * collectives  — per-kind {bytes, count}, loop-multiplied

Raw cost_analysis numbers are still recorded by the dry-run for reference.
"""
from __future__ import annotations

import re
from functools import lru_cache
from typing import Dict, List, NamedTuple, Optional

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*{\s*$")
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_TRIP_RE = re.compile(r'known_trip_count\D+(\d+)')
_CALL_ATTR_RE = re.compile(
    r"(?:body|calls|to_apply|condition|branch_computations)=\s*"
    r"(?:\{([^}]*)\}|%?([\w.\-]+))")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems_bytes(type_str: str):
    """All array shapes in a (possibly tuple) type string -> total bytes."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Op(NamedTuple):
    name: str
    type_str: str
    opcode: str
    line: str
    operands: List[str]
    calls: List[str]
    trip: int


class Module(NamedTuple):
    computations: Dict[str, List[Op]]
    entry: str


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "iota", "after-all", "partition-id", "replica-id"}
# Ops that touch only a window of their big operand: charge 2x output
# (read slice + write) like XLA's cost analysis, NOT the full operand —
# otherwise every scan iteration is billed the whole stacked tensor.
_SLICE_LIKE = {"slice", "dynamic-slice", "gather"}
# Write a window into a big buffer: charge 2x the update operand.
_UPDATE_LIKE = {"dynamic-update-slice", "scatter", "select-and-scatter"}
# Read small, write big: charge output only.
_EXPAND_LIKE = {"broadcast", "pad"}


def parse_module(text: str) -> Module:
    comps: Dict[str, List[Op]] = {}
    entry = ""
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = hdr.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_HEAD_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # result type: either a balanced-paren tuple (may contain /*index=N*/
        # comments!) or a single shape token
        if rest.startswith("("):
            depth = 0
            ti = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        ti = i
                        break
            type_str = rest[:ti + 1]
            rest = rest[ti + 1:]
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            type_str = rest[:sp]
            rest = rest[sp:]
        m2 = _OPCODE_RE.match(rest)
        if not m2:
            continue
        opcode = m2.group(1)
        # operand names: inside the first balanced parens after the opcode
        paren = rest[m2.end() - 1:]
        depth = 0
        end = 0
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_str = paren[:end + 1]
        operands = _OPERAND_RE.findall(operand_str)
        attrs = paren[end:]
        calls = []
        for g1, g2 in _CALL_ATTR_RE.findall(attrs):
            if g1:
                calls += _OPERAND_RE.findall(g1)
            elif g2:
                calls.append(g2)
        trip = 1
        tm = _TRIP_RE.search(line)
        if tm:
            trip = int(tm.group(1))
        comps[cur].append(Op(name, type_str, opcode, line, operands, calls,
                             trip))
    return Module(comps, entry)


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_dims = _shape_dims(op.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    cm = _CDIMS_RE.search(op.line)
    contract = 1
    if cm and op.operands:
        lhs_type = symtab.get(op.operands[0], "")
        lhs_dims = _shape_dims(lhs_type)
        for idx in cm.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_n * contract


def _conv_flops(op: Op, symtab: Dict[str, str]) -> float:
    out_dims = _shape_dims(op.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    if len(op.operands) < 2:
        return 0.0
    k_dims = _shape_dims(symtab.get(op.operands[1], ""))
    if not k_dims:
        return 0.0
    k_n = 1
    for d in k_dims:
        k_n *= d
    # kernel = spatial x in_ch x out_ch; out features appear in out_n too:
    # flops ~= 2 * out_n * (kernel_elems / out_features). Use the smallest
    # plausible feature dim as out_features.
    out_feat = min(k_dims)
    return 2.0 * out_n * (k_n / max(out_feat, 1))


class Analysis(NamedTuple):
    flops: float
    bytes: float
    collectives: dict


_PARAM_NUM_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_operand_bytes(mod: Module, op: Op, symtab: Dict[str, str]) -> float:
    """HBM bytes read by a fusion: per operand, if every consumer of the
    corresponding fused parameter is slice-like, charge the consumers'
    output sizes (XLA only reads the window); otherwise the full operand."""
    total = 0.0
    comp = mod.computations.get(op.calls[0], []) if op.calls else []
    params = {}
    consumers: Dict[str, List[Op]] = {}
    for fop in comp:
        if fop.opcode == "parameter":
            m = _PARAM_NUM_RE.search(fop.line)
            if m:
                params[int(m.group(1))] = fop.name
        for o in fop.operands:
            consumers.setdefault(o, []).append(fop)
    for i, operand in enumerate(op.operands):
        full = _shape_elems_bytes(symtab.get(operand, ""))
        pname = params.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c.opcode in _SLICE_LIKE for c in cons):
            total += sum(_shape_elems_bytes(c.type_str) for c in cons)
        else:
            total += full
    return total


def analyze(text: str) -> Analysis:
    mod = parse_module(text)

    memo: Dict[str, Analysis] = {}

    def comp_analysis(cname: str) -> Analysis:
        if cname in memo:
            return memo[cname]
        memo[cname] = Analysis(0.0, 0.0, {})  # cycle guard
        ops = mod.computations.get(cname, [])
        symtab = {op.name: op.type_str for op in ops}
        flops = 0.0
        nbytes = 0.0
        coll = {k: {"bytes": 0.0, "count": 0.0} for k in COLLECTIVE_KINDS}
        for op in ops:
            kind = op.opcode
            mult = op.trip if kind == "while" else 1
            # recurse into called computations (while/fusion/call/cond)
            sub_f = sub_b = 0.0
            sub_c = None
            if op.calls and kind not in ("all-reduce", "reduce-scatter"):
                for c in op.calls:
                    a = comp_analysis(c)
                    sub_f += a.flops
                    sub_b += a.bytes
                    if sub_c is None:
                        sub_c = {k: dict(v) for k, v in a.collectives.items()}
                    else:
                        for k in COLLECTIVE_KINDS:
                            sub_c[k]["bytes"] += a.collectives[k]["bytes"]
                            sub_c[k]["count"] += a.collectives[k]["count"]
            if kind == "fusion":
                # flops inside the fused computation count; bytes only at
                # the fusion boundary, windowed reads charged as windows
                flops += sub_f
                nbytes += (_shape_elems_bytes(op.type_str)
                           + _fusion_operand_bytes(mod, op, symtab))
                continue
            if kind == "while":
                flops += mult * sub_f
                nbytes += mult * sub_b
                if sub_c:
                    for k in COLLECTIVE_KINDS:
                        coll[k]["bytes"] += mult * sub_c[k]["bytes"]
                        coll[k]["count"] += mult * sub_c[k]["count"]
                continue
            if kind in ("call", "conditional", "custom-call"):
                flops += sub_f
                nbytes += sub_b
                if sub_c:
                    for k in COLLECTIVE_KINDS:
                        coll[k]["bytes"] += sub_c[k]["bytes"]
                        coll[k]["count"] += sub_c[k]["count"]
                # fall through to count own boundary bytes for custom-call
                if kind != "custom-call":
                    continue
            base = kind.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_KINDS:
                if kind.endswith("-done"):
                    continue
                b = _shape_elems_bytes(op.type_str)
                coll[base]["bytes"] += b
                coll[base]["count"] += 1
                nbytes += b
                continue
            if kind == "dot":
                flops += _dot_flops(op, symtab)
            elif kind == "convolution":
                flops += _conv_flops(op, symtab)
            if kind in _SKIP_BYTES:
                continue
            if kind in _SLICE_LIKE:
                nbytes += 2 * _shape_elems_bytes(op.type_str)
            elif kind in _UPDATE_LIKE:
                upd = (_shape_elems_bytes(symtab.get(op.operands[1], ""))
                       if len(op.operands) > 1 else
                       _shape_elems_bytes(op.type_str))
                nbytes += 2 * min(upd, _shape_elems_bytes(op.type_str))
            elif kind in _EXPAND_LIKE:
                nbytes += _shape_elems_bytes(op.type_str)
            else:
                nbytes += _shape_elems_bytes(op.type_str) + sum(
                    _shape_elems_bytes(symtab.get(o, "")) for o in op.operands)
        memo[cname] = Analysis(flops, nbytes, coll)
        return memo[cname]

    a = comp_analysis(mod.entry)
    coll = {k: {"bytes": v["bytes"], "count": v["count"]}
            for k, v in a.collectives.items()}
    coll["total_bytes"] = sum(v["bytes"] for k, v in a.collectives.items())
    return Analysis(a.flops, a.bytes, coll)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Loop-aware per-kind collective accounting (back-compat wrapper)."""
    return analyze(hlo_text).collectives
