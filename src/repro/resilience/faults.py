"""Fault injection for the training loop (AIOpsLab-style scenarios).

A 5-day run on a 256-GPU cluster (the paper's setting) gets preempted,
loses hosts, and stalls on stragglers. The trainers expose a ``step_hook``
seam — called with the global step index immediately before that step
runs — and this module provides the faults to plug into it:

  * **kill** — raise ``SimulatedFault`` before step ``kill_at``: the
    training process dies mid-run with whatever checkpoints it has already
    written. Recovery = a FRESH trainer (process-simulated: new
    ``Experiment``, new jit caches, re-initialized params) restoring the
    latest full-state snapshot and re-running the lost steps.
  * **delay** — sleep ``delay_s`` before step ``delay_at``: a straggler /
    slow-host fault. Numerics must be unaffected (the step stream is
    synchronous); what it costs is wall-clock, which the harness reports.

Where the kill lands is the scenario catalogue: mid-epoch (between
checkpoints — work since the last snapshot is lost and replayed),
mid-refresh-interval (the KNN graph / LSH tables in the snapshot are
*stale relative to the params* exactly as they were in the killed run —
restore must NOT rebuild them or the resumed trajectory diverges), and
post-DGC-accumulation (error-feedback residuals u/v are mid-flight and
must ride the snapshot).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional


class SimulatedFault(RuntimeError):
    """An injected process death. Escapes the training loop like a real
    SIGKILL would — nothing downstream of the loop runs."""


@dataclass(frozen=True)
class FaultPlan:
    """When to hurt the run. ``kill_at``/``delay_at`` are global step
    indices (the value the trainer's ``step_hook`` receives)."""
    kill_at: Optional[int] = None
    delay_at: Optional[int] = None
    delay_s: float = 0.0

    def __post_init__(self):
        if self.kill_at is None and self.delay_at is None:
            raise ValueError("FaultPlan with neither kill_at nor delay_at "
                             "injects nothing")
        if self.delay_at is not None and self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")


def fault_hook(plan: FaultPlan,
               sleep: Callable[[float], None] = time.sleep):
    """A ``step_hook`` implementing ``plan``. ``sleep`` is injectable so
    tests can count delay faults without real wall-clock."""
    def hook(t: int):
        if plan.delay_at is not None and t == plan.delay_at:
            sleep(plan.delay_s)
        if plan.kill_at is not None and t == plan.kill_at:
            raise SimulatedFault(f"injected kill before step {t}")
    return hook
