"""Kill-and-recover harness: inject a fault, restore, prove equivalence.

The contract under test (docs/resilience.md): a run killed at step ``k``
and resumed by a FRESH trainer from its latest full-state checkpoint must
be *equivalent* to a never-interrupted reference run —

  * ``"bitwise"`` — every leaf of the final full-state snapshot (FE
    params, head params, head aux, optimizer moments, DGC buffers) is
    byte-identical, and the per-step loss rows match exactly. This is the
    deterministic-path guarantee: the synthetic data stream, FCCS
    schedule, and per-step sampling are all pure functions of the saved
    cursor, and XLA CPU reductions are run-to-run deterministic.
  * ``"trajectory"`` — the resumed loss trajectory matches the reference
    to a tolerance (for paths with documented nondeterminism).

``kill_and_recover`` runs all three legs (reference, victim, resume) from
one experiment factory and returns a ``RecoveryReport`` with the
equivalence verdict plus the recovery metrics ROADMAP asks for: steps of
work lost (replayed), and restore wall-clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.resilience.faults import FaultPlan, SimulatedFault, fault_hook


# ---------------------------------------------------------------------------
# tree comparison
# ---------------------------------------------------------------------------


def tree_compare(a, b) -> dict:
    """Leaf-by-leaf comparison of two snapshot pytrees.

    Returns {"bitwise": bool, "max_abs_diff": float, "mismatches": [path]}.
    Bitwise means same dtype, same shape, same bytes — the strongest
    equivalence a restore can claim. ``max_abs_diff`` is over float leaves
    only (int leaves — graph indices, hash tables — either match or are
    listed as mismatches).
    """
    import jax

    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_flatten_with_path(b)[0]
    assert len(fa) == len(fb), "snapshot structures differ"
    mismatches, max_diff = [], 0.0
    for (pa, la), (pb, lb) in zip(fa, fb):
        x = np.asarray(jax.device_get(la))
        y = np.asarray(jax.device_get(lb))
        if x.dtype != y.dtype or x.shape != y.shape \
                or x.tobytes() != y.tobytes():
            mismatches.append(jax.tree_util.keystr(pa))
            if (x.shape == y.shape
                    and np.issubdtype(x.dtype, np.floating)):
                d = np.max(np.abs(x.astype(np.float64)
                                  - y.astype(np.float64)))
                max_diff = max(max_diff, float(d))
            else:
                max_diff = float("inf")
    return {"bitwise": not mismatches, "max_abs_diff": max_diff,
            "mismatches": mismatches}


def _snapshot_of(exp):
    """The experiment's full-state checkpoint tree (both systems)."""
    if hasattr(exp, "trainer"):            # paper system
        return exp.trainer._snapshot()
    return exp._snapshot()                 # zoo system


def _cursor_of(exp) -> int:
    return exp.trainer._t if hasattr(exp, "trainer") else exp._t


def _history_of(exp) -> list:
    return exp.trainer.history if hasattr(exp, "trainer") else exp.history


# ---------------------------------------------------------------------------
# the harness
# ---------------------------------------------------------------------------


@dataclass
class RecoveryReport:
    head: str
    equivalence: str                  # asserted class: bitwise | trajectory
    kill_at: int
    restored_step: int
    steps_replayed: int               # work lost to the fault (k - restore)
    recovery_s: float                 # fresh-trainer restore wall-clock
    bitwise: bool                     # final snapshots byte-identical
    max_abs_diff: float
    mismatches: list = field(default_factory=list)
    loss_max_rel: float = 0.0         # resumed-vs-reference loss rows
    loss_tol: float = 1e-4            # trajectory acceptance bound
    resumed_history: list = field(default_factory=list)
    reference_history: list = field(default_factory=list)
    restore_spans: list = field(default_factory=list)  # telemetry
                                      # "train.restore" SpanEvents
    # elastic (shrink/grow) legs only — zero/empty on same-mesh recovery
    reshard_s: float = 0.0            # "train.reshard" span wall-clock
    reshard_bytes_moved: float = 0.0  # "reshard.bytes_moved" counter
    src_mesh: str = ""                # geometry the checkpoint was written on
    dst_mesh: str = ""                # geometry the resumed run restored onto

    @property
    def ok(self) -> bool:
        if self.equivalence == "bitwise":
            return self.bitwise and self.loss_max_rel == 0.0
        return self.loss_max_rel < self.loss_tol

    def summary(self) -> str:
        elastic = ""
        if self.src_mesh and self.src_mesh != self.dst_mesh:
            elastic = (f" reshard {self.src_mesh}->{self.dst_mesh} "
                       f"{self.reshard_bytes_moved / 1e6:.2f} MB "
                       f"{self.reshard_s * 1e3:.0f} ms;")
        return (f"[{self.head}] kill@{self.kill_at} -> restore@"
                f"{self.restored_step} (+{self.steps_replayed} replayed, "
                f"{self.recovery_s * 1e3:.0f} ms restore)"
                f"{elastic} {self.equivalence}: "
                f"{'OK' if self.ok else 'DIVERGED ' + str(self.mismatches)}")


def _loss_divergence(resumed: list, reference: list) -> float:
    """Max relative loss gap over the steps both histories cover. The
    victim's pre-kill rows live in ITS history, not the resumed trainer's,
    so compare on step index."""
    ref = {r["step"]: r["loss"] for r in reference}
    worst = 0.0
    for row in resumed:
        if row["step"] in ref:
            a, b = row["loss"], ref[row["step"]]
            worst = max(worst, abs(a - b) / max(abs(b), 1e-12))
    return worst


def kill_and_recover(make_exp: Callable[[Optional[str]], object], *,
                     total_steps: int, kill_at: int, ckpt_dir: str,
                     equivalence: str = "bitwise", head: str = "?",
                     fit_kw: Optional[dict] = None,
                     plan: Optional[FaultPlan] = None,
                     telemetry=None) -> RecoveryReport:
    """Run the full scenario and report.

    ``make_exp(ckpt_dir)`` must build a FRESH experiment (new params, new
    jit caches) writing checkpoints under ``ckpt_dir`` when it is not
    None — each call simulates a separate process. ``fit_kw`` is passed to
    every ``fit`` call (e.g. ``{"lr": 0.5}`` for the zoo,
    ``{"use_fccs_batch": True}`` for the paper system). ``telemetry=``
    (a ``repro.telemetry.Tracer``; one is created internally when omitted)
    is installed on the resumed experiment, and its recorded
    ``train.restore`` spans land in ``RecoveryReport.restore_spans``.
    """
    from repro.telemetry import Tracer
    if equivalence not in ("bitwise", "trajectory"):
        raise ValueError(f"unknown equivalence class {equivalence!r}")
    if not 0 < kill_at < total_steps:
        raise ValueError(f"kill_at must be inside (0, {total_steps}), "
                         f"got {kill_at}")
    fit_kw = dict(fit_kw or {})
    plan = plan or FaultPlan(kill_at=kill_at)

    # 1. uninterrupted reference
    ref = make_exp(None)
    ref.fit(total_steps, **fit_kw)

    # 2. victim: same config, checkpointing, killed mid-run
    victim = make_exp(ckpt_dir)
    try:
        victim.fit(total_steps, step_hook=fault_hook(plan), **fit_kw)
        raise AssertionError(
            f"fault plan {plan} never fired in {total_steps} steps")
    except SimulatedFault:
        pass

    # 3. fresh process-simulated trainer restores and replays to the end
    tele = telemetry if telemetry is not None else Tracer()
    t0 = time.perf_counter()
    resumed = make_exp(ckpt_dir)
    if hasattr(resumed, "trainer"):        # paper system
        resumed.trainer.telemetry = tele
    else:                                  # zoo system
        resumed.telemetry = tele
    restored_step = resumed.restore()
    recovery_s = time.perf_counter() - t0
    remaining = total_steps - _cursor_of(resumed)
    if remaining > 0:
        resumed.fit(remaining, **fit_kw)

    cmp = tree_compare(_snapshot_of(resumed), _snapshot_of(ref))
    return RecoveryReport(
        head=head, equivalence=equivalence, kill_at=kill_at,
        restored_step=restored_step,
        steps_replayed=kill_at - restored_step, recovery_s=recovery_s,
        bitwise=cmp["bitwise"], max_abs_diff=cmp["max_abs_diff"],
        mismatches=cmp["mismatches"],
        loss_max_rel=_loss_divergence(_history_of(resumed),
                                      _history_of(ref)),
        resumed_history=list(_history_of(resumed)),
        reference_history=list(_history_of(ref)),
        restore_spans=[e for e in tele.events
                       if e.name == "train.restore"])


def _mesh_of(exp) -> str:
    return str(dict(exp.mesh.shape))


def elastic_kill_and_recover(
        make_src_exp: Callable[[Optional[str]], object],
        make_dst_exp: Callable[[Optional[str]], object], *,
        total_steps: int, kill_at: int, ckpt_dir: str, head: str = "?",
        fit_kw: Optional[dict] = None, plan: Optional[FaultPlan] = None,
        loss_tol: float = 0.1, telemetry=None) -> RecoveryReport:
    """The shrink/grow leg: kill a run on the SOURCE mesh, resume it on a
    DIFFERENT destination mesh through the elastic reshard path, and
    compare its loss trajectory against an uninterrupted reference run on
    the destination mesh.

    ``make_src_exp`` / ``make_dst_exp`` build fresh experiments on the two
    mesh shapes (same config otherwise). Equivalence is ``"trajectory"``
    by construction, and the tolerance is loose by design: the hybrid
    trainer differentiates INSIDE the shard_map body, where the psum
    transpose sums one replicated cotangent per device, so the head
    gradient's effective scale is proportional to the ring size (a fixed
    property of the trainer — on any one mesh it is a constant folded
    into the effective lr). The victim's pre-kill steps therefore
    optimize at the SRC ring's scale while the reference ran at the DST
    ring's throughout; the restore itself is exact (bitwise dense state —
    tests/test_elastic.py), and ``loss_tol`` bounds the percent-level
    trajectory gap the differing pre-kill scale leaves behind. The
    final-state tree compare is skipped (mesh-shaped aux legitimately
    differs in shape). The report additionally records the reshard
    wall-clock ("train.reshard" span) and bytes moved
    ("reshard.bytes_moved" counter).
    """
    from repro.telemetry import Tracer
    if not 0 < kill_at < total_steps:
        raise ValueError(f"kill_at must be inside (0, {total_steps}), "
                         f"got {kill_at}")
    fit_kw = dict(fit_kw or {})
    plan = plan or FaultPlan(kill_at=kill_at)

    # 1. uninterrupted reference on the DESTINATION mesh
    ref = make_dst_exp(None)
    ref.fit(total_steps, **fit_kw)

    # 2. victim on the SOURCE mesh, checkpointing, killed mid-run
    victim = make_src_exp(ckpt_dir)
    src_mesh = _mesh_of(victim)
    try:
        victim.fit(total_steps, step_hook=fault_hook(plan), **fit_kw)
        raise AssertionError(
            f"fault plan {plan} never fired in {total_steps} steps")
    except SimulatedFault:
        pass

    # 3. fresh dst-mesh trainer reshards the checkpoint and replays
    tele = telemetry if telemetry is not None else Tracer()
    t0 = time.perf_counter()
    resumed = make_dst_exp(ckpt_dir)
    if hasattr(resumed, "trainer"):        # paper system
        resumed.trainer.telemetry = tele
    else:                                  # zoo system
        resumed.telemetry = tele
    restored_step = resumed.restore(reshard=True)
    recovery_s = time.perf_counter() - t0
    remaining = total_steps - _cursor_of(resumed)
    if remaining > 0:
        resumed.fit(remaining, **fit_kw)

    reshard_ns = sum(e.dur_ns for e in tele.events
                     if e.name == "train.reshard")
    return RecoveryReport(
        head=head, equivalence="trajectory", kill_at=kill_at,
        restored_step=restored_step,
        steps_replayed=kill_at - restored_step, recovery_s=recovery_s,
        bitwise=False, max_abs_diff=float("nan"),
        loss_max_rel=_loss_divergence(_history_of(resumed),
                                      _history_of(ref)),
        loss_tol=loss_tol,
        resumed_history=list(_history_of(resumed)),
        reference_history=list(_history_of(ref)),
        restore_spans=[e for e in tele.events
                       if e.name in ("train.restore", "train.reshard")],
        reshard_s=reshard_ns * 1e-9,
        reshard_bytes_moved=float(
            tele.counters.get("reshard.bytes_moved", 0.0)),
        src_mesh=src_mesh, dst_mesh=_mesh_of(resumed))
