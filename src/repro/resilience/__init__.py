"""Resilience: full-state checkpoint/restore + fault injection.

See docs/resilience.md for the per-head checkpoint contract and the
recovery equivalence classes the harness asserts.
"""
from repro.resilience.faults import FaultPlan, SimulatedFault, fault_hook
from repro.resilience.harness import (RecoveryReport,
                                      elastic_kill_and_recover,
                                      kill_and_recover, tree_compare)

__all__ = ["FaultPlan", "SimulatedFault", "fault_hook", "RecoveryReport",
           "elastic_kill_and_recover", "kill_and_recover", "tree_compare"]
