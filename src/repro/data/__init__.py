from repro.data.synthetic import (
    ClassificationStream,
    lm_batch,
    sku_feature_batch,
    sku_image_batch,
)

__all__ = ["ClassificationStream", "lm_batch", "sku_feature_batch",
           "sku_image_batch"]
