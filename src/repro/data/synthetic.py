"""Deterministic synthetic data pipeline.

The Alibaba Retail Product Dataset is proprietary; we substitute generators
whose *difficulty structure* matches the paper's setting:

* SKU-style classification: each class has a unit prototype vector; samples
  are noisy prototypes. Nearby prototypes create genuine inter-class
  confusion, so the KNN graph over class weights is meaningful (neighbors =
  confusable classes — the property KNN softmax exploits).
* Image variant for the CNN trunk: prototypes are rendered into class-coded
  low-frequency patterns + noise.
* LM streams: affine-recurrence token sequences with noise — next-token
  structure a small LM can learn.

Everything is stateless/deterministic (seeded); batches can be produced for
any step index independently, which is what a sharded multi-host input
pipeline needs (each host computes its own slice — no data service needed).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


class ClassificationStream:
    """SKU-like stream: n_classes prototypes in R^d, noisy samples."""

    def __init__(self, n_classes: int, d: int, *, seed: int = 0,
                 noise: float = 0.2, n_clusters: Optional[int] = None):
        self.n_classes = n_classes
        self.d = d
        self.noise = noise
        key = jax.random.PRNGKey(seed)
        k1, k2, k3 = jax.random.split(key, 3)
        # clustered prototypes: classes within a cluster are confusable
        # (offset scale calibrated for a paper-like 80-90% accuracy band)
        n_clusters = n_clusters or max(1, n_classes // 64)
        centers = jax.random.normal(k1, (n_clusters, d))
        centers = centers / jnp.linalg.norm(centers, axis=-1, keepdims=True)
        assign = jax.random.randint(k2, (n_classes,), 0, n_clusters)
        offs = jax.random.normal(k3, (n_classes, d)) * (1.5 / jnp.sqrt(d))
        protos = centers[assign] + offs
        self.prototypes = protos / jnp.linalg.norm(protos, axis=-1,
                                                   keepdims=True)

    def batch(self, step: int, batch_size: int):
        """-> (features [b,d], labels [b]) for a given step (deterministic)."""
        key = jax.random.fold_in(jax.random.PRNGKey(9001), step)
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (batch_size,), 0, self.n_classes)
        feats = self.prototypes[labels] + self.noise * jax.random.normal(
            k2, (batch_size, self.d))
        return feats, labels

    def eval_batch(self, step: int, batch_size: int):
        key = jax.random.fold_in(jax.random.PRNGKey(77), step)
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (batch_size,), 0, self.n_classes)
        feats = self.prototypes[labels] + self.noise * jax.random.normal(
            k2, (batch_size, self.d))
        return feats, labels


def sku_feature_batch(step: int, batch_size: int, stream: ClassificationStream):
    f, y = stream.batch(step, batch_size)
    return {"features": f, "labels": y}


def sku_image_batch(step: int, batch_size: int, n_classes: int, hw: int = 32,
                    seed: int = 0, noise: float = 0.3):
    """Class-coded image batch for the CNN trunk: a per-class low-frequency
    pattern + noise. [b, hw, hw, 3]."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 4242), step)
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch_size,), 0, n_classes)
    yy, xx = jnp.meshgrid(jnp.linspace(0, 1, hw), jnp.linspace(0, 1, hw),
                          indexing="ij")
    lab = labels.astype(jnp.float32)[:, None, None]
    base = jnp.stack([
        jnp.sin(2 * jnp.pi * ((lab % 7 + 1) * xx[None] + (lab % 3) * 0.2)),
        jnp.cos(2 * jnp.pi * ((lab % 5 + 1) * yy[None])),
        jnp.sin(2 * jnp.pi * ((lab % 11 + 1) * (xx + yy)[None] * 0.5)),
    ], axis=-1)
    imgs = base + noise * jax.random.normal(k2, base.shape)
    return {"images": imgs, "labels": labels}


def lm_batch(step: int, batch_size: int, seq_len: int, vocab: int,
             seed: int = 0, noise_p: float = 0.05):
    """Learnable synthetic LM stream: per-sequence affine recurrence
    t_{i+1} = (a*t_i + c) mod vocab with occasional resets/noise.
    Returns {"tokens": [b,s], "labels": [b,s]} (labels = next token)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 31337), step)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    a = jax.random.randint(k1, (batch_size, 1), 1, 8) * 2 + 1
    c = jax.random.randint(k2, (batch_size, 1), 0, vocab)
    t0 = jax.random.randint(k3, (batch_size,), 0, vocab)

    def stepf(t, _):
        nt = (t * a[:, 0] + c[:, 0]) % vocab
        return nt, nt

    _, seq = jax.lax.scan(stepf, t0, None, length=seq_len)
    tokens = jnp.concatenate([t0[:, None], seq.T], axis=1)  # [b, s+1]
    noise = jax.random.bernoulli(k4, noise_p, tokens.shape)
    rnd = jax.random.randint(jax.random.fold_in(k4, 1), tokens.shape, 0, vocab)
    tokens = jnp.where(noise, rnd, tokens)
    return {"tokens": tokens[:, :seq_len],
            "labels": tokens[:, 1:seq_len + 1]}
