"""Reshard planning: map a checkpoint's source mesh onto a destination mesh.

A checkpoint stores GLOBAL (host-gathered) arrays, but several pieces of
state bake the mesh shape in anyway: the row partition of the `[V/n, D]`
class-weight and optimizer-moment shards, the sketch heads' bucket count
(rounded up to divide the ring), per-head aux CSRs with a leading
model-shard axis, and the DGC error-feedback buffers' leading worker axis.
This module is the geometry half of `repro.elastic`: it validates a
src->dst move up front (`ReshardError` instead of a shape error deep in
jax) and produces a `ReshardPlan` — the interval intersection of the src
and dst row partitions — that the transforms in `repro.elastic.reshard`
and the trainers' restore paths execute and account (bytes moved).

Everything here is host-side and jax-free; it is imported by the
checkpoint layer for up-front validation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple


class ReshardError(ValueError):
    """A checkpoint cannot be restored onto this experiment's geometry —
    raised up front (before any leaf is decoded or placed) with the src
    and dst geometries named, instead of a jax shape error downstream."""


@dataclass(frozen=True)
class MeshGeometry:
    """The mesh shape a checkpoint was written on (or is restored onto).

    ``n_model`` is the number of class/vocab row shards (the hybrid ring
    size on the paper system; ``gspmd.n_vocab_shards`` on the zoo),
    ``n_data`` the data-parallel width, ``n_classes`` the mesh-invariant
    logical class count (0 = unknown, skips the class-count check)."""
    n_model: int
    n_data: int = 1
    n_classes: int = 0

    def describe(self) -> str:
        return (f"(model={self.n_model}, data={self.n_data}, "
                f"classes={self.n_classes})")

    def meta(self) -> dict:
        """The dict stored in the checkpoint payload (`checkpoint.save
        meta=`)."""
        return {"n_model": self.n_model, "n_data": self.n_data,
                "n_classes": self.n_classes}


def geometry_from_meta(meta: Optional[dict],
                       default: MeshGeometry) -> MeshGeometry:
    """Geometry recorded in a checkpoint's meta dict; ``default`` (the
    restoring experiment's own geometry) for pre-elastic checkpoints that
    carry no meta — those can only assert same-mesh restores."""
    if not meta or "n_model" not in meta:
        return default
    return MeshGeometry(
        n_model=int(meta["n_model"]),
        n_data=int(meta.get("n_data", 1)),
        n_classes=int(meta.get("n_classes", default.n_classes)))


def validate_geometry(src: MeshGeometry, dst: MeshGeometry, *,
                      reshard: bool = False) -> None:
    """Up-front src-vs-dst check. Class-count changes are never
    reshardable; mesh-shape changes are allowed only when the caller asked
    for an elastic restore (``resume="reshard"`` / ``--resume-reshard``)."""
    if src.n_classes and dst.n_classes and src.n_classes != dst.n_classes:
        raise ReshardError(
            f"checkpoint was written for {src.n_classes} classes but this "
            f"experiment has {dst.n_classes}; class-count changes cannot "
            f"be resharded [src {src.describe()} -> dst {dst.describe()}]")
    if (src.n_model, src.n_data) != (dst.n_model, dst.n_data):
        if not reshard:
            raise ReshardError(
                f"checkpoint mesh {src.describe()} does not match restore "
                f"mesh {dst.describe()}; pass resume='reshard' "
                f"(launcher: --resume-reshard) to re-shard onto this mesh")
        if dst.n_classes and dst.n_classes % dst.n_model != 0:
            raise ReshardError(
                f"cannot reshard onto dst {dst.describe()}: "
                f"{dst.n_classes} classes not divisible by "
                f"{dst.n_model} model shards")


@dataclass(frozen=True)
class RowTransfer:
    """One contiguous global row interval ``[start, stop)`` moving from
    ``src_shard``'s block to ``dst_shard``'s block."""
    src_shard: int
    dst_shard: int
    start: int
    stop: int

    @property
    def rows(self) -> int:
        return self.stop - self.start


@dataclass(frozen=True)
class ReshardPlan:
    """Row repartition of ``n_rows`` global rows from ``src.n_model`` to
    ``dst.n_model`` equal blocks.

    ``aligned`` — one ring divides the other, so every dst block is a
    concatenation of whole src blocks (or a sub-slice of one): the restore
    places the global array gather-free (each device slices its own
    contiguous rows). Otherwise the restore host-stages one destination
    shard at a time (chunked copies; peak extra host memory is bounded by
    one shard block plus one chunk — never a second full-array gather).

    ``moved_rows`` counts rows whose owning shard INDEX changes (the
    device at ring position i keeps rows it already owned) — the bytes a
    real multi-host reshard puts on the wire.
    """
    src: MeshGeometry
    dst: MeshGeometry
    n_rows: int
    aligned: bool
    transfers: Tuple[RowTransfer, ...]
    moved_rows: int

    def bytes_moved(self, row_bytes: int) -> int:
        return self.moved_rows * int(row_bytes)

    def describe(self) -> str:
        kind = "aligned" if self.aligned else "chunked"
        return (f"{self.src.n_model}->{self.dst.n_model} shards, "
                f"{self.n_rows} rows, {kind}, moved={self.moved_rows}")


def plan_reshard(src: MeshGeometry, dst: MeshGeometry,
                 n_rows: Optional[int] = None) -> ReshardPlan:
    """Interval-intersect the src and dst row partitions of ``n_rows``
    (default: the geometries' class count) global rows."""
    n = int(n_rows if n_rows is not None else src.n_classes)
    n_src, n_dst = src.n_model, dst.n_model
    if n <= 0:
        raise ReshardError(f"cannot plan a reshard over {n} rows")
    for label, shards in (("src", n_src), ("dst", n_dst)):
        if shards < 1 or n % shards != 0:
            raise ReshardError(
                f"{n} rows not divisible by {label} shards={shards} "
                f"[src {src.describe()} -> dst {dst.describe()}]")
    r_src, r_dst = n // n_src, n // n_dst
    transfers, moved = [], 0
    for q in range(n_dst):
        lo, hi = q * r_dst, (q + 1) * r_dst
        for s in range(lo // r_src, (hi - 1) // r_src + 1):
            a, b = max(lo, s * r_src), min(hi, (s + 1) * r_src)
            transfers.append(RowTransfer(s, q, a, b))
            if s != q:
                moved += b - a
    aligned = n_src % n_dst == 0 or n_dst % n_src == 0
    return ReshardPlan(src=src, dst=dst, n_rows=n, aligned=aligned,
                       transfers=tuple(transfers), moved_rows=moved)
