"""Host-side reshard transforms for every kind of mesh-shaped state.

Checkpoints store host-gathered GLOBAL arrays, so dense `[V, D]` rows need
no data movement at all — only re-placement. What this module rewrites is
the state whose LAYOUT bakes in the ring size:

  * `place_row_sharded` — put a global row-sharded array back on a mesh:
    gather-free single `device_put` when the plan is aligned; otherwise
    host-staged per-destination-shard placement with chunked copies
    (peak extra host memory: one shard block + one chunk).
  * KNN graph CSR (`decompress_graph` / `repack_knn_aux`) — the per-shard
    CSR is exactly invertible (ranks record each entry's original column),
    so an n->m re-pack preserves the mid-refresh-interval graph bit-for-bit
    and n->m->n is the identity.
  * LSH tables (`lsh_bucket_map` / `repack_lsh_aux`) — per-shard bucket
    CSRs are inverted to a global class->bucket map and re-sorted per dst
    shard with the same stable-sort semantics `build_sharded_lsh_tables`
    uses, so the re-pack is exact (planes are replicated and untouched).
  * Sketch buckets (`rebucket_sketch`) — when the stored bucket count no
    longer divides the ring, classes are re-hashed with the SAME universal
    hash family at the new modulus and each new bucket's weight is the
    mean of its classes' old bucket weights (empty buckets zero). This is
    the one lossy transform (softmax support changes with B); optimizer
    moments get the identical mapping.
  * DGC error feedback (`redistribute_dgc`) — the per-worker residuals are
    redistributed mass-preservingly: every new worker gets an equal share
    of the total pending residual (top-k sparsification is nonlinear, so
    no per-worker split can be exactly equivalent; the total correction
    the ring will eventually apply is preserved).
  * Zoo vocab padding (`resize_vocab_rows`) — Megatron-style pad rows are
    sliced off / re-grown with zeros when the dst ring implies a different
    padded vocab (pad rows are masked out of the loss, so this is exact
    on the real vocabulary).
"""
from __future__ import annotations

import numpy as np

from repro.elastic.plan import ReshardError, ReshardPlan


def _host(a) -> np.ndarray:
    import jax
    return np.asarray(jax.device_get(a))


def leaf_bytes(a) -> int:
    arr = np.asarray(a) if not hasattr(a, "nbytes") else a
    return int(arr.nbytes)


# ---------------------------------------------------------------------------
# row placement (dense [V, ...] class-sharded arrays)
# ---------------------------------------------------------------------------


def place_row_sharded(arr, mesh, axis_name: str,
                      plan: ReshardPlan = None, *,
                      max_stage_rows: int = 1 << 16):
    """Place a global host array, row-sharded over ``mesh``'s
    ``axis_name``, executing the plan's placement strategy.

    Aligned (or no) plan: one gather-free ``device_put`` — the runtime
    slices each device's contiguous row block straight out of the host
    buffer. Unaligned: stage one destination shard at a time (copied in
    ``max_stage_rows`` chunks into a reusable bounded buffer) and
    assemble the global array from the per-device shards.
    """
    import jax
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    host = _host(arr)
    spec = P(axis_name, *(None,) * (host.ndim - 1))
    sharding = NamedSharding(mesh, spec)
    if plan is None or plan.aligned:
        return jax.device_put(host, sharding)
    n_dst = plan.dst.n_model
    if host.shape[0] % n_dst != 0:
        raise ReshardError(
            f"cannot place {host.shape} rows over {n_dst} shards")
    v_loc = host.shape[0] // n_dst
    devices = list(mesh.devices.flat)
    stage = np.empty((v_loc,) + host.shape[1:], host.dtype)
    shards = []
    for q in range(n_dst):
        for r0 in range(0, v_loc, max_stage_rows):
            r1 = min(r0 + max_stage_rows, v_loc)
            stage[r0:r1] = host[q * v_loc + r0:q * v_loc + r1]
        shards.append(jax.device_put(stage.copy(), devices[q]))
    return jax.make_array_from_single_device_arrays(
        host.shape, sharding, shards)


# ---------------------------------------------------------------------------
# KNN graph CSR re-pack (exact)
# ---------------------------------------------------------------------------


def decompress_graph(offsets, neighbors, ranks) -> np.ndarray:
    """Invert `knn_graph.compress_graph`: per-shard CSRs back to the
    global ``[N, k]`` neighbor table (pad columns -1). Exact — ``ranks``
    stores each entry's original column, and the shards partition the
    entries — so compress(decompress(aux), m) re-packs losslessly."""
    offsets = _host(offsets)
    neighbors = _host(neighbors)
    ranks = _host(ranks)
    n_shards, n1 = offsets.shape
    n = n1 - 1
    n_loc = n // n_shards
    k = int(ranks.max()) + 1 if ranks.size else 1
    g = np.full((n, k), -1, np.int64)
    for p in range(n_shards):
        off = offsets[p].astype(np.int64)
        nnz = int(off[-1])
        rows = np.repeat(np.arange(n), np.diff(off))
        g[rows, ranks[p, :nnz]] = neighbors[p, :nnz].astype(np.int64) \
            + p * n_loc
    return g


def repack_knn_aux(aux, n_dst: int):
    """Re-pack a (offsets, neighbors, ranks) CSR triple written for one
    ring size onto ``n_dst`` shards, preserving the graph exactly."""
    from repro.core import knn_graph as kg
    g = decompress_graph(*aux)
    if (g < 0).any():
        # ragged rows (shorter original neighbor lists): compress ignores
        # nothing, so pad entries must not exist — rebuild densely by
        # dropping pad columns per row via a masked re-pack
        raise ReshardError("KNN graph CSR has holes; cannot re-pack")
    cg = kg.compress_graph(g, n_dst)
    return (cg.offsets, cg.neighbors, cg.ranks)


# ---------------------------------------------------------------------------
# LSH table re-pack (exact)
# ---------------------------------------------------------------------------


def lsh_bucket_map(offsets, classes) -> np.ndarray:
    """Invert the per-shard bucket CSRs of `build_sharded_lsh_tables` to
    the global class->bucket assignment ``[R, V]`` (bucket values are
    mesh-independent — a function of the replicated planes and W rows)."""
    offsets = _host(offsets)
    classes = _host(classes)
    n_shards, n_tables, v_loc = classes.shape
    n_buckets = offsets.shape[2] - 1
    bucket = np.empty((n_tables, n_shards * v_loc), np.int64)
    for p in range(n_shards):
        for r in range(n_tables):
            per_pos = np.repeat(np.arange(n_buckets),
                                np.diff(offsets[p, r].astype(np.int64)))
            bucket[r, p * v_loc + classes[p, r].astype(np.int64)] = per_pos
    return bucket


def repack_lsh_aux(aux, n_dst: int):
    """Re-pack (planes, offsets, classes) onto ``n_dst`` shards. Planes
    are replicated and kept; per-shard CSRs are rebuilt with the same
    stable-sort semantics as `build_sharded_lsh_tables`, so the result is
    exactly what the builder would emit for the SAME bucket assignment —
    mid-refresh staleness included."""
    planes, offsets, classes = aux
    bucket = lsh_bucket_map(offsets, classes)
    n_tables, v = bucket.shape
    n_buckets = _host(offsets).shape[2] - 1
    if v % n_dst != 0:
        raise ReshardError(f"V={v} not divisible by dst shards={n_dst}")
    v_loc = v // n_dst
    new_off = np.zeros((n_dst, n_tables, n_buckets + 1), np.int32)
    new_cls = np.zeros((n_dst, n_tables, v_loc), np.int32)
    for q in range(n_dst):
        for r in range(n_tables):
            bloc = bucket[r, q * v_loc:(q + 1) * v_loc]
            order = np.argsort(bloc, kind="stable").astype(np.int32)
            new_cls[q, r] = order
            new_off[q, r] = np.searchsorted(
                bloc[order], np.arange(n_buckets + 1)).astype(np.int32)
    return (planes, new_off, new_cls)


# ---------------------------------------------------------------------------
# sketch-head bucket transfer (lossy, class-mean)
# ---------------------------------------------------------------------------


def rebucket_sketch(w, h_old, h_new, n_buckets_new: int) -> np.ndarray:
    """Transfer ``[R, B_old, D]`` bucket weights onto a new hash table:
    each new bucket's weight is the mean of its member classes' OLD bucket
    weights (empty new buckets stay zero). Deterministic, so params and
    optimizer moments map identically."""
    w = _host(w).astype(np.float32)
    h_old = _host(h_old).astype(np.int64)
    h_new = _host(h_new).astype(np.int64)
    n_rep, _, d = w.shape
    out = np.zeros((n_rep, n_buckets_new, d), np.float32)
    counts = np.zeros((n_rep, n_buckets_new), np.int64)
    for r in range(n_rep):
        np.add.at(out[r], h_new[r], w[r][h_old[r]])
        np.add.at(counts[r], h_new[r], 1)
    out /= np.maximum(counts, 1)[..., None]
    return out


# ---------------------------------------------------------------------------
# DGC error feedback (mass-preserving)
# ---------------------------------------------------------------------------


def redistribute_dgc(tree, n_dst: int):
    """Redistribute ``[n_src, ...]``-leading error-feedback leaves over
    ``n_dst`` workers: every new worker gets total/n_dst, preserving the
    total pending residual each parameter will eventually receive."""
    import jax

    def one(a):
        h = _host(a)
        total = h.sum(axis=0, dtype=h.dtype)
        return np.broadcast_to(total / n_dst, (n_dst,) + total.shape).copy()
    return jax.tree.map(one, tree)


# ---------------------------------------------------------------------------
# zoo vocab padding
# ---------------------------------------------------------------------------


def resize_vocab_rows(arr, v_src: int, v_dst: int, *, n_real: int):
    """Slice / zero-pad a vocab-leading array between two padded vocab
    sizes. Only pad rows (>= ``n_real``) may be created or dropped."""
    a = _host(arr)
    if a.shape[0] != v_src:
        return a
    if v_src == v_dst:
        return a
    if min(v_src, v_dst) < n_real:
        raise ReshardError(
            f"vocab resize {v_src}->{v_dst} would drop real rows "
            f"(real vocab {n_real})")
    if v_dst < v_src:
        return np.ascontiguousarray(a[:v_dst])
    pad = np.zeros((v_dst - v_src,) + a.shape[1:], a.dtype)
    return np.concatenate([a, pad], axis=0)
