"""repro.elastic — mesh-shape-agnostic checkpoint resharding.

Restore any checkpoint onto any mesh shape: `MeshGeometry` records the
source geometry in the checkpoint meta, `plan_reshard` interval-intersects
the src/dst row partitions into a `ReshardPlan` (gather-free when aligned,
host-staged chunked otherwise), and the transforms in
`repro.elastic.reshard` re-pack everything the ring size was baked into —
per-shard KNN/LSH CSRs (exactly), sketch bucket weights (re-hashed with
the same universal family), DGC worker residuals (mass-preserving), and
zoo vocab padding. `reshard_paper_snapshot` / `reshard_zoo_snapshot`
drive a whole trainer snapshot through the `SoftmaxHead.reshard_state`
seam and return an itemized "reshard" comm ledger.

Entry points: `Experiment.fit(resume="reshard")`,
`Experiment.restore(reshard=True)`, the launcher's `--resume-reshard`,
and `repro.resilience.elastic_kill_and_recover`. See docs/resilience.md.
"""
from repro.elastic.apply import (analytic_reshard_ledger,
                                 reshard_paper_snapshot,
                                 reshard_zoo_snapshot)
from repro.elastic.plan import (MeshGeometry, ReshardError, ReshardPlan,
                                RowTransfer, geometry_from_meta,
                                plan_reshard, validate_geometry)
from repro.elastic.reshard import (decompress_graph, lsh_bucket_map,
                                   place_row_sharded, rebucket_sketch,
                                   redistribute_dgc, repack_knn_aux,
                                   repack_lsh_aux, resize_vocab_rows)

__all__ = [
    "MeshGeometry", "ReshardError", "ReshardPlan", "RowTransfer",
    "geometry_from_meta", "plan_reshard", "validate_geometry",
    "reshard_paper_snapshot", "reshard_zoo_snapshot",
    "analytic_reshard_ledger", "place_row_sharded", "decompress_graph",
    "repack_knn_aux", "lsh_bucket_map", "repack_lsh_aux",
    "rebucket_sketch", "redistribute_dgc", "resize_vocab_rows",
]
