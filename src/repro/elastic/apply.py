"""Snapshot-level reshard drivers: one per trainer snapshot layout.

`reshard_paper_snapshot` / `reshard_zoo_snapshot` take the host pytree a
trainer's `_snapshot()` template restored from disk, the head, and the
src/dst geometries, and return `(tree, needs_refresh, CommLedger)` — the
tree rewritten for the dst mesh, whether the trainer must run the head's
own refresh path afterwards (the fallback for aux with no exact re-pack
rule), and an itemized "reshard"-kind comm ledger of the bytes a real
multi-host reshard would move (gated in BENCH_table8.json).

The head-specific work happens through the `SoftmaxHead.reshard_state` /
`reshard_params_like` seam (repro.api.heads), so a new head plugs into
elastic restores the same way it plugs into training.
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.elastic.plan import (MeshGeometry, ReshardPlan, plan_reshard,
                                validate_geometry)
from repro.elastic.reshard import leaf_bytes, redistribute_dgc, \
    resize_vocab_rows
from repro.telemetry.ledger import CommLedger


def _tree_bytes(tree) -> int:
    return sum(leaf_bytes(a) for a in jax.tree.leaves(tree))


def _aux_changed(old_aux, new_aux) -> bool:
    old_leaves = jax.tree.leaves(old_aux)
    new_leaves = jax.tree.leaves(new_aux)
    return any(a is not b for a, b in zip(old_leaves, new_leaves)) \
        or len(old_leaves) != len(new_leaves)


def _account_head(led: CommLedger, head, old_head_tree, new_head_tree,
                  plan: ReshardPlan) -> None:
    """Itemize the head's reshard traffic: dense [V, D] params move only
    the plan's displaced rows; re-bucketed sketch params and re-packed aux
    are re-laid-out wholesale, so their full payload counts."""
    old_p, new_p = old_head_tree["params"], new_head_tree["params"]
    if jax.tree.leaves(old_p):
        if head.params_are_class_weights:
            row = leaf_bytes(old_p) // max(1, plan.n_rows)
            led.add("reshard", "head.params", plan.bytes_moved(row))
        elif _aux_changed(old_p, new_p):
            led.add("reshard", "head.params", _tree_bytes(new_p))
    if _aux_changed(old_head_tree["aux"], new_head_tree["aux"]):
        led.add("reshard", "head.aux", _tree_bytes(new_head_tree["aux"]))


def _reshard_moments(opt, head, src, dst, plan, led: CommLedger,
                     *, model_leaf_fn=None):
    """Optimizer moments mirror (trunk params, head params): trunk moments
    are replicated (paper) or resized like the model (zoo, via
    ``model_leaf_fn``); head-param moments get the head's own
    params transform."""
    def fix(moment):
        if moment is None:
            return None
        trunk_m, hp_m = moment
        if model_leaf_fn is not None:
            trunk_m = jax.tree.map(model_leaf_fn, trunk_m)
        if jax.tree.leaves(hp_m):
            new_hp = jax.tree.map(
                lambda a: head.reshard_params_like(a, src, dst), hp_m)
            if head.params_are_class_weights:
                row = _tree_bytes(hp_m) // max(1, plan.n_rows)
                led.add("reshard", "opt.moments", plan.bytes_moved(row))
            elif _aux_changed(hp_m, new_hp):
                led.add("reshard", "opt.moments", _tree_bytes(new_hp))
            hp_m = new_hp
        return (trunk_m, hp_m)

    return type(opt)(step=opt.step, mu=fix(opt.mu),
                     nu=fix(getattr(opt, "nu", None)))


def reshard_paper_snapshot(tree: dict, head, src: MeshGeometry,
                           dst: MeshGeometry
                           ) -> Tuple[dict, bool, CommLedger]:
    """Rewrite a paper-trainer snapshot (fe / head / opt / dgc / extra)
    for the dst ring. FE params are replicated (untouched); class-weight
    rows are global in the snapshot, so only the head's aux, sketch
    buckets, moment mirrors, and DGC worker buffers change layout."""
    validate_geometry(src, dst, reshard=True)
    plan = plan_reshard(src, dst)
    led = CommLedger()
    out = dict(tree)
    new_head, needs_refresh = head.reshard_state(tree["head"], src, dst)
    _account_head(led, head, tree["head"], new_head, plan)
    out["head"] = new_head
    out["opt"] = _reshard_moments(tree["opt"], head, src, dst, plan, led)
    if "dgc" in tree:
        out["dgc"] = redistribute_dgc(tree["dgc"], dst.n_model)
        led.add("reshard", "dgc.error_feedback", _tree_bytes(out["dgc"]))
    return out, needs_refresh, led


def reshard_zoo_snapshot(tree: dict, head, model_cfg, src: MeshGeometry,
                         dst: MeshGeometry, *, padded_vocab_src: int
                         ) -> Tuple[dict, bool, CommLedger]:
    """Rewrite a zoo (GSPMD) snapshot (model / head / opt / extra) for a
    dst vocab sharding: vocab-leading model leaves are re-padded when the
    dst ring implies a different padded vocab, and the head/moments go
    through the same seam as the paper path."""
    validate_geometry(src, dst, reshard=True)
    v_dst = model_cfg.vocab_size
    n_real = int(model_cfg.real_vocab_size or model_cfg.vocab_size)
    plan = plan_reshard(src, dst, v_dst)
    led = CommLedger()

    def fix_model_leaf(a):
        if padded_vocab_src != v_dst \
                and getattr(a, "shape", ()) \
                and a.shape[0] == padded_vocab_src:
            out = resize_vocab_rows(a, padded_vocab_src, v_dst,
                                    n_real=n_real)
            led.add("reshard", "model.vocab_pad",
                    abs(leaf_bytes(out) - leaf_bytes(a)))
            return out
        return a

    out = dict(tree)
    out["model"] = jax.tree.map(fix_model_leaf, tree["model"])
    new_head, needs_refresh = head.reshard_state(tree["head"], src, dst)
    _account_head(led, head, tree["head"], new_head, plan)
    out["head"] = new_head
    out["opt"] = _reshard_moments(tree["opt"], head, src, dst, plan, led,
                                  model_leaf_fn=fix_model_leaf)
    return out, needs_refresh, led


def analytic_reshard_ledger(src: MeshGeometry, dst: MeshGeometry, *,
                            row_bytes: int,
                            n_moment_trees: int = 1) -> CommLedger:
    """The dense-head reshard traffic a (src -> dst) move implies, without
    materializing any state — the benchmark-side twin of the restore
    path's measured ledger (`benchmarks/table8_end2end.py`)."""
    plan = plan_reshard(src, dst)
    led = CommLedger()
    led.add("reshard", "head.params", plan.bytes_moved(row_bytes))
    if n_moment_trees:
        led.add("reshard", "opt.moments",
                plan.bytes_moved(row_bytes) * n_moment_trees)
    return led
