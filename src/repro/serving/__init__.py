"""``repro.serving`` — the batched, cached, trace-driven serving tier.

The paper's system exists to put the 100M-class head in front of real
retail traffic; this package is the "millions of users, heavy traffic"
leg of that made concrete:

  * ``Coalescer`` — packs async-submitted single queries into fixed-shape
    micro-batches (power-of-two bucketed padding bounds jit recompiles; a
    max-wait flush deadline bounds tail latency).
  * ``ServingEngine`` — one ``submit()/poll()/drain()`` API over the
    per-head batched top-k / greedy retrieval steps, with per-request
    timing, donated input buffers, and an optional score cache. Usable
    from both the paper (hybrid) and zoo (GSPMD) systems via
    ``ServingEngine.for_experiment``.
  * ``ScoreCache`` — LRU hot-query score cache (embedding-keyed exact
    match, optional cosine-threshold hits) for head-of-distribution
    traffic, invalidated when the served weights refresh.
  * ``IVFIndex`` — sublinear top-k: a k-means coarse quantizer fit
    distributed over the class shards; serve probes ``nprobe`` centroids
    and reranks only their member rows (``ServingEngine.for_experiment(...,
    index="ivf")``), refit on the same ``weights_version`` seam.
  * ``repro.serving.trace`` — synthetic bursty/Zipfian trace generator +
    ``VirtualClock`` for load replay (``benchmarks/serve_replay.py``).

See docs/serving.md for the lifecycle, the knobs, and the BENCH schema.
"""
from repro.serving.cache import ScoreCache
from repro.serving.coalescer import Coalescer, MicroBatch, Request, bucket_for
from repro.serving.engine import ServingEngine, latency_stats, replay_trace
from repro.serving.index import IVFIndex
from repro.serving.trace import (TraceConfig, VirtualClock, generate_trace,
                                 make_query_pool)

__all__ = [
    "Coalescer", "IVFIndex", "MicroBatch", "Request", "ScoreCache",
    "ServingEngine", "TraceConfig", "VirtualClock", "bucket_for",
    "generate_trace", "latency_stats", "make_query_pool", "replay_trace",
]
