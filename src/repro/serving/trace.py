"""Synthetic serving traces: bursty arrivals x Zipfian query mix.

The load-replay benchmark needs traffic shaped like production, not like a
fixed-size eval batch. Two generators compose here:

  * **Arrival process** — a two-state Markov-modulated Poisson process:
    exponentially-distributed OFF periods at ``base_rate`` qps alternate
    with ON bursts at ``base_rate + burst_rate`` qps (the on/off burst
    model used for e-commerce / cluster traffic; cf. the workload docs in
    the AIOpsLab file set under /root/related/). Inter-arrivals within a
    state are exponential.
  * **Query mix** — query ids drawn Zipf(``zipf_s``) from a finite pool of
    ``pool`` distinct queries, so a skewed head of hot queries repeats —
    exactly the structure the engine's score cache exploits.

Everything is seeded and pure numpy: the same ``TraceConfig`` always
yields the same trace, so cached-vs-uncached replay runs see identical
traffic.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class TraceConfig:
    duration: float = 2.0          # virtual seconds of traffic
    base_rate: float = 100.0       # qps in the OFF (quiet) state
    burst_rate: float = 400.0      # ADDITIONAL qps while a burst is on
    mean_on: float = 0.10          # mean burst length (s, exponential)
    mean_off: float = 0.30         # mean quiet gap (s, exponential)
    zipf_s: float = 1.1            # query-popularity exponent (>0)
    pool: int = 256                # distinct queries in the mix
    seed: int = 0

    @property
    def expected_rate(self) -> float:
        """Long-run mean arrival rate (qps) of the on/off process."""
        on, off = self.mean_on, self.mean_off
        if on + off <= 0:
            return self.base_rate
        duty = on / (on + off)
        return self.base_rate + duty * self.burst_rate


def zipf_probs(pool: int, s: float) -> np.ndarray:
    """Normalized Zipf pmf over ranks 0..pool-1 (rank 0 hottest)."""
    p = 1.0 / np.arange(1, pool + 1, dtype=np.float64) ** s
    return p / p.sum()


def generate_trace(cfg: TraceConfig) -> Tuple[np.ndarray, np.ndarray]:
    """-> (times [n] float64 ascending, qids [n] int32 in [0, pool))."""
    rng = np.random.default_rng(cfg.seed)
    times = []
    t, t_state_end, on = 0.0, 0.0, True  # first state drawn below
    on = bool(rng.integers(0, 2))
    t_state_end = t + rng.exponential(cfg.mean_on if on else cfg.mean_off)
    while t < cfg.duration:
        rate = cfg.base_rate + (cfg.burst_rate if on else 0.0)
        if rate <= 0:
            t = t_state_end
        else:
            dt = rng.exponential(1.0 / rate)
            if t + dt >= t_state_end:
                t = t_state_end          # state flips before next arrival
            else:
                t += dt
                if t < cfg.duration:
                    times.append(t)
                continue
        on = not on
        t_state_end = t + rng.exponential(cfg.mean_on if on else cfg.mean_off)
    times = np.asarray(times, np.float64)
    qids = rng.choice(cfg.pool, size=times.shape[0],
                      p=zipf_probs(cfg.pool, cfg.zipf_s)).astype(np.int32)
    return times, qids


def make_query_pool(n_classes: int, d: int, pool: int, *, seed: int = 0,
                    noise: float = 0.2) -> np.ndarray:
    """[pool, d] float32 query embeddings: noisy samples of the synthetic
    SKU prototypes (``repro.data.synthetic``), so replayed queries look
    like the features the trained head actually retrieves against."""
    from repro.data.synthetic import ClassificationStream
    stream = ClassificationStream(n_classes, d, seed=seed)
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, n_classes, size=pool)
    protos = np.asarray(stream.prototypes)[labels]
    q = protos + noise * rng.standard_normal((pool, d))
    return q.astype(np.float32)


class VirtualClock:
    """Monotone replay clock: ``now()`` plugs into the engine, the replay
    loop advances it to each trace arrival time."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def now(self) -> float:
        return self.t

    __call__ = now

    def advance(self, dt: float):
        if dt < 0:
            raise ValueError(f"cannot rewind the clock (dt={dt})")
        self.t += dt

    def advance_to(self, t: float):
        self.t = max(self.t, float(t))
