"""LRU hot-query score cache for head-of-distribution serving traffic.

Retail query streams are heavily skewed (the same motivation the dynamic
class-selection and CMS-softmax lines exploit at train time — PAPERS.md):
a small head of distinct queries accounts for most requests. Caching their
retrieval results turns that skew directly into served QPS.

Keys are the query EMBEDDING bytes (optionally quantized to ``quantize``
decimals so float jitter from an upstream encoder still matches); an
optional ``cosine_threshold`` additionally accepts near-duplicate vector
queries — a linear scan over the cached (normalized) keys, intended for
the few-thousand-entry caches a head-of-distribution working set needs.

The cache stores whatever the engine computed for the query — ``(ids,
scores)`` for top-k retrieval, a scalar class id for greedy — and must be
dropped when the served weights move: ``invalidate()`` is the hook the
``ServingEngine`` wires to its weight-version check (and that a trainer's
head-refresh cadence can call directly).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Optional, Tuple

import numpy as np


class _Entry:
    __slots__ = ("value", "unit")

    def __init__(self, value: Any, unit: Optional[np.ndarray]):
        self.value = value
        self.unit = unit            # normalized flat query (cosine probing)


class ScoreCache:
    def __init__(self, capacity: int = 1024, *,
                 cosine_threshold: Optional[float] = None,
                 quantize: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if cosine_threshold is not None and not 0.0 < cosine_threshold <= 1.0:
            raise ValueError(
                f"cosine_threshold must be in (0, 1], got {cosine_threshold}")
        self.capacity = capacity
        self.cosine_threshold = cosine_threshold
        self.quantize = quantize
        self._od: "OrderedDict[Tuple, _Entry]" = OrderedDict()
        self.hits = 0
        self.exact_hits = 0
        self.cosine_hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._od)

    def _key(self, query: np.ndarray) -> Tuple:
        q = np.asarray(query, np.float32)
        if self.quantize is not None:
            q = np.round(q, self.quantize)
        return (q.shape, q.tobytes())

    @staticmethod
    def _unit(query: np.ndarray) -> Optional[np.ndarray]:
        q = np.asarray(query, np.float32).reshape(-1)
        n = float(np.linalg.norm(q))
        return q / n if n > 0 else None

    def get(self, query: np.ndarray):
        """-> (value, kind) on a hit (kind: "exact" | "cosine"), else None.
        A hit refreshes the entry's LRU position."""
        key = self._key(query)
        entry = self._od.get(key)
        if entry is not None:
            self._od.move_to_end(key)
            self.hits += 1
            self.exact_hits += 1
            return entry.value, "exact"
        if self.cosine_threshold is not None and self._od:
            unit = self._unit(query)
            if unit is not None:
                best_key, best_cos = None, -1.0
                for k, e in self._od.items():
                    if e.unit is None or e.unit.shape != unit.shape:
                        continue
                    c = float(e.unit @ unit)
                    if c > best_cos:
                        best_key, best_cos = k, c
                if best_key is not None and best_cos >= self.cosine_threshold:
                    self._od.move_to_end(best_key)
                    self.hits += 1
                    self.cosine_hits += 1
                    return self._od[best_key].value, "cosine"
        self.misses += 1
        return None

    def put(self, query: np.ndarray, value: Any):
        key = self._key(query)
        unit = (self._unit(query) if self.cosine_threshold is not None
                else None)
        self._od[key] = _Entry(value, unit)
        self._od.move_to_end(key)
        while len(self._od) > self.capacity:
            self._od.popitem(last=False)     # evict least-recently used

    def invalidate(self):
        """Drop every entry — the served weights changed, cached scores are
        stale. Counters survive (hit-rate is a per-run statistic)."""
        if self._od:
            self.invalidations += 1
        self._od.clear()

    clear = invalidate

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def stats(self) -> dict:
        return {
            "size": len(self._od), "capacity": self.capacity,
            "hits": self.hits, "exact_hits": self.exact_hits,
            "cosine_hits": self.cosine_hits, "misses": self.misses,
            "hit_rate": self.hit_rate, "invalidations": self.invalidations,
        }
