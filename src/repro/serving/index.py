"""``repro.serving.index`` — IVF coarse-quantizer over the class shards.

Serving cost was linear in the class count V: every query scored the full
[V/n, D] shard on every device — the one hot path still paying the cost the
paper's whole training system avoids (§3.2's KNN softmax trains against a
small active set; Zhang'18 / Vijayanarasimhan'16 in PAPERS.md show a small
active set preserves top-k quality). ``IVFIndex`` applies the same idea at
serve time:

  * **fit** — spherical k-means (Lloyd on L2-normalized rows, assignment by
    max dot product, centroids renormalized each iteration) runs as ONE
    shard_map over the model ring: each device clusters its own [V/n, D]
    shard, so the index is trained distributed and sharded exactly like the
    head it indexes. Initialization is a deterministic stride over the valid
    rows (no RNG — refits are reproducible). Member lists are then packed
    host-side into a fixed [P, C, cap] int32 tensor with a CAPACITY-BALANCED
    assignment (``cap = ceil(1.25 * V_loc/C)``; rows greedily take their
    best-scoring cluster with space left, most-confident rows first) — the
    same device_get/pack/device_put round-trip as the KNN graph's
    ``compress_graph``, but with a deterministic rerank cost: probing
    ``nprobe`` clusters scans exactly ``nprobe * cap`` rows, with no
    straggler cluster inflating every query. The 25% slack keeps natural
    clusters together (a hard ``cap = V_loc/C`` exiles boundary rows to
    their 2nd-best cell, costing ~4 recall points at default nprobe). No
    row is ever dropped, so ``nprobe == n_clusters`` returns the exact
    scan's ids bit-for-bit (scores agree to float accumulation order).
  * **probe + rerank** — at serve time each shard ranks its centroids
    against the (normalized) query, takes the top ``nprobe``, and reranks
    only their member rows (``core.sharded_softmax.serve_topk_ivf_local``;
    pallas backend = the fused ``ops.ivf_rerank`` gather+top-k kernel), then
    the existing one-ring all-gather merges shard winners. Retrieval cost
    scales with nprobe * cap, not V.
  * **lifecycle** — the index snapshots the experiment's ``weights_version``
    at fit time; the serving engine refits whenever the version moves (the
    same probe that invalidates the score cache — one seam for "the served
    weights changed", covering train steps, head refreshes, and checkpoint
    restores). ``state_to_save``/``state_from_restore`` mirror the
    ``SoftmaxHead`` checkpoint contract so a resumed server reinstalls the
    index instead of refitting (tests/test_ivf_index.py round-trips it
    bitwise through ``repro.checkpoint``).

Defaults: C = round(sqrt(V_loc)) clusters per shard, nprobe = max(2, C/32)
(a probe scans a whole balanced cluster, so two clusters already cover the
confusable neighborhood of a query even when it sits on a cell boundary;
the bench's recall-vs-latency table in docs/serving.md is the tuning
guide).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np


def default_n_clusters(v_loc: int) -> int:
    """sqrt(V_loc) clusters per shard — the classic IVF balance point
    between probe cost (C) and rerank cost (V_loc / C per cluster)."""
    return max(1, min(v_loc, int(round(v_loc ** 0.5))))


def default_nprobe(n_clusters: int) -> int:
    """At least two probes — a query near a cell boundary has its true
    neighborhood split across two cells, and one probe caps recall ~0.91
    no matter how clusterable the weights are (measured in the bench);
    past that, FAISS-style C/32 scales with the cell count."""
    return max(2, n_clusters // 32)


def _axes_tuple(axes) -> tuple:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _exp_head_geometry(exp):
    """(w [V, D] on-mesh, mesh, model axes, n_valid) of an experiment's
    retrieval matrix. Works for BOTH systems; sketch heads (mach/csoft)
    have no [V, D] class matrix to index and are refused loudly."""
    if hasattr(exp, "trainer"):                            # paper system
        from repro.train.hybrid import AXIS
        head = exp.trainer.head
        if not head.params_are_class_weights:
            raise NotImplementedError(
                f"the IVF index quantizes the [V, D] class matrix, which "
                f"the {head.name!r} head does not train; use a W-head "
                f"(full/knn/selective/sampled)")
        return exp.state.head_params, exp.mesh, AXIS, head.n_valid
    if hasattr(exp, "par"):                                # zoo system
        from repro.models import lm
        head = exp.head
        if not head.params_are_class_weights:
            raise NotImplementedError(
                f"the IVF index quantizes the [V, D] class matrix, which "
                f"the {head.name!r} head does not train; use a W-head "
                f"(full/knn/selective/sampled)")
        return (lm.head_weight(exp.params, exp.model_cfg), exp.mesh,
                exp._maxis, head.n_valid)
    raise TypeError(f"not a paper/zoo Experiment: {type(exp).__name__}")


@dataclasses.dataclass
class IVFIndex:
    """A fitted coarse quantizer over one experiment's class shards.

    centroids [P, C, D] fp32 and members [P, C, cap] int32 are device
    arrays sharded along the model axes (leading dim P = shard count);
    counts [P, C] stays a host numpy array (stats only)."""

    centroids: Any
    members: Any
    counts: np.ndarray
    n_clusters: int
    cap: int
    nprobe: int
    iters: int
    model_axis: Any
    version: Tuple[int, ...]

    def resolve_nprobe(self, nprobe: Optional[int] = None) -> int:
        """Effective probe width: caller override, else the fit-time
        default, clamped to the cluster count."""
        return max(1, min(int(nprobe or self.nprobe), self.n_clusters))

    # -- fit ----------------------------------------------------------------

    @classmethod
    def fit(cls, exp, *, n_clusters: int = 0, nprobe: int = 0,
            iters: int = 8) -> "IVFIndex":
        """Fit over the experiment's CURRENT class shards (see module
        docstring). Deterministic: no RNG anywhere in the fit."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.core.sharded_softmax import (_flat_axis_index, _normalize,
                                                _shard_limit)

        w, mesh, axes, n_valid = _exp_head_geometry(exp)
        v, d = w.shape
        n_shards = int(np.prod([mesh.shape[a] for a in _axes_tuple(axes)]))
        v_loc = v // n_shards
        c = min(v_loc, n_clusters or default_n_clusters(v_loc))

        def body(w_loc):
            v_start = _flat_axis_index(axes) * v_loc
            limit = _shard_limit(v_start, v_loc, n_valid)
            valid = jnp.arange(v_loc) < limit
            wn = _normalize(w_loc.astype(jnp.float32))
            wn = jnp.where(valid[:, None], wn, 0.0)
            # deterministic strided init over the valid rows
            idx0 = jnp.clip((jnp.arange(c) * jnp.maximum(limit, 1)) // c,
                            0, v_loc - 1)
            cent = _normalize(wn[idx0])

            def lloyd(cent, _):
                assign = jnp.argmax(wn @ cent.T, axis=1)
                oh = jax.nn.one_hot(assign, c, dtype=jnp.float32)
                oh = oh * valid[:, None].astype(jnp.float32)
                cnt = jnp.sum(oh, axis=0)
                # empty clusters keep their previous centroid
                cent = jnp.where(cnt[:, None] > 0, _normalize(oh.T @ wn),
                                 cent)
                return cent, None

            cent, _ = jax.lax.scan(lloyd, cent, None, length=iters)
            return cent[None]

        with jax.set_mesh(mesh):
            cent = jax.device_get(jax.jit(jax.shard_map(
                body, mesh=mesh, in_specs=(P(axes, None),),
                out_specs=P(axes, None, None),
                check_vma=False))(w))
            w_host = np.asarray(jax.device_get(w), np.float32)

        # host-side member packing (the compress_graph idiom), capacity-
        # balanced with 25% slack: cap = ceil(1.25 * V_loc/C); rows claim
        # their best-scoring cluster that still has space, most-confident
        # rows first, so the member tensor is dense and the per-probe
        # rerank cost is exactly cap rows. Deterministic (stable sorts,
        # no RNG).
        p = cent.shape[0]
        cap = max(1, min(v_loc, -(-(5 * v_loc) // (4 * c))))
        nv = int(n_valid) if n_valid else v
        counts = np.zeros((p, c), np.int32)
        members = np.full((p, c, cap), -1, np.int32)
        for s in range(p):
            limit = min(max(nv - s * v_loc, 0), v_loc)
            if limit == 0:
                continue
            ws = w_host[s * v_loc:s * v_loc + limit]
            wn = ws / np.maximum(
                np.linalg.norm(ws, axis=1, keepdims=True), 1e-12)
            scores = wn @ cent[s].T                       # [limit, C]
            pref = np.argsort(-scores, axis=1, kind="stable")
            order = np.argsort(-scores.max(axis=1), kind="stable")
            fill = counts[s]
            for r in order:
                for ci in pref[r]:
                    if fill[ci] < cap:
                        members[s, ci, fill[ci]] = r
                        fill[ci] += 1
                        break
        sh = NamedSharding(mesh, P(axes, None, None))
        with jax.set_mesh(mesh):
            cent_dev = jax.device_put(jnp.asarray(cent, jnp.float32), sh)
            members_dev = jax.device_put(jnp.asarray(members), sh)
        return cls(centroids=cent_dev, members=members_dev, counts=counts,
                   n_clusters=c, cap=cap,
                   nprobe=min(c, nprobe or default_nprobe(c)),
                   iters=iters, model_axis=axes,
                   version=tuple(exp.weights_version))

    # -- checkpoint contract (mirrors SoftmaxHead state_to_save/restore) ----

    def state_to_save(self) -> dict:
        """Checkpoint pytree — pass to ``repro.checkpoint.save`` (or embed
        in a larger snapshot) so a resumed server skips the refit."""
        import jax.numpy as jnp
        return {
            "centroids": self.centroids,
            "members": self.members,
            "counts": jnp.asarray(self.counts),
            "meta": {
                "n_clusters": jnp.asarray(self.n_clusters, jnp.int32),
                "cap": jnp.asarray(self.cap, jnp.int32),
                "nprobe": jnp.asarray(self.nprobe, jnp.int32),
                "iters": jnp.asarray(self.iters, jnp.int32),
                "version": jnp.asarray(self.version, jnp.int32),
            },
        }

    @classmethod
    def state_from_restore(cls, tree: dict, mesh, *,
                           model_axis) -> "IVFIndex":
        """Re-place a restored snapshot on the serving mesh (device_put with
        the index's own specs, like the heads do)."""
        import jax
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        sh = NamedSharding(mesh, P(model_axis, None, None))
        with jax.set_mesh(mesh):
            cent = jax.device_put(np.asarray(tree["centroids"], np.float32),
                                  sh)
            members = jax.device_put(np.asarray(tree["members"], np.int32),
                                     sh)
        meta = tree["meta"]
        return cls(centroids=cent, members=members,
                   counts=np.asarray(tree["counts"], np.int32),
                   n_clusters=int(np.asarray(meta["n_clusters"])),
                   cap=int(np.asarray(meta["cap"])),
                   nprobe=int(np.asarray(meta["nprobe"])),
                   iters=int(np.asarray(meta["iters"])),
                   model_axis=model_axis,
                   version=tuple(int(x) for x in np.asarray(meta["version"])))
