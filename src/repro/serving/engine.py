"""``ServingEngine`` — batched multi-query retrieval behind submit/drain.

One engine wraps one served model (a paper-system or zoo ``Experiment``)
and turns the per-head batched top-k / greedy steps into a serving loop:

    engine = ServingEngine.for_experiment(exp, top_k=5,
                                          cache=ScoreCache(1024))
    rid = engine.submit(query)          # single [D] embedding (or image)
    done = engine.poll()                # run any due micro-batches
    done += engine.drain()              # flush everything (shutdown)

* ``submit`` first consults the optional ``ScoreCache`` (invalidated
  automatically when the served weights' version moves — a weight refresh
  must not serve stale scores); on a miss the query joins the
  ``Coalescer`` queue.
* ``poll``/``drain`` cut due micro-batches (power-of-two padded, so jit
  compiles at most one step per bucket; the padded input buffer is
  donated), execute them through the experiment's batched serve step, and
  deliver completed ``Request``s with per-request timestamps.
* Service is modeled as a single serial executor: a batch starts at
  ``max(flush time, previous batch's completion)`` and its measured
  wall-clock compute is charged from there — with the real clock this is
  just what happens; under a replay ``VirtualClock`` it makes queueing
  delay during bursts show up in p99 exactly as a busy server would.

The engine itself is transport-agnostic: it only needs a ``step_fn`` that
scores a padded query batch. ``for_experiment`` builds that step for the
paper (hybrid) and zoo (GSPMD) systems.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, List, Optional, Sequence

import numpy as np

from repro.serving.cache import ScoreCache
from repro.serving.coalescer import Coalescer, Request, bucket_for
from repro.telemetry import NULL_TRACER


def latency_stats(requests: Sequence[Request]) -> dict:
    """p50/p95/p99/mean/max request latency (ms) over completed requests."""
    if not requests:
        return {"n": 0, "p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                "mean_ms": 0.0, "max_ms": 0.0}
    lat = np.asarray([r.latency for r in requests], np.float64) * 1e3
    p50, p95, p99 = np.percentile(lat, [50, 95, 99])
    return {"n": int(lat.size), "p50_ms": float(p50), "p95_ms": float(p95),
            "p99_ms": float(p99), "mean_ms": float(lat.mean()),
            "max_ms": float(lat.max())}


class ServingEngine:
    """See module docstring. ``step_fn(queries [bucket, ...], n_valid)``
    returns ``(ids, scores)`` — ids ``[bucket, k]`` / scores ``[bucket,
    k]`` for top-k engines, ids ``[bucket]`` / scores ``None`` for greedy
    — with padded rows already masked (-1 / -inf)."""

    def __init__(self, step_fn: Callable[[np.ndarray, int], tuple], *,
                 top_k: Optional[int] = None, max_batch: int = 64,
                 max_wait_ms: float = 2.0, cache: Optional[ScoreCache] = None,
                 clock: Callable[[], float] = time.monotonic,
                 version_fn: Optional[Callable[[], Any]] = None,
                 min_bucket: int = 2, telemetry=None):
        self.step_fn = step_fn
        self.telemetry = telemetry or NULL_TRACER
        self.top_k = top_k
        self.cache = cache
        self.clock = clock
        self.version_fn = version_fn
        self.coalescer = Coalescer(max_batch=max_batch,
                                   max_wait=max_wait_ms * 1e-3,
                                   min_bucket=min_bucket)
        self._rid = 0
        self._version = version_fn() if version_fn else None
        self._done: List[Request] = []
        self._server_free_at = -np.inf
        # aggregate stats
        self.n_submitted = 0
        self.n_batches = 0
        self.occupancies: List[float] = []
        self.compute_s = 0.0

    # -- submission --------------------------------------------------------

    def _check_version(self):
        """Weight-refresh invalidation: a new served-weights version drops
        every cached score before the next lookup can hit it."""
        if self.version_fn is None:
            return
        v = self.version_fn()
        if v != self._version:
            self._version = v
            if self.cache is not None:
                self.cache.invalidate()

    def submit(self, query, *, now: Optional[float] = None) -> int:
        """Enqueue one query; returns its request id. Cache hits complete
        immediately (delivered by the next ``poll``/``drain``)."""
        now = self.clock() if now is None else now
        q = np.asarray(query, np.float32)
        rid = self._rid
        self._rid += 1
        self.n_submitted += 1
        tr = self.telemetry or NULL_TRACER
        tr.count("serve.submitted")
        req = Request(rid=rid, query=q, t_submit=now)
        if self.cache is not None:
            self._check_version()
            t0 = time.perf_counter_ns()
            hit = self.cache.get(q)
            lookup_ns = time.perf_counter_ns() - t0
            if hit is not None:
                (ids, scores), _kind = hit
                req.ids, req.scores = ids, scores
                req.cached = True
                # a cache hit is served in the measured lookup time, not
                # zero — sub-ms latencies must survive into the percentiles
                req.t_flush = req.t_start = now
                req.t_done = now + lookup_ns * 1e-9
                self._done.append(req)
                tr.count("serve.cache_hits")
                tr.add_span("serve.cache_hit", t0, lookup_ns)
                return rid
            tr.count("serve.cache_misses")
        self.coalescer.put(req)
        return rid

    # -- execution ---------------------------------------------------------

    def _pad(self, queries: List[np.ndarray], bucket: int) -> np.ndarray:
        q = np.stack(queries).astype(np.float32)
        if q.shape[0] < bucket:
            pad = np.zeros((bucket - q.shape[0],) + q.shape[1:], np.float32)
            q = np.concatenate([q, pad], axis=0)
        return q

    def _run_batch(self, mb) -> List[Request]:
        tr = self.telemetry or NULL_TRACER
        n = len(mb.requests)
        with tr.span("serve.flush"):
            padded = self._pad([r.query for r in mb.requests], mb.bucket)
        t0 = time.perf_counter_ns()
        with warnings.catch_warnings():
            # buffer donation is best-effort: XLA warns when out shapes
            # cannot alias the donated input; that is expected here
            warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
            ids, scores = self.step_fn(padded, n)
        dt_ns = time.perf_counter_ns() - t0
        dt = dt_ns * 1e-9
        self.n_batches += 1
        self.occupancies.append(mb.occupancy)
        self.compute_s += dt
        tr.add_span("serve.compute", t0, dt_ns)
        tr.count("serve.batches")
        tr.gauge("serve.occupancy", mb.occupancy)
        if self.cache is not None:
            tr.gauge("serve.cache_hit_rate", self.cache.hit_rate)
        t_start = max(mb.t_flush, self._server_free_at)
        t_done = t_start + dt
        self._server_free_at = t_done
        # queue wait on the engine clock: submit -> modeled batch start
        tr.count("serve.queue_wait_s",
                 sum(t_start - r.t_submit for r in mb.requests))
        ids = np.asarray(ids)
        scores = None if scores is None else np.asarray(scores)
        for i, r in enumerate(mb.requests):
            r.ids = ids[i].copy()
            r.scores = None if scores is None else scores[i].copy()
            r.t_start, r.t_done = t_start, t_done
            if self.cache is not None:
                self.cache.put(r.query, (r.ids, r.scores))
        return list(mb.requests)

    def _deliver(self, batches) -> List[Request]:
        done = self._done
        self._done = []
        for mb in batches:
            done.extend(self._run_batch(mb))
        return done

    def poll(self, now: Optional[float] = None) -> List[Request]:
        """Run micro-batches due at ``now`` (full buckets, expired
        deadlines); returns every request completed since the last call."""
        now = self.clock() if now is None else now
        return self._deliver(self.coalescer.ready(now))

    def drain(self, now: Optional[float] = None) -> List[Request]:
        """Flush the queue regardless of deadlines and return everything
        completed since the last poll (shutdown / end of replay)."""
        now = self.clock() if now is None else now
        return self._deliver(self.coalescer.flush(now))

    def warmup(self, example_query, buckets: Optional[Sequence[int]] = None):
        """Pre-compile the step for every padding bucket so the first real
        request doesn't pay jit latency."""
        q = np.asarray(example_query, np.float32)
        if buckets is None:
            buckets, b = [], 0
            while True:
                nb = bucket_for(b + 1, self.coalescer.min_bucket,
                                self.coalescer.max_batch)
                if buckets and nb == buckets[-1]:
                    break
                buckets.append(nb)
                b = nb
        for bucket in buckets:
            z = np.zeros((bucket,) + q.shape, np.float32)
            with warnings.catch_warnings():
                warnings.filterwarnings("ignore", message=".*[Dd]onat.*")
                self.step_fn(z, 0)

    def stats(self) -> dict:
        out = {
            "n_submitted": self.n_submitted,
            "n_batches": self.n_batches,
            "mean_batch_occupancy": (float(np.mean(self.occupancies))
                                     if self.occupancies else 0.0),
            "compute_s": self.compute_s,
            "cache_hit_rate": (self.cache.hit_rate
                               if self.cache is not None else 0.0),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats()
        return out

    # -- construction over an Experiment ------------------------------------

    @staticmethod
    def for_experiment(exp, *, top_k: Optional[int] = None,
                       max_batch: int = 64, max_wait_ms: float = 2.0,
                       cache: Optional[ScoreCache] = None,
                       clock: Callable[[], float] = time.monotonic,
                       donate: bool = True, min_bucket: int = 2,
                       index: Optional[str] = None,
                       nprobe: Optional[int] = None,
                       telemetry=None) -> "ServingEngine":
        """Build an engine over a paper (hybrid) or zoo (GSPMD)
        ``Experiment``. Queries are single feature embeddings ``[D]`` (or
        images for the cnn trunk); ``top_k=None`` serves greedy class ids,
        ``top_k=k`` serves ``(ids [k], scores [k])`` per request.

        ``index="ivf"`` routes the top-k path through the experiment's
        ``IVFIndex`` (fit lazily, refit when ``weights_version`` moves):
        each shard probes ``nprobe`` centroids (default: the index's own)
        and reranks only their member rows — sublinear in V."""
        if index not in (None, "none", "ivf"):
            raise ValueError(f"unknown serving index {index!r}; "
                             f"expected 'none' or 'ivf'")
        use_ivf = index == "ivf"
        if use_ivf and top_k is None:
            raise ValueError("index='ivf' serves top-k retrieval; "
                             "pass top_k=...")
        if hasattr(exp, "trainer"):                     # paper system
            step_fn = (_paper_ivf_step_fn(exp, top_k, nprobe, donate)
                       if use_ivf else _paper_step_fn(exp, top_k, donate))
        elif hasattr(exp, "par"):                       # zoo system
            step_fn = (_zoo_ivf_step_fn(exp, top_k, nprobe, donate)
                       if use_ivf else _zoo_step_fn(exp, top_k, donate))
        else:
            raise TypeError(
                f"not a paper/zoo Experiment: {type(exp).__name__}")
        # the probe must move on every restore as well as every train step:
        # a checkpoint restore REWINDS the step counter, and a rewound run
        # retrained to a previously-cached step value has different weights
        # — a bare step probe would serve those stale scores. Experiment's
        # ``weights_version`` is (restore count, step) for exactly this.
        version_fn = lambda: exp.weights_version        # noqa: E731
        return ServingEngine(step_fn, top_k=top_k, max_batch=max_batch,
                             max_wait_ms=max_wait_ms, cache=cache,
                             clock=clock, version_fn=version_fn,
                             min_bucket=min_bucket, telemetry=telemetry)


def replay_trace(engine: ServingEngine, clock, times, qids,
                 pool: np.ndarray) -> List[Request]:
    """Drive an engine with a generated trace under a ``VirtualClock``.

    Arrivals are replayed in trace order; between arrivals the clock also
    stops at any pending coalescer deadline so lull-tail flushes happen at
    their true due time (not lazily at the next arrival). Returns every
    completed request (one per trace event)."""
    done: List[Request] = []

    def run_due_before(t):
        while True:
            dl = engine.coalescer.oldest_deadline()
            if dl is None or dl >= t:
                return
            clock.advance_to(dl)
            done.extend(engine.poll())

    for t, qid in zip(times, qids):
        run_due_before(float(t))
        clock.advance_to(float(t))
        engine.submit(pool[int(qid)])
        done.extend(engine.poll())
    end = engine.coalescer.oldest_deadline()
    if end is not None:
        clock.advance_to(end)
    done.extend(engine.drain())
    return done


def _paper_step_fn(exp, top_k, donate):
    import jax
    import jax.numpy as jnp

    from repro.train import hybrid

    head = exp.trainer.head
    if top_k is not None:
        step = hybrid.make_batched_topk_serve_step(
            exp.model_cfg, exp.head_cfg, exp.mesh, exp.state, top_k,
            head=head, donate=donate)
    else:
        step = hybrid.make_batched_serve_step(
            exp.model_cfg, exp.head_cfg, exp.mesh, exp.state, head=head,
            donate=donate)

    def run(queries: np.ndarray, n_valid: int):
        with jax.set_mesh(exp.mesh):
            out = jax.device_get(step(exp.state, jnp.asarray(queries),
                                      jnp.asarray(n_valid, jnp.int32)))
        if top_k is not None:
            vals, gids = out
            return gids, vals
        return out, None

    return run


def _paper_ivf_step_fn(exp, top_k, nprobe, donate):
    import jax
    import jax.numpy as jnp

    from repro.train import hybrid

    head = exp.trainer.head
    built = {}           # (n_clusters, cap, nprobe) -> jitted step

    def ensure():
        # exp.ivf_index() refits when weights_version moves; the jitted
        # step is rebuilt only when the index GEOMETRY (or the effective
        # probe width) changes — same-shape refits reuse the compile.
        idx = exp.ivf_index()
        np_eff = idx.resolve_nprobe(nprobe)
        key = (idx.n_clusters, idx.cap, np_eff)
        if key not in built:
            built.clear()
            built[key] = hybrid.make_batched_ivf_topk_serve_step(
                exp.model_cfg, exp.head_cfg, exp.mesh, exp.state, top_k,
                nprobe=np_eff, head=head, donate=donate)
        return idx, built[key]

    def run(queries: np.ndarray, n_valid: int):
        idx, step = ensure()
        with jax.set_mesh(exp.mesh):
            vals, gids = jax.device_get(step(
                exp.state, idx.centroids, idx.members, jnp.asarray(queries),
                jnp.asarray(n_valid, jnp.int32)))
        return gids, vals

    return run


def _zoo_step_fn(exp, top_k, donate):
    import jax
    import jax.numpy as jnp

    from repro.train import gspmd

    step = gspmd.make_feature_serve_step(
        exp.model_cfg, exp.head_cfg, exp.par, exp.mesh, top_k=top_k,
        head=exp.head, donate=donate)

    def run(queries: np.ndarray, n_valid: int):
        with jax.set_mesh(exp.mesh):
            out = jax.device_get(step(
                exp.params, exp.head_state.params, exp.head_state.aux,
                jnp.asarray(queries), jnp.asarray(n_valid, jnp.int32)))
        if top_k is not None:
            vals, gids = out
            return gids, vals
        return out, None

    return run


def _zoo_ivf_step_fn(exp, top_k, nprobe, donate):
    import jax
    import jax.numpy as jnp

    from repro.train import gspmd

    built = {}           # (n_clusters, cap, nprobe) -> jitted step

    def ensure():
        idx = exp.ivf_index()
        np_eff = idx.resolve_nprobe(nprobe)
        key = (idx.n_clusters, idx.cap, np_eff)
        if key not in built:
            built.clear()
            built[key] = gspmd.make_feature_ivf_serve_step(
                exp.model_cfg, exp.head_cfg, exp.par, exp.mesh, top_k,
                nprobe=np_eff, head=exp.head, donate=donate)
        return idx, built[key]

    def run(queries: np.ndarray, n_valid: int):
        idx, step = ensure()
        with jax.set_mesh(exp.mesh):
            vals, gids = jax.device_get(step(
                exp.params, exp.head_state.params, exp.head_state.aux,
                idx.centroids, idx.members, jnp.asarray(queries),
                jnp.asarray(n_valid, jnp.int32)))
        return gids, vals

    return run
