"""Request coalescing: single async queries -> fixed-shape micro-batches.

Serving traffic arrives one query at a time; the accelerator wants big,
*fixed-shape* batches. The ``Coalescer`` bridges the two:

  * queries queue in submission order (a monotone sequence number breaks
    ties, so replaying the same submissions always packs the same batches
    — even when the caller's timestamps arrive out of order);
  * a batch is cut as soon as ``max_batch`` queries are waiting, or when
    the OLDEST waiting query has aged past ``max_wait`` seconds — the
    flush deadline that bounds tail latency during lulls;
  * every cut batch is padded up to a power-of-two bucket (floor
    ``min_bucket``, cap ``max_batch``), so the engine compiles at most
    ``log2(max_batch / min_bucket) + 1`` distinct step shapes.

``min_bucket`` defaults to 2 because on the CPU backend a 1-row matmul
(matvec) takes a different accumulation path from the batched gemm; from
2 rows up, every bucket scores each row bitwise-identically, which is what
makes the engine's results exactly equal to per-query serving
(tests/test_serving.py).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, List, Optional


def bucket_for(n: int, min_bucket: int = 2, max_batch: int = 64) -> int:
    """Smallest power-of-two bucket >= n (floored at min_bucket, capped at
    max_batch). ``max_batch`` itself need not be a power of two — a full
    batch runs at exactly ``max_batch`` rows."""
    if n >= max_batch:
        return max_batch
    b = max(1, min_bucket)
    while b < n:
        b <<= 1
    return min(b, max_batch)


@dataclass
class Request:
    """One in-flight query and its lifecycle timestamps (all in the
    engine's clock domain; ``latency`` is submit -> completion)."""
    rid: int
    query: Any                    # np.ndarray feature / image
    t_submit: float
    seq: int = 0
    # filled at completion
    t_flush: float = 0.0          # batch cut from the queue
    t_start: float = 0.0          # service start (>= t_flush under load)
    t_done: float = 0.0
    cached: bool = False
    bucket: int = 0               # padded batch shape it rode in (0: cached)
    batch_n: int = 0              # real queries in that batch
    ids: Any = None               # [k] int32 (or scalar for greedy)
    scores: Any = None            # [k] float32 or None (greedy)

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


@dataclass
class MicroBatch:
    requests: List[Request]
    bucket: int
    t_flush: float

    @property
    def occupancy(self) -> float:
        return len(self.requests) / self.bucket


class Coalescer:
    def __init__(self, *, max_batch: int = 64, max_wait: float = 0.002,
                 min_bucket: int = 2):
        if max_batch < 1:
            raise ValueError(f"max_batch must be positive, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.min_bucket = max(1, min_bucket)
        self._queue: List[Request] = []
        self._seq = itertools.count()

    def __len__(self) -> int:
        return len(self._queue)

    def put(self, req: Request) -> Request:
        req.seq = next(self._seq)
        self._queue.append(req)
        return req

    def _cut(self, n: int, now: float) -> MicroBatch:
        reqs, self._queue = self._queue[:n], self._queue[n:]
        mb = MicroBatch(reqs, bucket_for(n, self.min_bucket, self.max_batch),
                        now)
        for r in reqs:
            r.t_flush = now
            r.bucket = mb.bucket
            r.batch_n = n
        return mb

    def _sort(self):
        # timsort is stable and near-O(n) on the almost-sorted queue; the
        # (t_submit, seq) key makes packing deterministic under
        # out-of-order timestamps from a virtual clock
        self._queue.sort(key=lambda r: (r.t_submit, r.seq))

    def ready(self, now: float) -> List[MicroBatch]:
        """Batches due at ``now``: full ``max_batch`` cuts first, then one
        deadline flush if the oldest survivor has waited >= max_wait."""
        self._sort()
        out = []
        while len(self._queue) >= self.max_batch:
            out.append(self._cut(self.max_batch, now))
        # NB: compare against t_submit + max_wait — the exact expression
        # oldest_deadline() returns — not (now - t_submit) >= max_wait:
        # the two differ by a float rounding, and a replay clock advanced
        # exactly to the deadline must always trigger the cut
        if self._queue and now >= self._queue[0].t_submit + self.max_wait:
            out.append(self._cut(len(self._queue), now))
        return out

    def flush(self, now: float) -> List[MicroBatch]:
        """Drain everything regardless of age (shutdown / end of replay)."""
        self._sort()
        out = []
        while self._queue:
            out.append(self._cut(min(len(self._queue), self.max_batch), now))
        return out

    def oldest_deadline(self, default: Optional[float] = None
                        ) -> Optional[float]:
        """Absolute time the next deadline flush comes due (None if idle)."""
        if not self._queue:
            return default
        return min(r.t_submit for r in self._queue) + self.max_wait
