"""Compatibility layer: run the jax>=0.6-style codebase on older jax.

The repo is written against the modern jax API surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.make_mesh(..., axis_types=...)``,
``jax.sharding.AxisType``, ``jax.lax.pcast``, ``jax.lax.axis_size``). The
pinned container ships jax 0.4.x, where those either live under
``jax.experimental`` or do not exist yet. Importing :mod:`repro` installs
thin forward-compatible aliases onto the ``jax`` module so one source tree
runs on both. On a modern jax every patch below is a no-op.
"""
from __future__ import annotations

import contextlib
import enum
import functools

import jax


def _install() -> None:
    # -- jax.shard_map (stable alias of jax.experimental.shard_map) -------
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      **kw):
            if check_vma is not None:   # renamed from check_rep
                kw.setdefault("check_rep", check_vma)
            return _shard_map(f, mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    # -- jax.set_mesh (context-manager usage only) -------------------------
    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    # -- jax.sharding.AxisType + make_mesh(axis_types=...) ----------------
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    # -- lax additions -----------------------------------------------------
    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size

    if not hasattr(jax.lax, "pcast"):
        # varying-ness annotation; with check_rep/check_vma off it is an
        # identity at trace time
        def pcast(x, axes, *, to=None):
            return x

        jax.lax.pcast = pcast


_install()
