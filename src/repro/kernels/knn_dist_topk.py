"""Pallas TPU kernel: fused cosine-score + running top-k' merge — the inner
loop of the distributed KNN graph build (paper §3.2.2).

Per ring hop, each device scores its local rows Q [Nq, D] against the
traveling block K [Nk, D] and merges into a running top-k'. This kernel
fuses the MXU matmul with the merge so the [Nq, Nk] score tile never leaves
VMEM: grid = (q_blocks, n_blocks) with the n dimension innermost; a VMEM
scratch carries (vals, ids) across the n sweep and flushes on the last tile.

The merge is k' max-extraction sweeps over [bq, k' + bn] (k' static —
unrolls onto the VPU; matmul tiles are 128-aligned for the MXU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -jnp.inf


def _merge_sweep(vals, ids, k: int):
    """Top-k of each row of (vals, ids) [bq, W] by k extraction sweeps."""
    bq, w = vals.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, w), 1)
    out_v = []
    out_i = []
    for i in range(k):
        m = jnp.max(vals, axis=1)
        am = jnp.argmax(vals, axis=1).astype(jnp.int32)
        out_v.append(m)
        out_i.append(jnp.take_along_axis(ids, am[:, None], axis=1)[:, 0])
        vals = jnp.where(col == am[:, None], NEG, vals)
    return jnp.stack(out_v, axis=1), jnp.stack(out_i, axis=1)


def _dist_topk_kernel(q_ref, k_ref, vals_ref, idx_ref, acc_v, acc_i, *,
                      kprime: int, bn: int, n_valid: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_v[...] = jnp.full_like(acc_v, NEG)
        acc_i[...] = jnp.full_like(acc_i, -1)

    q = q_ref[...]                                # [bq, D]
    kb = k_ref[...]                               # [bn, D]
    scores = jax.lax.dot_general(
        q, kb, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # [bq, bn] MXU
    ids = (j * bn + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1))
    scores = jnp.where(ids < n_valid, scores, NEG)  # padded cols never win
    cat_v = jnp.concatenate([acc_v[...], scores], axis=1)
    cat_i = jnp.concatenate([acc_i[...], ids], axis=1)
    new_v, new_i = _merge_sweep(cat_v, cat_i, kprime)
    acc_v[...] = new_v
    acc_i[...] = new_i

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        vals_ref[...] = acc_v[...]
        idx_ref[...] = acc_i[...]


def dist_topk(q: jax.Array, kmat: jax.Array, kprime: int, *,
              block_q: int = 128, block_n: int = 128,
              col_offset: int = 0, interpret: bool = True):
    """q [Nq, D] x kmat [Nk, D] -> (vals [Nq, k'], ids [Nq, k'] global ids
    offset by col_offset). Rows/cols padded to block multiples."""
    nq, d = q.shape
    nk = kmat.shape[0]
    pq, pn = (-nq) % block_q, (-nk) % block_n
    if pq:
        q = jnp.pad(q, ((0, pq), (0, 0)))
    if pn:
        kmat = jnp.pad(kmat, ((0, pn), (0, 0)))  # masked inside the kernel
    nq_p, nk_p = q.shape[0], kmat.shape[0]
    grid = (nq_p // block_q, nk_p // block_n)
    vals, idx = pl.pallas_call(
        functools.partial(_dist_topk_kernel, kprime=kprime, bn=block_n,
                          n_valid=nk),
        out_shape=(jax.ShapeDtypeStruct((nq_p, kprime), jnp.float32),
                   jax.ShapeDtypeStruct((nq_p, kprime), jnp.int32)),
        grid=grid,
        in_specs=[pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
                  pl.BlockSpec((block_n, d), lambda i, j: (j, 0))],
        out_specs=(pl.BlockSpec((block_q, kprime), lambda i, j: (i, 0)),
                   pl.BlockSpec((block_q, kprime), lambda i, j: (i, 0))),
        scratch_shapes=[pltpu.VMEM((block_q, kprime), jnp.float32),
                        pltpu.VMEM((block_q, kprime), jnp.int32)],
        interpret=interpret,
    )(q, kmat)
    vals, idx = vals[:nq], idx[:nq]
    real = (idx >= 0) & (idx < nk)
    vals = jnp.where(real, vals, NEG)
    idx = jnp.where(real, idx + col_offset, -1)
    return vals, idx
