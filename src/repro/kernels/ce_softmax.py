"""Pallas TPU kernel: streaming fused softmax cross-entropy over a vocab
shard — the paper's softmax-stage hotspot (§3.2: ">80% of the time is spent
in the softmax stage ... over 10 GB for the output space of the last fc").

Forward: grid sweeps vocab tiles; each tile does an MXU matmul
f [B,D] @ W_tile [bv,D]^T and folds it into online-softmax running stats
(max m, sum z, label logit corr, argmax col) carried in VMEM scratch — the
[B, V_local] logit tensor NEVER exists in HBM (that is the 10 GB the paper
pays). A traced ``limit`` scalar (SMEM) masks columns >= limit, which covers
both Megatron-style vocab padding (n_valid) and the kernel's own block_v
padding in one mechanism. (Candidate-set CE with per-column bias — the
sampled head's -logQ — lives in sparse_ce.py, not here.)

Backward: second sweep recomputes each tile's scores and applies the
caller-provided per-row cotangents (gz for the partition sum, gc for the
label logit):
    dlogits_j = (exp(s_j - m) * gz + onehot_j(label) * gc) * scale
    df += dlogits @ W_tile ; dW_tile = dlogits^T @ f
Parameterizing the backward by (gz, gc) instead of a scalar loss cotangent
lets the SAME kernel serve the single-shard loss (ops.fused_ce: gz = g/z,
gc = -g) and the distributed sharded loss (ops.ce_shard_stats: gz/gc arrive
from autodiff of the cross-shard pmax/psum completion). The per-row max m is
returned as a non-differentiable statistic — its true total derivative
cancels exactly against z's internal rescaling, so ignoring its cotangent is
mathematically exact, not an approximation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -jnp.inf


def _fwd_kernel(lim_ref, f_ref, w_ref, y_ref,
                m_ref, z_ref, corr_ref, amax_ref,
                acc_m, acc_z, acc_c, acc_a, *, bv: int, scale: float):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_m[...] = jnp.full_like(acc_m, NEG)
        acc_z[...] = jnp.zeros_like(acc_z)
        acc_c[...] = jnp.zeros_like(acc_c)
        acc_a[...] = jnp.full_like(acc_a, -1)

    f = f_ref[...]                                    # [B, D]
    w = w_ref[...]                                    # [bv, D]
    s = jax.lax.dot_general(f, w, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    y = y_ref[...]                                    # [B] local label ids
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = col < lim_ref[0]                          # vocab + block padding
    s = jnp.where(valid, s, NEG)
    hit = col == y[:, None]
    # fold the label logit (each label hits exactly one tile)
    acc_c[...] += jnp.sum(jnp.where(hit, s, 0.0), axis=1)

    m_old = acc_m[...]
    tile_m = jnp.max(s, axis=1)                       # NEG if tile all-masked
    tile_a = j * bv + jnp.argmax(s, axis=1).astype(jnp.int32)
    m_new = jnp.maximum(m_old, tile_m)
    acc_a[...] = jnp.where(tile_m > m_old, tile_a, acc_a[...])
    # rescale the running sum to the new max (online softmax); masked columns
    # contribute 0 via the `valid` select, which also discards the NaN from
    # exp(-inf - -inf) on fully-masked rows
    zcorr = jnp.where(jnp.isfinite(m_old), jnp.exp(m_old - m_new), 0.0)
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    acc_z[...] = acc_z[...] * zcorr + jnp.sum(p, axis=1)
    acc_m[...] = m_new

    @pl.when(j == pl.num_programs(0) - 1)
    def _flush():
        m_ref[...] = acc_m[...]
        z_ref[...] = acc_z[...]
        corr_ref[...] = acc_c[...]
        amax_ref[...] = acc_a[...]


def ce_forward(f, w, y, *, limit=None, block_v: int = 512,
               scale: float = 1.0, interpret: bool = True):
    """f [B,D], w [V,D], y [B] local ids (out-of-range = not owned).

    ``limit`` (traced int scalar, default V) masks columns >= limit out of
    the softmax — Megatron vocab padding on the owning shard.
    Returns per-row fp32 (m, z, corr, amax): running max, partition sum
    relative to m, label logit, argmax column (-1 when all columns masked).
    """
    b, d = f.shape
    v = w.shape[0]
    bv = min(block_v, max(8, v))
    pv = (-v) % bv
    if pv:
        w = jnp.pad(w, ((0, pv), (0, 0)))
    vp = w.shape[0]
    if limit is None:
        limit = jnp.asarray(v, jnp.int32)
    lim = jnp.minimum(jnp.asarray(limit, jnp.int32), v).reshape(1)
    # out-of-shard labels must not fold anything: map them to -1 (never
    # matches the col iota)
    y = jnp.where((y >= 0) & (y < v), y, -1)
    m, z, corr, amax = pl.pallas_call(
        functools.partial(_fwd_kernel, bv=bv, scale=scale),
        out_shape=(jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.int32)),
        grid=(vp // bv,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((b, d), lambda j: (0, 0)),
                  pl.BlockSpec((bv, d), lambda j: (j, 0)),
                  pl.BlockSpec((b,), lambda j: (0,))],
        out_specs=(pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,))),
        scratch_shapes=[pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.int32)],
        interpret=interpret,
    )(lim, f.astype(jnp.float32), w.astype(jnp.float32), y.astype(jnp.int32))
    return m, z, corr, amax


def _bwd_kernel(lim_ref, f_ref, w_ref, y_ref, m_ref, gz_ref, gc_ref,
                dw_ref, df_ref, acc_df, *, bv: int, scale: float):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_df[...] = jnp.zeros_like(acc_df)

    f = f_ref[...]
    w = w_ref[...]
    s = jax.lax.dot_general(f, w, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m = m_ref[...]                                    # [B] forward's row max
    gz = gz_ref[...]                                  # [B] dL/dz
    gc = gc_ref[...]                                  # [B] dL/dcorr
    y = y_ref[...]
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = col < lim_ref[0]
    p = jnp.where(valid & jnp.isfinite(m)[:, None],
                  jnp.exp(s - m[:, None]), 0.0)       # [B, bv] exp rel. to m
    hit = (col == y[:, None]).astype(jnp.float32)
    dl = (p * gz[:, None] + hit * gc[:, None]) * scale
    dw_ref[...] = jax.lax.dot_general(
        dl, f, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # [bv, D]
    acc_df[...] += jax.lax.dot_general(
        dl, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # [B, D]

    @pl.when(j == pl.num_programs(0) - 1)
    def _flush():
        df_ref[...] = acc_df[...]


def ce_backward(f, w, y, m, gz, gc, *, limit=None,
                block_v: int = 512, scale: float = 1.0,
                interpret: bool = True):
    """Streamed backward from per-row cotangents.

    m is the forward's per-row running max (residual); gz / gc are the
    cotangents of the forward's z / corr outputs. Returns (df [B,D],
    dw [V,D]) fp32.
    """
    b, d = f.shape
    v = w.shape[0]
    bv = min(block_v, max(8, v))
    pv = (-v) % bv
    if pv:
        w = jnp.pad(w, ((0, pv), (0, 0)))
    vp = w.shape[0]
    if limit is None:
        limit = jnp.asarray(v, jnp.int32)
    lim = jnp.minimum(jnp.asarray(limit, jnp.int32), v).reshape(1)
    y = jnp.where((y >= 0) & (y < v), y, -1)
    dw, df = pl.pallas_call(
        functools.partial(_bwd_kernel, bv=bv, scale=scale),
        out_shape=(jax.ShapeDtypeStruct((vp, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, d), jnp.float32)),
        grid=(vp // bv,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((b, d), lambda j: (0, 0)),
                  pl.BlockSpec((bv, d), lambda j: (j, 0)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,))],
        out_specs=(pl.BlockSpec((bv, d), lambda j: (j, 0)),
                   pl.BlockSpec((b, d), lambda j: (0, 0))),
        scratch_shapes=[pltpu.VMEM((b, d), jnp.float32)],
        interpret=interpret,
    )(lim, f.astype(jnp.float32), w.astype(jnp.float32), y.astype(jnp.int32),
      m, gz.astype(jnp.float32), gc.astype(jnp.float32))
    return df, dw[:v]
