"""Pallas TPU kernel: streaming fused softmax cross-entropy over a vocab
shard — the paper's softmax-stage hotspot (§3.2: ">80% of the time is spent
in the softmax stage ... over 10 GB for the output space of the last fc").

Forward: grid sweeps vocab tiles; each tile does an MXU matmul
f [B,D] @ W_tile [bv,D]^T and folds it into online-softmax running
(max m, sum z, label logit corr) carried in VMEM scratch — the [B, V_local]
logit tensor NEVER exists in HBM (that is the 10 GB the paper pays).

Backward: second sweep recomputes each tile's probabilities from (m, z) and
accumulates df (VMEM scratch) while writing dW tiles directly:
    dlogits = (softmax - onehot(label)) * g
    df += dlogits @ W_tile ; dW_tile = dlogits^T @ f
Fused in ops.fused_ce via jax.custom_vjp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fwd_kernel(f_ref, w_ref, y_ref, m_ref, z_ref, corr_ref,
                acc_m, acc_z, acc_c, *, bv: int, scale: float):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_m[...] = jnp.full_like(acc_m, -jnp.inf)
        acc_z[...] = jnp.zeros_like(acc_z)
        acc_c[...] = jnp.zeros_like(acc_c)

    f = f_ref[...]                                    # [B, D]
    w = w_ref[...]                                    # [bv, D]
    s = jax.lax.dot_general(f, w, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    y = y_ref[...]                                    # [B] local label ids
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    hit = col == y[:, None]
    # fold the label logit (each label hits exactly one tile)
    acc_c[...] += jnp.sum(jnp.where(hit, s, 0.0), axis=1)

    m_old = acc_m[...]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
    # rescale the running sum to the new max (online softmax)
    zcorr = jnp.where(jnp.isfinite(m_old), jnp.exp(m_old - m_new), 0.0)
    acc_z[...] = acc_z[...] * zcorr + jnp.sum(jnp.exp(s - m_new[:, None]), axis=1)
    acc_m[...] = m_new

    @pl.when(j == pl.num_programs(0) - 1)
    def _flush():
        m_ref[...] = acc_m[...]
        z_ref[...] = acc_z[...]
        corr_ref[...] = acc_c[...]


def ce_forward(f, w, y, *, block_v: int = 512, scale: float = 1.0,
               interpret: bool = True):
    """f [B,D], w [V,D], y [B] local ids (out-of-range = not owned).
    Returns (m, z, corr) per row, fp32."""
    b, d = f.shape
    v = w.shape[0]
    pv = (-v) % block_v
    if pv:
        w = jnp.pad(w, ((0, pv), (0, 0)))
    vp = w.shape[0]
    # out-of-shard labels must not fold anything: padded tile cols score like
    # real ones, so map OOR labels to -1 (never matches col iota)
    y = jnp.where((y >= 0) & (y < v), y, -1)
    # padded rows of W are zero -> logits 0; they inflate z. Mask by pushing
    # their scores out via a -inf bias column trick: instead we subtract
    # their contribution: exp(0 - m) per padded col. Simpler: pad W with a
    # large negative first component and zero feature? We instead handle it
    # here: compute with padded cols, then remove analytically below.
    m, z, corr = pl.pallas_call(
        functools.partial(_fwd_kernel, bv=block_v, scale=scale),
        out_shape=(jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.float32)),
        grid=(vp // block_v,),
        in_specs=[pl.BlockSpec((b, d), lambda j: (0, 0)),
                  pl.BlockSpec((block_v, d), lambda j: (j, 0)),
                  pl.BlockSpec((b,), lambda j: (0,))],
        out_specs=(pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,))),
        scratch_shapes=[pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.float32)],
        interpret=interpret,
    )(f.astype(jnp.float32), w.astype(jnp.float32), y.astype(jnp.int32))
    if pv:  # remove the pv zero-logit contributions exp(0*scale - m)
        z = z - pv * jnp.exp(-m)
    return m, z, corr


def _bwd_kernel(f_ref, w_ref, y_ref, m_ref, z_ref, g_ref, dw_ref, df_ref,
                acc_df, *, bv: int, scale: float, n_valid: int):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_df[...] = jnp.zeros_like(acc_df)

    f = f_ref[...]
    w = w_ref[...]
    s = jax.lax.dot_general(f, w, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    m = m_ref[...]
    z = z_ref[...]
    g = g_ref[...]                                    # upstream dloss [B]
    p = jnp.exp(s - m[:, None]) / z[:, None]          # [B, bv]
    y = y_ref[...]
    col = j * bv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    p = jnp.where(col < n_valid, p, 0.0)              # padded cols: no grad
    dl = (p - (col == y[:, None]).astype(jnp.float32)) * g[:, None] * scale
    dw_ref[...] = jax.lax.dot_general(
        dl, f, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # [bv, D]
    acc_df[...] += jax.lax.dot_general(
        dl, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # [B, D]

    @pl.when(j == pl.num_programs(0) - 1)
    def _flush():
        df_ref[...] = acc_df[...]


def ce_backward(f, w, y, m, z, g, *, block_v: int = 512, scale: float = 1.0,
                interpret: bool = True):
    """Streamed backward. Returns (df [B,D], dw [V,D]) fp32."""
    b, d = f.shape
    v = w.shape[0]
    pv = (-v) % block_v
    if pv:
        w = jnp.pad(w, ((0, pv), (0, 0)))
    vp = w.shape[0]
    y = jnp.where((y >= 0) & (y < v), y, -1)
    dw, df = pl.pallas_call(
        functools.partial(_bwd_kernel, bv=block_v, scale=scale, n_valid=v),
        out_shape=(jax.ShapeDtypeStruct((vp, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, d), jnp.float32)),
        grid=(vp // block_v,),
        in_specs=[pl.BlockSpec((b, d), lambda j: (0, 0)),
                  pl.BlockSpec((block_v, d), lambda j: (j, 0)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,))],
        out_specs=(pl.BlockSpec((block_v, d), lambda j: (j, 0)),
                   pl.BlockSpec((b, d), lambda j: (0, 0))),
        scratch_shapes=[pltpu.VMEM((b, d), jnp.float32)],
        interpret=interpret,
    )(f.astype(jnp.float32), w.astype(jnp.float32), y.astype(jnp.int32),
      m, z, g)
    return df, dw[:v]
