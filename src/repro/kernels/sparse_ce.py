"""Pallas TPU kernel: active-class sparse softmax cross-entropy — the fused
analogue of dynamic class selection (Zhang et al., AAAI'18) that the KNN and
selective heads run in dense form, and the candidate-set CE of the sampled
head.

Each model shard scores only A active local classes (KNN-graph selection /
LSH buckets / drawn negatives) instead of its full V_local shard. The ref
path gathers ``w[ids]`` to an [A, D] tensor in HBM, matmuls to a dense
[B, A] logit tensor, and lets autodiff scatter the gradient back. This
kernel fuses all three stages:

  forward — grid sweeps tiles of the active-id list; per tile, the [ba, D]
  weight rows are gathered from the FULL [V_local, D] shard (kept whole in
  kernel memory; a fori_loop of per-row dynamic slices — on hardware these
  lower to per-row DMAs) into VMEM scratch, matmul'd against f [B, D] on the
  MXU, bias-shifted (the sampled head's -logQ), masked, and folded into
  online-softmax running stats (m, z, corr, argmax). Neither the gathered
  [A, D] weights nor the [B, A] logits ever reach HBM.

  per-column masking is computed in-kernel from the GLOBAL candidate ids vs
  each row's global label: ``mask_hits=False`` folds the FIRST label hit
  into corr (knn / selective — the label is a candidate; duplicates from
  random filler collisions count once, matching the ref path's
  ``argmax(hit)``); ``mask_hits=True`` drops every hit from z entirely
  (sampled softmax's accidental-hit correction — the label is scored
  separately by the caller).

  backward — second sweep re-gathers + recomputes each tile's scores and
  applies per-row cotangents (gz, gc) exactly like ce_softmax's backward:
  dlogits = (exp(s - m) * gz + onehot * gc) * scale. dW comes out as the
  compact per-tile [ba, D] product; the wrapper (ops.sparse_ce_stats)
  scatter-adds it into the [V_local, D] shard.

Wrapped by ``ops.sparse_ce_stats`` (jax.custom_vjp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -jnp.inf


def _gather_tile(ids_ref, w_ref, tile, j: int, ba: int):
    """Copy w rows ids[j*ba : (j+1)*ba] into the [ba, D] VMEM scratch."""
    def body(r, _):
        tile[pl.ds(r, 1), :] = w_ref[pl.ds(ids_ref[j * ba + r], 1), :]
        return 0
    jax.lax.fori_loop(0, ba, body, 0)


def _first_hit(hit, seen):
    """Leftmost hit column per row, and only if no earlier tile hit: the
    ref path's ``argmax(hit)`` counts the label column exactly ONCE even
    when duplicate candidate ids equal the label (random fillers can
    collide), so corr / the backward onehot must too."""
    leftmost = hit & (jnp.cumsum(hit.astype(jnp.int32), axis=1) == 1)
    return leftmost & (seen == 0)[:, None]


def _fwd_kernel(ids_ref, f_ref, w_ref, gids_ref, bias_ref, valid_ref, y_ref,
                m_ref, z_ref, corr_ref, amax_ref,
                tile, acc_m, acc_z, acc_c, acc_a, acc_seen,
                *, ba: int, scale: float, mask_hits: bool):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_m[...] = jnp.full_like(acc_m, NEG)
        acc_z[...] = jnp.zeros_like(acc_z)
        acc_c[...] = jnp.zeros_like(acc_c)
        acc_a[...] = jnp.full_like(acc_a, -1)
        acc_seen[...] = jnp.zeros_like(acc_seen)

    _gather_tile(ids_ref, w_ref, tile, j, ba)
    f = f_ref[...]                                    # [B, D]
    s = jax.lax.dot_general(f, tile[...], (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[...][None, :]
    y = y_ref[...]                                    # [B] GLOBAL labels
    gids = gids_ref[...]                              # [ba] global cand ids
    col_ok = valid_ref[...] > 0                       # [ba]
    hit = (gids[None, :] == y[:, None]) & col_ok[None, :]
    if mask_hits:                                     # sampled: drop dupes
        keep = col_ok[None, :] & ~hit
    else:                                             # knn/selective: corr
        keep = jnp.broadcast_to(col_ok[None, :], s.shape)
        first = _first_hit(hit, acc_seen[...])
        acc_c[...] += jnp.sum(jnp.where(first, s, 0.0), axis=1)
        acc_seen[...] = jnp.maximum(
            acc_seen[...], jnp.any(hit, axis=1).astype(jnp.int32))
    s = jnp.where(keep, s, NEG)

    m_old = acc_m[...]
    tile_m = jnp.max(s, axis=1)
    tile_a = j * ba + jnp.argmax(s, axis=1).astype(jnp.int32)
    m_new = jnp.maximum(m_old, tile_m)
    acc_a[...] = jnp.where(tile_m > m_old, tile_a, acc_a[...])
    zcorr = jnp.where(jnp.isfinite(m_old), jnp.exp(m_old - m_new), 0.0)
    p = jnp.where(keep, jnp.exp(s - m_new[:, None]), 0.0)
    acc_z[...] = acc_z[...] * zcorr + jnp.sum(p, axis=1)
    acc_m[...] = m_new

    @pl.when(j == pl.num_programs(0) - 1)
    def _flush():
        m_ref[...] = acc_m[...]
        z_ref[...] = acc_z[...]
        corr_ref[...] = acc_c[...]
        amax_ref[...] = acc_a[...]


def _pad_cols(ids, gids, bias, valid, ba):
    a = ids.shape[0]
    pa = (-a) % ba
    if pa:
        ids = jnp.pad(ids, (0, pa))                  # clipped-safe row 0
        gids = jnp.pad(gids, (0, pa), constant_values=-1)
        bias = jnp.pad(bias.astype(jnp.float32), (0, pa))
        valid = jnp.pad(valid, (0, pa))              # padded cols invalid
    return ids, gids, bias, valid, a + pa


def sparse_ce_forward(f, w, ids, gids, bias, valid, y, *, block_a: int = 128,
                      scale: float = 1.0, mask_hits: bool = False,
                      interpret: bool = True):
    """f [B,D]; w [V_loc,D]; ids [A] local rows of w; gids [A] global class
    ids of the candidates; bias [A] per-column logit shift; valid [A] col
    mask (int/bool); y [B] global labels. Returns per-row fp32
    (m, z, corr, amax-col)."""
    b, d = f.shape
    v = w.shape[0]
    ba = min(block_a, max(8, ids.shape[0]))
    ids = jnp.clip(ids.astype(jnp.int32), 0, v - 1)
    ids, gids, bias, valid, ap = _pad_cols(
        ids, gids.astype(jnp.int32), bias, valid.astype(jnp.int32), ba)
    m, z, corr, amax = pl.pallas_call(
        functools.partial(_fwd_kernel, ba=ba, scale=scale,
                          mask_hits=mask_hits),
        out_shape=(jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.float32),
                   jax.ShapeDtypeStruct((b,), jnp.int32)),
        grid=(ap // ba,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((b, d), lambda j: (0, 0)),
                  pl.BlockSpec(memory_space=pltpu.VMEM),
                  pl.BlockSpec((ba,), lambda j: (j,)),
                  pl.BlockSpec((ba,), lambda j: (j,)),
                  pl.BlockSpec((ba,), lambda j: (j,)),
                  pl.BlockSpec((b,), lambda j: (0,))],
        out_specs=(pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,)),
                   pl.BlockSpec((b,), lambda j: (0,))),
        scratch_shapes=[pltpu.VMEM((ba, d), jnp.float32),
                        pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.float32),
                        pltpu.VMEM((b,), jnp.int32),
                        pltpu.VMEM((b,), jnp.int32)],
        interpret=interpret,
    )(ids, f.astype(jnp.float32), w.astype(jnp.float32), gids, bias,
      valid, y.astype(jnp.int32))
    return m, z, corr, amax


def _bwd_kernel(ids_ref, f_ref, w_ref, gids_ref, bias_ref, valid_ref, y_ref,
                m_ref, gz_ref, gc_ref,
                dwa_ref, df_ref, tile, acc_df, acc_seen,
                *, ba: int, scale: float, mask_hits: bool):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_df[...] = jnp.zeros_like(acc_df)
        acc_seen[...] = jnp.zeros_like(acc_seen)

    _gather_tile(ids_ref, w_ref, tile, j, ba)
    f = f_ref[...]
    w_t = tile[...]
    s = jax.lax.dot_general(f, w_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = s + bias_ref[...][None, :]
    y = y_ref[...]
    gids = gids_ref[...]
    col_ok = valid_ref[...] > 0
    hit = (gids[None, :] == y[:, None]) & col_ok[None, :]
    if mask_hits:
        keep = col_ok[None, :] & ~hit
        hitf = jnp.zeros_like(s)
    else:
        keep = jnp.broadcast_to(col_ok[None, :], s.shape)
        # the corr onehot hits the FIRST label column only, like the forward
        hitf = _first_hit(hit, acc_seen[...]).astype(jnp.float32)
        acc_seen[...] = jnp.maximum(
            acc_seen[...], jnp.any(hit, axis=1).astype(jnp.int32))

    m = m_ref[...]
    gz = gz_ref[...]
    gc = gc_ref[...]
    p = jnp.where(keep & jnp.isfinite(m)[:, None],
                  jnp.exp(s - m[:, None]), 0.0)
    dl = (p * gz[:, None] + hitf * gc[:, None]) * scale
    dwa_ref[...] = jax.lax.dot_general(
        dl, f, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # [ba, D] compact dW
    acc_df[...] += jax.lax.dot_general(
        dl, w_t, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # [B, D]

    @pl.when(j == pl.num_programs(0) - 1)
    def _flush():
        df_ref[...] = acc_df[...]


def sparse_ce_backward(f, w, ids, gids, bias, valid, y, m, gz, gc, *,
                       block_a: int = 128, scale: float = 1.0,
                       mask_hits: bool = False, interpret: bool = True):
    """Streamed backward. Returns (df [B,D], dw_act [A,D] per-candidate
    weight grads — scatter-add into [V_loc, D] is the wrapper's job)."""
    b, d = f.shape
    v = w.shape[0]
    a = ids.shape[0]
    ba = min(block_a, max(8, a))
    ids = jnp.clip(ids.astype(jnp.int32), 0, v - 1)
    ids, gids, bias, valid, ap = _pad_cols(
        ids, gids.astype(jnp.int32), bias, valid.astype(jnp.int32), ba)
    dwa, df = pl.pallas_call(
        functools.partial(_bwd_kernel, ba=ba, scale=scale,
                          mask_hits=mask_hits),
        out_shape=(jax.ShapeDtypeStruct((ap, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, d), jnp.float32)),
        grid=(ap // ba,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((b, d), lambda j: (0, 0)),
                  pl.BlockSpec(memory_space=pltpu.VMEM),
                  pl.BlockSpec((ba,), lambda j: (j,)),
                  pl.BlockSpec((ba,), lambda j: (j,)),
                  pl.BlockSpec((ba,), lambda j: (j,)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,)),
                  pl.BlockSpec((b,), lambda j: (0,))],
        out_specs=(pl.BlockSpec((ba, d), lambda j: (j, 0)),
                   pl.BlockSpec((b, d), lambda j: (0, 0))),
        scratch_shapes=[pltpu.VMEM((ba, d), jnp.float32),
                        pltpu.VMEM((b, d), jnp.float32),
                        pltpu.VMEM((b,), jnp.int32)],
        interpret=interpret,
    )(ids, f.astype(jnp.float32), w.astype(jnp.float32), gids, bias,
      valid, y.astype(jnp.int32), m, gz.astype(jnp.float32),
      gc.astype(jnp.float32))
    return df, dwa[:a]
