# Pallas TPU kernels for the compute hot-spots the paper itself optimizes:
#   ce_softmax     — streaming fused softmax-CE over a vocab shard (§3.2's
#                    ">80% of the time" softmax stage; fwd + bwd)
#   sparse_ce      — fused active-class gather + CE (dynamic class
#                    selection; knn / selective / sampled candidate sets)
#   knn_dist_topk  — fused distance + running top-k' (graph build §3.2.2)
#   topk_dc        — divide-and-conquer top-k stage 1 (Fig. 5; DGC + top-k
#                    serving)
#   ops            — jit'd public wrappers + custom VJPs (the only module
#                    the rest of the repo imports)
#   ref            — pure-jnp oracles for the tests
# Heads select this path with HeadConfig.backend="pallas"; docs/kernels.md
# has the inventory, the VJP seam, and the interpret-mode caveat.
