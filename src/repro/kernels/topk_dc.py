"""Pallas TPU kernel: stage 1 of divide-and-conquer top-k (paper Fig. 5).

The paper's DGC bottleneck is selecting top-k from a large flat gradient
tensor. Their fix: split into M chunks, select top-k per chunk in parallel
(this kernel), then top-k over the M*k survivors (tiny — stage 2 in ops.py).
Exact, no sampling.

TPU mapping: the flat tensor is reshaped [M, C]; the grid tiles M into
row-blocks resident in VMEM; per row, k max-extraction sweeps over the lane
dimension (k is small and static, so the sweeps unroll onto the VPU; C is a
multiple of 128 lanes after padding). No HBM round-trip between the k sweeps
— that's the win over k separate jnp.max calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -jnp.inf


def _stage1_kernel(x_ref, vals_ref, idx_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)           # [bm, C]
    bm, c = x.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (bm, c), 1)
    for i in range(k):                           # k static -> unrolled sweeps
        m = jnp.max(x, axis=1)                   # [bm]
        am = jnp.argmax(x, axis=1).astype(jnp.int32)
        vals_ref[:, i] = m
        idx_ref[:, i] = am
        x = jnp.where(col == am[:, None], NEG, x)


def stage1_topk(chunks: jax.Array, k: int, *, block_rows: int = 8,
                interpret: bool = True):
    """chunks: [M, C] -> (vals [M, k] fp32 desc-sorted, idx [M, k] int32)."""
    m, c = chunks.shape
    pad_m = (-m) % block_rows
    if pad_m:
        chunks = jnp.pad(chunks, ((0, pad_m), (0, 0)), constant_values=NEG)
    mp = chunks.shape[0]
    grid = (mp // block_rows,)
    vals, idx = pl.pallas_call(
        functools.partial(_stage1_kernel, k=k),
        out_shape=(jax.ShapeDtypeStruct((mp, k), jnp.float32),
                   jax.ShapeDtypeStruct((mp, k), jnp.int32)),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, c), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((block_rows, k), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, k), lambda i: (i, 0))),
        interpret=interpret,
    )(chunks)
    return vals[:m], idx[:m]
