"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_flat_ref(x: jax.Array, k: int):
    """Global top-k of a flat tensor: (vals desc, ids)."""
    vals, ids = jax.lax.top_k(x, k)
    return vals.astype(jnp.float32), ids.astype(jnp.int32)


def stage1_topk_ref(chunks: jax.Array, k: int):
    """Per-chunk top-k: chunks [M, C] -> (vals [M,k], idx [M,k])."""
    vals, idx = jax.lax.top_k(chunks.astype(jnp.float32), k)
    return vals, idx.astype(jnp.int32)


def dist_topk_ref(q: jax.Array, kmat: jax.Array, kprime: int,
                  col_offset: int = 0):
    """Fused scoring+topk oracle: cosine scores q @ kmat^T, row-wise top-k'."""
    s = (q.astype(jnp.float32) @ kmat.astype(jnp.float32).T)
    k_eff = min(kprime, kmat.shape[0])
    vals, ids = jax.lax.top_k(s, k_eff)
    if k_eff < kprime:
        pad = kprime - k_eff
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        ids = jnp.pad(ids, ((0, 0), (0, pad)), constant_values=-1 - col_offset)
    return vals, (ids + col_offset).astype(jnp.int32)


def ce_stats_ref(f, w, y, scale: float = 1.0):
    """Oracle for ce_forward: per-row (max, z, label logit)."""
    s = f.astype(jnp.float32) @ w.astype(jnp.float32).T * scale
    m = jnp.max(s, axis=1)
    z = jnp.sum(jnp.exp(s - m[:, None]), axis=1)
    v = w.shape[0]
    yc = jnp.clip(y, 0, v - 1)
    corr = jnp.take_along_axis(s, yc[:, None], axis=1)[:, 0]
    corr = jnp.where((y >= 0) & (y < v), corr, 0.0)
    return m, z, corr


def ce_loss_ref(f, w, y, scale: float = 1.0):
    """Mean CE over rows with in-shard labels only (single-shard oracle)."""
    m, z, corr = ce_stats_ref(f, w, y, scale)
    return jnp.mean(jnp.log(z) + m - corr)


def ce_grads_ref(f, w, y, scale: float = 1.0):
    return jax.grad(
        lambda f_, w_: ce_loss_ref(f_, w_, y, scale), argnums=(0, 1)
    )(f.astype(jnp.float32), w.astype(jnp.float32))
