"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only (TPU v5e is
the compile target); on real TPU pass interpret=False (or set
REPRO_PALLAS_INTERPRET=0).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ce_softmax as _ce
from repro.kernels import knn_dist_topk as _dk
from repro.kernels import topk_dc as _dc

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


# ---------------------------------------------------------------------------
# divide-and-conquer top-k (paper Fig. 5)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_rows"))
def topk_dc(x: jax.Array, k: int, *, chunk: int = 2048, block_rows: int = 8):
    """Exact top-k of a flat tensor via chunked two-stage selection.
    Returns (vals [k] desc, ids [k] int32 into x)."""
    n = x.shape[0]
    if n <= chunk:
        vals, ids = jax.lax.top_k(x.astype(jnp.float32), min(k, n))
        return vals, ids.astype(jnp.int32)
    pad = (-n) % chunk
    xp = jnp.pad(x.astype(jnp.float32), (0, pad), constant_values=-jnp.inf)
    chunks = xp.reshape(-1, chunk)
    kk = min(k, chunk)
    sub_v, sub_i = _dc.stage1_topk(chunks, kk, block_rows=block_rows,
                                   interpret=INTERPRET)        # stage 1
    base = (jnp.arange(chunks.shape[0], dtype=jnp.int32) * chunk)[:, None]
    flat_v = sub_v.reshape(-1)
    flat_i = (sub_i + base).reshape(-1)
    vals, pos = jax.lax.top_k(flat_v, min(k, flat_v.shape[0]))  # stage 2
    return vals, flat_i[pos]


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_rows"))
def topk_threshold(x_abs: jax.Array, k: int, *, chunk: int = 2048,
                   block_rows: int = 8):
    """k-th largest value (DGC threshold) via the d&c kernel."""
    vals, _ = topk_dc(x_abs, k, chunk=chunk, block_rows=block_rows)
    return vals[-1]


# ---------------------------------------------------------------------------
# fused distance + top-k' (graph build inner loop)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("kprime", "block_q", "block_n",
                                             "col_offset"))
def dist_topk(q: jax.Array, kmat: jax.Array, kprime: int, *,
              block_q: int = 128, block_n: int = 128, col_offset: int = 0):
    return _dk.dist_topk(q, kmat, kprime, block_q=block_q, block_n=block_n,
                         col_offset=col_offset, interpret=INTERPRET)


# ---------------------------------------------------------------------------
# fused streaming softmax-CE (the paper's softmax stage)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def fused_ce(f, w, y, scale: float = 1.0, block_v: int = 512):
    """Mean CE of rows whose label is in-shard; [B,V] never materializes.
    f [B,D], w [V,D], y [B] local ids (-1/out-of-range = not owned here)."""
    m, z, corr = _ce.ce_forward(f, w, y, block_v=block_v, scale=scale,
                                interpret=INTERPRET)
    owned = (y >= 0) & (y < w.shape[0])
    per = jnp.log(z) + m - jnp.where(owned, corr, 0.0)
    return jnp.mean(per)


def _fused_ce_fwd(f, w, y, scale, block_v):
    m, z, corr = _ce.ce_forward(f, w, y, block_v=block_v, scale=scale,
                                interpret=INTERPRET)
    owned = (y >= 0) & (y < w.shape[0])
    per = jnp.log(z) + m - jnp.where(owned, corr, 0.0)
    return jnp.mean(per), (f, w, y, m, z)


def _fused_ce_bwd(scale, block_v, res, g):
    f, w, y, m, z = res
    b = f.shape[0]
    gv = jnp.full((b,), g / b, jnp.float32)
    df, dw = _ce.ce_backward(f, w, y, m, z, gv, block_v=block_v, scale=scale,
                             interpret=INTERPRET)
    return df.astype(f.dtype), dw.astype(w.dtype), None


fused_ce.defvjp(_fused_ce_fwd, _fused_ce_bwd)


@functools.partial(jax.jit, static_argnames=("scale", "block_v"))
def fused_ce_stats(f, w, y, *, scale: float = 1.0, block_v: int = 512):
    """(m, z, corr) building blocks for the distributed (sharded) loss."""
    return _ce.ce_forward(f, w, y, block_v=block_v, scale=scale,
                          interpret=INTERPRET)
