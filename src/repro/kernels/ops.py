"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True because this container is CPU-only (TPU v5e is
the compile target); on real TPU pass interpret=False (or set
REPRO_PALLAS_INTERPRET=0).

The two CE entry points the heads consume are ``ce_shard_stats`` (dense
vocab-shard sweep) and ``sparse_ce_stats`` (active-class gather + CE). Both
are ``jax.custom_vjp`` over per-row ONLINE-SOFTMAX STATS (m, z, corr, amax)
rather than over a scalar loss: the distributed completion (pmax/psum across
model shards, metrics) is plain jnp in ``core.sharded_softmax``, and its
autodiff delivers the per-row cotangents (gz, gc) that the streaming
backward kernels consume. The running max m is non-differentiable by
construction — its true total derivative cancels exactly against z's
internal rescaling (z is Σ exp(s - m), so z·e^m is m-free), which is why the
backward kernels can ignore its cotangent and still be exact.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ce_softmax as _ce
from repro.kernels import ivf_rerank as _ir
from repro.kernels import knn_dist_topk as _dk
from repro.kernels import sparse_ce as _sp
from repro.kernels import topk_dc as _dc

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


# ---------------------------------------------------------------------------
# divide-and-conquer top-k (paper Fig. 5)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_rows"))
def topk_dc(x: jax.Array, k: int, *, chunk: int = 2048, block_rows: int = 8):
    """Exact top-k of a flat tensor via chunked two-stage selection.
    Returns (vals [k] desc, ids [k] int32 into x)."""
    n = x.shape[0]
    if n <= chunk:
        vals, ids = jax.lax.top_k(x.astype(jnp.float32), min(k, n))
        return vals, ids.astype(jnp.int32)
    pad = (-n) % chunk
    xp = jnp.pad(x.astype(jnp.float32), (0, pad), constant_values=-jnp.inf)
    chunks = xp.reshape(-1, chunk)
    kk = min(k, chunk)
    sub_v, sub_i = _dc.stage1_topk(chunks, kk, block_rows=block_rows,
                                   interpret=INTERPRET)        # stage 1
    base = (jnp.arange(chunks.shape[0], dtype=jnp.int32) * chunk)[:, None]
    flat_v = sub_v.reshape(-1)
    flat_i = (sub_i + base).reshape(-1)
    vals, pos = jax.lax.top_k(flat_v, min(k, flat_v.shape[0]))  # stage 2
    return vals, flat_i[pos]


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_rows"))
def topk_threshold(x_abs: jax.Array, k: int, *, chunk: int = 2048,
                   block_rows: int = 8):
    """k-th largest value (DGC threshold) via the d&c kernel."""
    vals, _ = topk_dc(x_abs, k, chunk=chunk, block_rows=block_rows)
    return vals[-1]


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_rows"))
def topk_rows(x: jax.Array, k: int, *, chunk: int = 2048,
              block_rows: int = 8):
    """Row-wise exact top-k of x [B, N] via the stage-1 kernel: each row is
    chunked, per-chunk top-k runs in parallel on the kernel, and a tiny
    stage-2 ``lax.top_k`` merges the survivors. Returns (vals [B, k] desc,
    ids [B, k] int32 column indices). Powers the top-k serving path."""
    b, n = x.shape
    kk = min(k, n)
    if n <= chunk:
        vals, ids = _dc.stage1_topk(x, kk, block_rows=block_rows,
                                    interpret=INTERPRET)
        return vals[:, :kk], ids[:, :kk]
    pad = (-n) % chunk
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad)),
                 constant_values=-jnp.inf)
    nch = xp.shape[1] // chunk
    chunks = xp.reshape(b * nch, chunk)
    kc = min(kk, chunk)
    sub_v, sub_i = _dc.stage1_topk(chunks, kc, block_rows=block_rows,
                                   interpret=INTERPRET)
    base = (jnp.arange(nch, dtype=jnp.int32) * chunk)[None, :, None]
    flat_v = sub_v.reshape(b, nch * kc)
    flat_i = (sub_i.reshape(b, nch, kc) + base).reshape(b, nch * kc)
    vals, pos = jax.lax.top_k(flat_v, kk)
    return vals, jnp.take_along_axis(flat_i, pos, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "block_a"))
def ivf_rerank(f, w, cand, k: int, *, block_a: int = 128):
    """Fused gather + per-row top-k over IVF candidate lists (the serving
    index's rerank stage). f [B, D]; w [V_loc, D] — candidate rows are
    gathered in-kernel; cand [B, A] int32 local row ids, -1 = empty slot.
    Returns (vals [B, k] fp32 desc, ids [B, k] int32 row ids, -1 when a row
    has fewer than k candidates). Neither the gathered [A, D] weights nor
    the [B, A] scores reach HBM."""
    return _ir.ivf_rerank(f, w, cand, k, block_a=block_a,
                          interpret=INTERPRET)


# ---------------------------------------------------------------------------
# fused distance + top-k' (graph build inner loop)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("kprime", "block_q", "block_n",
                                             "col_offset"))
def dist_topk(q: jax.Array, kmat: jax.Array, kprime: int, *,
              block_q: int = 128, block_n: int = 128, col_offset: int = 0):
    return _dk.dist_topk(q, kmat, kprime, block_q=block_q, block_n=block_n,
                         col_offset=col_offset, interpret=INTERPRET)


# ---------------------------------------------------------------------------
# fused streaming softmax-CE (the paper's softmax stage)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def ce_shard_stats(f, w, y, limit, scale: float = 1.0, block_v: int = 512):
    """Streaming online-softmax stats of f [B,D] against the vocab shard
    w [V,D]: per-row (m, z, corr, amax). y [B] are LOCAL ids (-1 / out of
    range = label not owned by this shard); ``limit`` (traced int scalar)
    masks columns >= limit (Megatron vocab padding). The [B, V] logit tensor
    never materializes; m and amax are non-differentiable statistics."""
    return _ce.ce_forward(f, w, y, limit=limit, scale=scale, block_v=block_v,
                          interpret=INTERPRET)


def _ce_shard_fwd(f, w, y, limit, scale, block_v):
    m, z, corr, amax = _ce.ce_forward(f, w, y, limit=limit, scale=scale,
                                      block_v=block_v, interpret=INTERPRET)
    return (m, z, corr, amax), (f, w, y, limit, m)


def _ce_shard_bwd(scale, block_v, res, cts):
    f, w, y, limit, m = res
    _, gz, gc, _ = cts          # gm / gamax ignored: exact (see module doc)
    df, dw = _ce.ce_backward(f, w, y, m, gz, gc, limit=limit, scale=scale,
                             block_v=block_v, interpret=INTERPRET)
    return df.astype(f.dtype), dw.astype(w.dtype), None, None


ce_shard_stats.defvjp(_ce_shard_fwd, _ce_shard_bwd)


@functools.partial(jax.jit, static_argnames=("scale", "block_v"))
def fused_ce(f, w, y, scale: float = 1.0, block_v: int = 512):
    """Mean CE of rows whose label is in-shard; [B,V] never materializes.
    f [B,D], w [V,D], y [B] local ids (-1/out-of-range = not owned here).
    Single-shard convenience over ``ce_shard_stats`` (grads flow through its
    custom_vjp)."""
    v = w.shape[0]
    m, z, corr, _ = ce_shard_stats(f, w, y, jnp.asarray(v, jnp.int32),
                                   scale, block_v)
    per = jnp.log(z) + m - corr      # corr is 0 for unowned rows
    return jnp.mean(per)


@functools.partial(jax.jit, static_argnames=("scale", "block_v"))
def fused_ce_stats(f, w, y, *, scale: float = 1.0, block_v: int = 512):
    """(m, z, corr) building blocks for the distributed (sharded) loss."""
    m, z, corr, _ = _ce.ce_forward(f, w, y, scale=scale, block_v=block_v,
                                   interpret=INTERPRET)
    return m, z, corr


# ---------------------------------------------------------------------------
# active-class sparse CE (KNN / selective / sampled candidate sets)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9))
def sparse_ce_stats(f, w, ids, gids, bias, valid, y, scale: float = 1.0,
                    block_a: int = 128, mask_hits: bool = False):
    """Fused gather + streaming CE stats over an active-class set.

    f [B,D]; w [V_loc,D] (full local shard — rows are gathered in-kernel);
    ids [A] local candidate rows; gids [A] global candidate ids; bias [A]
    per-column logit shift (-logQ for sampled, zeros otherwise); valid [A]
    column mask; y [B] GLOBAL labels. ``mask_hits`` drops candidates whose
    gid equals the row label from z (sampled accidental hits) instead of
    folding them into corr (knn / selective label columns).

    Returns per-row fp32 (m, z, corr, amax-col); m / amax non-diff. Only f
    and w receive gradients; dW is a compact [A, D] kernel output
    scatter-added into the shard here."""
    return _sp.sparse_ce_forward(f, w, ids, gids, bias, valid, y,
                                 scale=scale, block_a=block_a,
                                 mask_hits=mask_hits, interpret=INTERPRET)


def _sparse_ce_fwd(f, w, ids, gids, bias, valid, y, scale, block_a,
                   mask_hits):
    m, z, corr, amax = _sp.sparse_ce_forward(
        f, w, ids, gids, bias, valid, y, scale=scale, block_a=block_a,
        mask_hits=mask_hits, interpret=INTERPRET)
    return (m, z, corr, amax), (f, w, ids, gids, bias, valid, y, m)


def _sparse_ce_bwd(scale, block_a, mask_hits, res, cts):
    f, w, ids, gids, bias, valid, y, m = res
    _, gz, gc, _ = cts          # gm / gamax ignored: exact (see module doc)
    df, dwa = _sp.sparse_ce_backward(
        f, w, ids, gids, bias, valid, y, m, gz, gc, scale=scale,
        block_a=block_a, mask_hits=mask_hits, interpret=INTERPRET)
    safe = jnp.clip(ids.astype(jnp.int32), 0, w.shape[0] - 1)
    dw = jnp.zeros(w.shape, jnp.float32).at[safe].add(dwa)
    return (df.astype(f.dtype), dw.astype(w.dtype), None, None, None, None,
            None)


sparse_ce_stats.defvjp(_sparse_ce_fwd, _sparse_ce_bwd)
