"""Pallas TPU kernel: fused gather + top-k rerank for the IVF serving index.

The IVF serve path (``repro.serving.index``) probes the top-``nprobe``
k-means centroids per query and then scores ONLY the member rows of the
probed clusters. The ref path gathers ``w[cand]`` to a [B, A, D] tensor in
HBM, matmuls to dense [B, A] scores, and runs ``lax.top_k``. This kernel
fuses all three stages, reusing the two idioms already proven in this repo:

  * the per-row dynamic-slice gather of ``sparse_ce`` (candidate ids live
    in SMEM, the full [V_loc, D] shard stays whole in kernel memory, and a
    fori_loop of row slices — per-row DMAs on hardware — fills a [ba, D]
    VMEM scratch tile);
  * the k max-extraction sweeps of ``topk_dc`` stage 1 (k is small and
    static, so the sweeps unroll onto the VPU).

The grid is (query, candidate-tile); the running top-k accumulator IS the
output block (same block for every tile step → revisited in place, the
standard sequential-grid accumulator pattern). Per tile the fresh scores
are concatenated with the current top-k and k sweeps re-extract the best k
— neither the gathered [A, D] weights nor the [B, A] score tensor ever
reach HBM.

Candidate slots of -1 are padding (short clusters); they score -inf and
come back as id -1 when a row has fewer than k real candidates, matching
the ref path bit-for-bit on ids. Wrapped by ``ops.ivf_rerank``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -jnp.inf


def _rerank_kernel(ids_ref, f_ref, w_ref, cand_ref, vals_ref, idx_ref, tile,
                   *, ba: int, k: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        vals_ref[...] = jnp.full_like(vals_ref, NEG)
        idx_ref[...] = jnp.full_like(idx_ref, -1)

    def body(r, _):
        tile[pl.ds(r, 1), :] = w_ref[pl.ds(ids_ref[b, j * ba + r], 1), :]
        return 0
    jax.lax.fori_loop(0, ba, body, 0)

    f = f_ref[...]                                        # [1, D]
    s = jax.lax.dot_general(f, tile[...], (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [1, ba]
    cand = cand_ref[...]                                  # [1, ba]; -1 = pad
    s = jnp.where(cand >= 0, s, NEG)

    # merge the tile into the running top-k: k unrolled max-extraction
    # sweeps over [current top-k ++ tile scores] (topk_dc stage-1 style)
    cat_v = jnp.concatenate([vals_ref[...], s], axis=1)   # [1, k + ba]
    cat_i = jnp.concatenate([idx_ref[...], cand], axis=1)
    col = jax.lax.broadcasted_iota(jnp.int32, cat_v.shape, 1)
    vals = jnp.full(vals_ref.shape, NEG, jnp.float32)
    idxs = jnp.full(idx_ref.shape, -1, jnp.int32)
    for i in range(k):
        m = jnp.max(cat_v, axis=1)                        # [1]
        am = jnp.argmax(cat_v, axis=1).astype(jnp.int32)
        picked = jnp.take_along_axis(cat_i, am[:, None], axis=1)[:, 0]
        # a -inf max means the row ran out of real candidates: the slot
        # must surface as id -1 (never a stale duplicate of a real id)
        picked = jnp.where(jnp.isfinite(m), picked, -1)
        vals = vals.at[:, i].set(m)
        idxs = idxs.at[:, i].set(picked)
        cat_v = jnp.where(col == am[:, None], NEG, cat_v)
    vals_ref[...] = vals
    idx_ref[...] = idxs


def ivf_rerank(f, w, cand, k: int, *, block_a: int = 128,
               interpret: bool = True):
    """f [B, D]; w [V_loc, D] (rows gathered in-kernel); cand [B, A] int32
    local row ids with -1 marking empty slots. Returns (vals [B, k] fp32
    descending, ids [B, k] int32 row ids, -1 where a row has fewer than k
    real candidates)."""
    b, d = f.shape
    v = w.shape[0]
    a = cand.shape[1]
    ba = min(block_a, max(8, a))
    pa = (-a) % ba
    cand = cand.astype(jnp.int32)
    if pa:
        cand = jnp.pad(cand, ((0, 0), (0, pa)), constant_values=-1)
    ap = a + pa
    safe = jnp.clip(cand, 0, v - 1)                       # clip-safe gather
    vals, idx = pl.pallas_call(
        functools.partial(_rerank_kernel, ba=ba, k=k),
        out_shape=(jax.ShapeDtypeStruct((b, k), jnp.float32),
                   jax.ShapeDtypeStruct((b, k), jnp.int32)),
        grid=(b, ap // ba),
        in_specs=[pl.BlockSpec(memory_space=pltpu.SMEM),
                  pl.BlockSpec((1, d), lambda i, j: (i, 0)),
                  pl.BlockSpec(memory_space=pltpu.VMEM),
                  pl.BlockSpec((1, ba), lambda i, j: (i, j))],
        out_specs=(pl.BlockSpec((1, k), lambda i, j: (i, 0)),
                   pl.BlockSpec((1, k), lambda i, j: (i, 0))),
        scratch_shapes=[pltpu.VMEM((ba, d), jnp.float32)],
        interpret=interpret,
    )(safe, f.astype(jnp.float32), w.astype(jnp.float32), cand)
    return vals, idx
