"""Pallas TPU kernel: fused flash attention (forward).

§Perf pair-1 finding (EXPERIMENTS.md): the pure-JAX flash path is memory-
bound because every [q_block, kv_block] probability tile crosses an XLA
fusion boundary (HBM round-trip) — at prefill_32k that's ~2.3 TB/device of
prob traffic vs 0.8 s of matmul work. The structural fix is this kernel:
the score/prob tile lives ONLY in VMEM; HBM sees q, k, v, o exactly once.

Layout: inputs flattened to [BH, S, Dh]; grid = (BH, q_blocks, kv_blocks)
with the kv dimension innermost; VMEM scratch carries the online-softmax
(m, l, acc) across the kv sweep and the output flushes on the last tile.
Causality lets the sweep skip nothing here (masked tiles still counted) —
block-level skipping is a further ~2x (documented, not implemented).

This container is CPU-only: the kernel is validated in interpret mode
against the pure-jnp oracle; the GSPMD dry-run keeps the jnp path because
Pallas cannot lower for TPU on a CPU backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr, *,
                  bq: int, bkv: int, scale: float, causal: bool,
                  window: int, n_valid: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)

    q = q_ref[0]                                  # [bq, Dh]
    k = k_ref[0]                                  # [bkv, Dh]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    valid = kpos < n_valid
    if causal:
        valid &= kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, -jnp.inf)

    m_old = m_scr[...][:, 0]                      # [bq]
    m_new = jnp.maximum(m_old, jnp.max(s, axis=1))
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(jnp.where(valid, s - safe_m[:, None], -jnp.inf))  # [bq,bkv]
    corr = jnp.where(jnp.isfinite(m_old), jnp.exp(m_old - safe_m), 0.0)
    l_scr[...] = (l_scr[...][:, 0] * corr + jnp.sum(p, axis=1))[:, None]
    acc[...] = acc[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new[:, None]

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        l = l_scr[...][:, 0]
        o_ref[0] = (acc[...] / jnp.maximum(l, 1e-30)[:, None]).astype(
            o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = True):
    """q: [BH, Sq, Dh]; k, v: [BH, T, Dh] -> [BH, Sq, Dh].

    GQA is handled by the caller repeating/reshaping heads into BH.
    """
    bh, sq, dh = q.shape
    t = k.shape[1]
    scale = 1.0 / (dh ** 0.5)
    pq, pk = (-sq) % block_q, (-t) % block_kv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    sqp, tp = q.shape[1], k.shape[1]
    grid = (bh, sqp // block_q, tp // block_kv)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=block_q, bkv=block_kv,
                          scale=scale, causal=causal, window=window,
                          n_valid=t),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, dh), q.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, block_kv, dh), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, block_kv, dh), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[pltpu.VMEM((block_q, dh), jnp.float32),
                        pltpu.VMEM((block_q, 1), jnp.float32),
                        pltpu.VMEM((block_q, 1), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
