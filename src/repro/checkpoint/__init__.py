from repro.checkpoint.checkpoint import (all_steps, latest_step, prune,
                                         restore, save)

__all__ = ["save", "restore", "latest_step", "all_steps", "prune"]
