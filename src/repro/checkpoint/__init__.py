from repro.checkpoint.checkpoint import (all_steps, latest_step, prune,
                                         read_meta, restore, save,
                                         validate_restore)

__all__ = ["save", "restore", "latest_step", "all_steps", "prune",
           "read_meta", "validate_restore"]
