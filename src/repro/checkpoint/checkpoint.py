"""Checkpointing: msgpack + zstd of flattened pytrees.

Arrays are gathered to host (fully-addressable single-process here; on a real
multi-host pod each host would write its addressable shards — the format
already keys leaves by tree path, so per-shard files compose). Restore takes
a ``target`` template pytree (params/opt-state structure with NamedTuples)
and refills its leaves, preserving shardings via device_put-like placement by
the caller.

zstd is optional: containers without the ``zstandard`` wheel fall back to
stdlib zlib. Restore sniffs the frame magic, so either side can read files
written by the other.
"""
from __future__ import annotations

import os
import re
import zlib
from typing import Any, Optional

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # container without the wheel: stdlib fallback
    zstandard = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, level=6)


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError("checkpoint is zstd-compressed but the "
                               "'zstandard' module is unavailable")
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: Any, step: int = 0,
         keep: Optional[int] = None, meta: Optional[dict] = None) -> str:
    """Write ``<path>/ckpt_<step>.msgpack.zst``. Returns the file path.

    The write is atomic (tmp file + ``os.replace``): a run killed mid-write
    never leaves a truncated checkpoint behind for ``latest_step`` to find.
    ``keep=N`` prunes all but the N highest-step files AFTER the new file is
    durable (oldest steps first — a long-run cadence must not fill the
    disk); ``keep=None``/0 retains everything. ``meta`` is a small
    msgpack-able dict stored alongside the leaves — the trainers record
    their mesh geometry here so ``validate_restore`` can reject (or
    ``repro.elastic`` can reshard) a mismatched restore up front.
    """
    os.makedirs(path, exist_ok=True)
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    payload = {"step": step, "meta": dict(meta or {}), "leaves": {}}
    for kp, leaf in leaves_with_paths:
        arr = np.asarray(jax.device_get(leaf))
        payload["leaves"][_key_str(kp)] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": arr.tobytes()}
    raw = msgpack.packb(payload, use_bin_type=True)
    fname = os.path.join(path, f"ckpt_{step}.msgpack.zst")
    tmp = fname + f".tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            f.write(_compress(raw))
        os.replace(tmp, fname)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    if keep:
        prune(path, keep)
    return fname


def all_steps(path: str) -> list:
    """Sorted step numbers of every checkpoint under ``path``."""
    if not os.path.isdir(path):
        return []
    return sorted(int(m.group(1)) for fn in os.listdir(path)
                  if (m := re.match(r"ckpt_(\d+)\.msgpack\.zst$", fn)))


def prune(path: str, keep: int) -> list:
    """Delete all but the ``keep`` highest-step checkpoint files. Returns
    the pruned step numbers (ascending — oldest removed first)."""
    if keep < 1:
        raise ValueError(f"keep must be >= 1, got {keep}")
    doomed = all_steps(path)[:-keep] if keep else []
    for s in doomed:
        os.remove(os.path.join(path, f"ckpt_{s}.msgpack.zst"))
    return doomed


def latest_step(path: str) -> Optional[int]:
    steps = all_steps(path)
    return steps[-1] if steps else None


def read_meta(path: str, step: Optional[int] = None) -> Optional[dict]:
    """The geometry/meta dict stored with a checkpoint (``save(meta=...)``)
    — None for files written before meta existed (those can only assert
    same-mesh restores; there is nothing to validate against)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    fname = os.path.join(path, f"ckpt_{step}.msgpack.zst")
    with open(fname, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    return payload.get("meta") or None


def validate_restore(path: str, expect, step: Optional[int] = None, *,
                     reshard: bool = False):
    """Up-front geometry check BEFORE any leaf is decoded or placed.

    ``expect`` is the restoring experiment's ``repro.elastic.MeshGeometry``.
    Raises ``repro.elastic.ReshardError`` naming both geometries when the
    class count differs (never reshardable) or when the mesh shape differs
    and ``reshard`` was not requested — instead of the shape error the
    mismatch used to hit deep inside jax. Returns the checkpoint's stored
    geometry (== ``expect`` for pre-meta checkpoints).
    """
    from repro.elastic.plan import geometry_from_meta, validate_geometry
    meta = read_meta(path, step)
    src = geometry_from_meta(meta, expect)
    validate_geometry(src, expect, reshard=reshard)
    return src


def restore(path: str, target: Any, step: Optional[int] = None):
    """Refill ``target``'s leaves from a checkpoint. Returns (tree, step)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    fname = os.path.join(path, f"ckpt_{step}.msgpack.zst")
    with open(fname, "rb") as f:
        raw = _decompress(f.read())
    payload = msgpack.unpackb(raw, raw=False)
    stored = payload["leaves"]
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    new_leaves = []
    for kp, leaf in leaves_with_paths:
        key = _key_str(kp)
        if key not in stored:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = stored[key]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves), payload["step"]
