"""Pluggable softmax-head strategies (the paper's §3.2/§4.1 comparison as an
API).

The KDD'20 paper's core claim is a *comparison* of softmax variants — full,
KNN softmax, selective softmax [Zhang et al., AAAI'18], MACH [Medini et al.,
NeurIPS'19], plus the sampled-softmax [Jean et al., ACL'15] and CSoft
count-min-sketch baselines — trained under identical hybrid-parallel
conditions. This module makes the head a first-class strategy so any head
composes with any trainer and any mesh:

  * ``SoftmaxHead`` — the protocol. A head owns its trainable params AND its
    auxiliary (non-trainable) state as pytrees, provides the
    ``PartitionSpec``s that place both on a mesh, a shard_map-compatible
    ``loss_local`` body, a distributed ``eval_logits_local`` prediction body,
    its metrics spec, and an optional ``refresh`` for periodic work (KNN
    graph rebuilds, LSH table rebuilds).
  * ``HEAD_REGISTRY`` / ``register_head`` / ``make_head`` — the registry
    keyed by ``HeadConfig.softmax_impl``; new heads plug in with
    ``@register_head`` and no trainer changes (see docs/heads.md for the
    authoring guide).

Trainers (``repro.train.hybrid`` faithfully, ``repro.train.gspmd`` for the
zoo) call heads only through this protocol — no ``use_knn`` booleans, no
head-specific branches.

Every head additionally honors ``HeadConfig.backend`` ("ref" | "pallas"):
the strategy threads the choice down into its distributed body, which runs
the softmax-stage hotspot either as plain XLA or through the fused Pallas
kernels (streaming CE for the dense heads, active-class sparse CE for the
selection heads) — see docs/kernels.md. Trainers stay untouched: the
backend is a head concern, selected per-config like the head itself.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import HeadConfig, ModelConfig, effective_vocab
from repro.core import baselines as bl
from repro.core import knn_graph as kg
from repro.core.knn_softmax import knn_softmax_local
from repro.core.sharded_softmax import (_normalize, full_softmax_local,
                                        serve_argmax_local,
                                        serve_logits_local)


class HeadState(NamedTuple):
    """A head's state: ``params`` are trained by the outer optimizer,
    ``aux`` is head-owned non-trainable state (graphs, hash tables, ...)."""
    params: Any
    aux: Any


class SoftmaxHead:
    """Base strategy. Subclasses are stateless objects bound to configs;
    all array state lives in the ``HeadState`` they create."""

    name = "?"
    # True when the head's trainable params ARE the [V, D] class-weight
    # matrix. The zoo (GSPMD) trainer then feeds ``lm.head_weight(params)``
    # (tied embedding or params["head"]) and trains it as part of the model;
    # sketch heads (mach / csoft) set False and the zoo threads
    # ``HeadState.params`` as an extra trainable pytree instead.
    params_are_class_weights = True

    def __init__(self, model_cfg: ModelConfig, head_cfg: HeadConfig):
        self.model_cfg = model_cfg
        self.head_cfg = head_cfg
        self.n_classes = model_cfg.vocab_size
        self.d = model_cfg.d_model
        # padded-vocab masking (Megatron-style): labels < n_valid always
        self.n_valid = (effective_vocab(model_cfg)
                        if model_cfg.real_vocab_size else 0)
        # compute backend for the hot bodies: "ref" (XLA) | "pallas" (fused
        # kernels); the VMEM blocking knobs ride along
        self.backend = head_cfg.backend
        self.block_v = head_cfg.pallas_block_v
        self.block_a = head_cfg.pallas_block_a

    # -- state ------------------------------------------------------------
    def init(self, key, n_dev: int) -> HeadState:
        raise NotImplementedError

    def init_aux(self, key, n_dev: int):
        """Aux-only init, for trainers that own the class weights elsewhere
        (the zoo's W-heads). Default falls back to a full ``init`` and
        discards the params; heads override to avoid the throwaway draw."""
        return self.init(key, n_dev).aux

    def params_spec(self, model_axis):
        """Pytree of PartitionSpecs matching ``state.params``."""
        raise NotImplementedError

    def aux_spec(self, model_axis):
        """Pytree of PartitionSpecs matching ``state.aux``."""
        return ()

    # -- shard_map bodies -------------------------------------------------
    def loss_local(self, f_all, y_all, params, aux, *, model_axis,
                   batch_axes, global_batch: int, step=None):
        """Distributed CE on one device's shard. ``f_all``/``y_all`` are the
        ring-gathered (global) batch; ``step`` is the replicated training-
        step scalar (for heads with per-step randomness; may be None).
        Returns (loss, metrics)."""
        raise NotImplementedError

    def eval_logits_local(self, f_all, params, aux, *, model_axis):
        """Deploy-style prediction (§4.5 retrieval equivalence). Returns
        (pred [b] global class ids, local scores)."""
        raise NotImplementedError

    def metrics_spec(self) -> dict:
        return {"accuracy": P(), "logz": P()}

    # -- checkpoint contract ----------------------------------------------
    def state_to_save(self, state: HeadState):
        """Full-state snapshot pytree for the checkpoint layer: the head's
        trainable params AND its aux (KNN graph, LSH tables, CMS hashes /
        bucket weights). Aux is saved, not rebuilt, so a restore resumes
        mid-refresh-interval with the exact tables the killed run was
        using (docs/resilience.md)."""
        return {"params": state.params, "aux": state.aux}

    def state_from_restore(self, tree, mesh, *, model_axis) -> HeadState:
        """Re-place a restored ``state_to_save`` snapshot on ``mesh`` with
        the head's own PartitionSpecs. Shapes may differ from a fresh
        ``init`` (a refreshed KNN graph is denser than the warm-start
        self-graph); only the tree structure must match."""
        def put(subtree, spec):
            if not jax.tree.leaves(subtree):   # e.g. () params on the zoo
                return subtree
            return jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh, s)),
                subtree, spec)
        params = put(tree["params"], self.params_spec(model_axis))
        aux = put(tree["aux"], self.aux_spec(model_axis))
        return HeadState(params=params, aux=aux)

    # -- elastic resharding (repro.elastic) -------------------------------
    def reshard_state(self, tree, src, dst):
        """Map a host-side ``state_to_save`` snapshot written on the
        ``src`` mesh geometry onto ``dst`` (both
        ``repro.elastic.MeshGeometry``). Dense [V, D] params are stored as
        GLOBAL rows and pass through; heads whose aux bakes in the ring
        size override with an exact re-pack. Returns
        ``(tree, needs_refresh)`` — the default for aux without a re-pack
        rule re-initializes it shape-correct for the dst ring and asks the
        trainer to run the head's own ``refresh`` path after placement."""
        if src.n_model == dst.n_model or not jax.tree.leaves(tree["aux"]):
            return tree, False
        return dict(tree, aux=self.init_aux(jax.random.PRNGKey(0),
                                            dst.n_model)), True

    def reshard_params_like(self, arr, src, dst):
        """Reshard one optimizer-moment leaf shaped like ``params``.
        Identity for heads whose params are global [V, D] rows; sketch
        heads apply their bucket transfer so moments track params."""
        return arr

    # -- periodic work ----------------------------------------------------
    @property
    def refresh_every(self) -> int:
        """Steps between ``refresh`` calls; 0 = no periodic work."""
        return 0

    def refresh(self, mesh, head_state: HeadState, *,
                model_axis) -> HeadState:
        """Rebuild aux state from the current params (no-op by default)."""
        return head_state

    # -- shared helpers ---------------------------------------------------
    def _init_w(self, key, dtype=jnp.float32):
        return (jax.random.normal(key, (self.n_classes, self.d))
                / jnp.sqrt(self.d)).astype(dtype)


HEAD_REGISTRY: dict = {}


def register_head(name: str):
    def deco(cls):
        cls.name = name
        HEAD_REGISTRY[name] = cls
        return cls
    return deco


def make_head(model_cfg: ModelConfig, head_cfg: HeadConfig) -> SoftmaxHead:
    try:
        cls = HEAD_REGISTRY[head_cfg.softmax_impl]
    except KeyError:
        raise ValueError(
            f"unknown softmax_impl {head_cfg.softmax_impl!r}; registered: "
            f"{sorted(HEAD_REGISTRY)}") from None
    return cls(model_cfg, head_cfg)


# ---------------------------------------------------------------------------
# full softmax (paper baseline)
# ---------------------------------------------------------------------------


@register_head("full")
class FullSoftmaxHead(SoftmaxHead):
    """W [V, D] row-sharded; exact distributed softmax (§3.1)."""

    def init(self, key, n_dev: int) -> HeadState:
        return HeadState(params=self._init_w(key), aux=())

    def init_aux(self, key, n_dev: int):
        return ()

    def params_spec(self, model_axis):
        return P(model_axis, None)

    def loss_local(self, f_all, y_all, params, aux, *, model_axis,
                   batch_axes, global_batch, step=None):
        return full_softmax_local(
            f_all, y_all, params, model_axis=model_axis,
            batch_axes=batch_axes, global_batch=global_batch,
            cosine_scale=self.head_cfg.cosine_scale, n_valid=self.n_valid,
            backend=self.backend, block_v=self.block_v)

    def eval_logits_local(self, f_all, params, aux, *, model_axis):
        f = f_all.astype(jnp.float32)
        w = params.astype(jnp.float32)
        if self.head_cfg.cosine_scale > 0:
            # §4.5 retrieval equivalence holds for the normalized objective;
            # raw-trained heads (zoo LM full softmax) decode raw argmax
            f, w = _normalize(f), _normalize(w)
        if self.backend == "pallas":
            # streaming (max, argmax) stats — no [b, V_loc] logits in HBM
            return serve_argmax_local(f, w, model_axis=model_axis,
                                      n_valid=self.n_valid,
                                      block_v=self.block_v)
        return serve_logits_local(f, w, model_axis=model_axis,
                                  n_valid=self.n_valid)


# ---------------------------------------------------------------------------
# KNN softmax (the paper's contribution, §3.2)
# ---------------------------------------------------------------------------


@register_head("knn")
class KNNSoftmaxHead(FullSoftmaxHead):
    """Active classes from the compressed KNN graph of W; ``refresh``
    rebuilds the exact graph on the training devices (§3.2.2)."""

    def init(self, key, n_dev: int) -> HeadState:
        return HeadState(params=self._init_w(key),
                         aux=self.init_aux(key, n_dev))

    def init_aux(self, key, n_dev: int):
        # warm-start graph before the first refresh: self-only neighbor
        # lists (lossless by construction — every label selects itself);
        # needs no weights
        import numpy as np
        self_graph = np.arange(self.n_classes, dtype=np.int32)[:, None]
        cg = kg.compress_graph(self_graph, n_dev)
        return (cg.offsets, cg.neighbors, cg.ranks)

    def aux_spec(self, model_axis):
        return (P(model_axis, None),) * 3

    @property
    def refresh_every(self) -> int:
        return self.head_cfg.rebuild_every

    def refresh(self, mesh, head_state: HeadState, *,
                model_axis) -> HeadState:
        """Paper §3.2.2: suspend training, ring-build the exact KNN graph of
        the CURRENT class weights, compress per shard (host round-trip for
        CSR packing — an offline step in the paper)."""
        import numpy as np
        n_dev = mesh.shape[model_axis]
        graph = kg.build_graph_distributed(
            mesh, head_state.params, k=self.head_cfg.knn_k,
            kprime=self.head_cfg.knn_kprime, model_axis=model_axis,
            backend=self.backend)
        cg = kg.compress_graph(np.asarray(jax.device_get(graph)), n_dev)
        sh = NamedSharding(mesh, P(model_axis, None))
        aux = tuple(jax.device_put(a, sh)
                    for a in (cg.offsets, cg.neighbors, cg.ranks))
        return HeadState(params=head_state.params, aux=aux)

    def loss_local(self, f_all, y_all, params, aux, *, model_axis,
                   batch_axes, global_batch, step=None):
        offsets, neighbors, ranks = aux
        v_loc = params.shape[0]
        m_local = max(8, int(v_loc * self.head_cfg.active_frac))
        return knn_softmax_local(
            f_all, y_all, params, offsets, neighbors, ranks,
            model_axis=model_axis, batch_axes=batch_axes,
            global_batch=global_batch, m_local=m_local,
            k_cap=self.head_cfg.knn_k,
            cosine_scale=self.head_cfg.cosine_scale,
            pad_random=self.head_cfg.knn_pad_random, n_valid=self.n_valid,
            backend=self.backend, block_a=self.block_a)

    def metrics_spec(self) -> dict:
        return {"accuracy": P(), "logz": P(), "active_frac": P(),
                "label_recall": P()}

    def reshard_state(self, tree, src, dst):
        """Exact CSR re-pack: the per-shard graph compression is
        invertible (``ranks`` keeps original columns), so the restored
        graph — mid-refresh staleness included — is preserved bit-for-bit
        and n->m->n round-trips to the identity."""
        if src.n_model == dst.n_model:
            return tree, False
        from repro.elastic.reshard import repack_knn_aux
        return dict(tree, aux=repack_knn_aux(tree["aux"],
                                             dst.n_model)), False


# ---------------------------------------------------------------------------
# selective softmax [Zhang et al., AAAI'18] — LSH active classes
# ---------------------------------------------------------------------------


@register_head("selective")
class SelectiveSoftmaxHead(FullSoftmaxHead):
    """W [V, D] row-sharded + per-shard LSH tables; ``refresh`` rebuilds the
    tables on the current weights (the baseline's table-refresh cadence)."""

    def _build_tables(self, key, w, n_dev: int):
        return bl.build_sharded_lsh_tables(
            key, w, n_dev, self.head_cfg.selective_n_hash,
            self.head_cfg.selective_n_bits)

    def init(self, key, n_dev: int) -> HeadState:
        kw, kt = jax.random.split(key)
        w = self._init_w(kw)
        planes, offsets, classes = self._build_tables(kt, w, n_dev)
        return HeadState(params=w, aux=(planes, offsets, classes))

    def init_aux(self, key, n_dev: int):
        # shape-correct tables without a throwaway [V, D] weight draw (all
        # classes land in bucket 0); ``refresh`` rebuilds from the real
        # class weights before any training step uses them
        return self._build_tables(
            key, jnp.zeros((self.n_classes, self.d), jnp.float32), n_dev)

    def aux_spec(self, model_axis):
        return (P(), P(model_axis, None, None), P(model_axis, None, None))

    @property
    def refresh_every(self) -> int:
        return self.head_cfg.rebuild_every

    def refresh(self, mesh, head_state: HeadState, *,
                model_axis) -> HeadState:
        n_dev = mesh.shape[model_axis]
        w = jax.device_get(head_state.params)
        planes, offsets, classes = self._build_tables(
            jax.random.PRNGKey(41), jnp.asarray(w), n_dev)
        sh = NamedSharding(mesh, P(model_axis, None, None))
        aux = (jax.device_put(planes, NamedSharding(mesh, P())),
               jax.device_put(offsets, sh), jax.device_put(classes, sh))
        return HeadState(params=head_state.params, aux=aux)

    def loss_local(self, f_all, y_all, params, aux, *, model_axis,
                   batch_axes, global_batch, step=None):
        planes, offsets, classes = aux
        v_loc = params.shape[0]
        m_local = max(8, int(v_loc * self.head_cfg.active_frac))
        return bl.selective_softmax_local(
            f_all, y_all, params, planes, offsets, classes,
            model_axis=model_axis, batch_axes=batch_axes,
            global_batch=global_batch, m_local=m_local,
            cap=self.head_cfg.selective_cap,
            cosine_scale=self.head_cfg.cosine_scale,
            backend=self.backend, block_a=self.block_a)

    def metrics_spec(self) -> dict:
        return {"accuracy": P(), "logz": P(), "active_frac": P(),
                "label_recall": P()}

    def reshard_state(self, tree, src, dst):
        """Exact table re-pack: bucket assignments are a function of the
        replicated planes and the global W rows (mesh-independent), so the
        per-shard CSRs invert to a class->bucket map and re-sort per dst
        shard with the builder's own stable-sort semantics — bitwise what
        ``build_sharded_lsh_tables`` would emit for the same assignment."""
        if src.n_model == dst.n_model:
            return tree, False
        from repro.elastic.reshard import repack_lsh_aux
        return dict(tree, aux=repack_lsh_aux(tree["aux"],
                                             dst.n_model)), False


# ---------------------------------------------------------------------------
# MACH [Medini et al., NeurIPS'19] — R hashed B-way softmaxes
# ---------------------------------------------------------------------------


@register_head("mach")
class MACHSoftmaxHead(SoftmaxHead):
    """R independent bucket heads [R, B, D] with the BUCKET axis sharded
    over the model axis; static class->bucket hash tables replicated."""

    params_are_class_weights = False
    _hash_seed = 0          # universal-hash family seed (csoft uses 1)

    def _n_buckets(self, n_dev: int) -> int:
        # bucket axis must divide the ring
        b = self.head_cfg.mach_b
        return -(-b // n_dev) * n_dev

    def init(self, key, n_dev: int) -> HeadState:
        head = bl.init_mach(key, self.n_classes, self.d,
                            n_buckets=self._n_buckets(n_dev),
                            n_rep=self.head_cfg.mach_r,
                            seed=self._hash_seed)
        return HeadState(params=head.w, aux=(head.hashes,))

    def params_spec(self, model_axis):
        return P(None, model_axis, None)

    def aux_spec(self, model_axis):
        return (P(),)

    def reshard_state(self, tree, src, dst):
        """Keep the stored bucket weights AND hash tables verbatim when
        the stored bucket count still divides the dst ring (the loss reads
        B from the shard shape) — bitwise decode-equivalence. Otherwise
        re-bucket: re-hash classes with the SAME universal family at the
        new modulus and transfer each new bucket the mean of its member
        classes' old bucket weights (the lossy case; docs/resilience.md)."""
        import numpy as np
        w = np.asarray(jax.device_get(tree["params"]))
        if w.shape[1] % dst.n_model == 0:
            return tree, False
        from repro.elastic.reshard import rebucket_sketch
        b_dst = self._n_buckets(dst.n_model)
        h_new = bl.mach_hashes(self.n_classes, b_dst, n_rep=w.shape[0],
                               seed=self._hash_seed)
        w_new = rebucket_sketch(w, tree["aux"][0], h_new, b_dst)
        return dict(tree, params=jnp.asarray(w_new),
                    aux=(jnp.asarray(h_new),)), False

    def reshard_params_like(self, arr, src, dst):
        import numpy as np
        a = np.asarray(jax.device_get(arr))
        if a.ndim != 3 or a.shape[1] % dst.n_model == 0:
            return arr
        from repro.elastic.reshard import rebucket_sketch
        b_dst = self._n_buckets(dst.n_model)
        # both tables recompute deterministically from the family seed, so
        # moments get the identical transfer the params got
        h_old = bl.mach_hashes(self.n_classes, a.shape[1],
                               n_rep=a.shape[0], seed=self._hash_seed)
        h_new = bl.mach_hashes(self.n_classes, b_dst, n_rep=a.shape[0],
                               seed=self._hash_seed)
        return jnp.asarray(rebucket_sketch(a, h_old, h_new, b_dst))

    def loss_local(self, f_all, y_all, params, aux, *, model_axis,
                   batch_axes, global_batch, step=None):
        (hashes,) = aux
        return bl.mach_softmax_local(
            f_all, y_all, params, hashes, model_axis=model_axis,
            batch_axes=batch_axes, global_batch=global_batch,
            backend=self.backend, block_v=self.block_v)

    def eval_logits_local(self, f_all, params, aux, *, model_axis):
        (hashes,) = aux
        pred = bl.mach_predict_local(f_all, params, hashes,
                                     model_axis=model_axis)
        return pred, None


# ---------------------------------------------------------------------------
# sampled softmax [Jean et al., ACL'15] — logQ-corrected negative sampling
# ---------------------------------------------------------------------------


@register_head("sampled")
class SampledSoftmaxHead(FullSoftmaxHead):
    """W [V, D] row-sharded; CE over the true label plus a drawn negative
    set with the standard logQ correction.

    ``sampled_dist="uniform"`` draws stratified per-shard negatives without
    replacement — at ``sampled_n >= V`` the loss equals the full softmax
    exactly, and shrinking ``sampled_n`` trades accuracy for compute.
    ``"log_uniform"`` is the classic Zipfian LM sampler (with replacement,
    identical draw on every class shard). Negatives are re-drawn every step
    from (``sampled_seed``, the trainer-threaded ``step``, the batch's
    labels); there is no aux state and no periodic work.

    The train-time ``accuracy`` metric is relative to the candidate set
    (label + drawn negatives), like knn's active-set accuracy — use the
    deploy-style eval for full-vocabulary top-1."""

    def loss_local(self, f_all, y_all, params, aux, *, model_axis,
                   batch_axes, global_batch, step=None):
        return bl.sampled_softmax_local(
            f_all, y_all, params, model_axis=model_axis,
            batch_axes=batch_axes, global_batch=global_batch,
            n_samples=self.head_cfg.sampled_n,
            distribution=self.head_cfg.sampled_dist,
            seed=self.head_cfg.sampled_seed,
            cosine_scale=self.head_cfg.cosine_scale, n_valid=self.n_valid,
            step=step, backend=self.backend, block_a=self.block_a)

    def metrics_spec(self) -> dict:
        return {"accuracy": P(), "logz": P(), "sample_frac": P()}


# ---------------------------------------------------------------------------
# CSoft — count-min sketch over class ids (MACH lineage, min-decode)
# ---------------------------------------------------------------------------


@register_head("csoft")
class CSoftSketchHead(MACHSoftmaxHead):
    """Count-min sketch over class ids: R pairwise-independent hash rows of
    B buckets, [R, B, D] with the BUCKET axis sharded over the model axis.

    Training is the sketch's R small softmaxes (exactly MACH's loss,
    inherited) — the two heads differ in their hash family seed and in
    DECODING: csoft takes the min of the row log-probabilities, the
    count-min bound, instead of MACH's mean of probabilities;
    ``csoft_agg="mean"`` selects the geometric-mean variant."""

    _hash_seed = 1

    def _n_buckets(self, n_dev: int) -> int:
        # bucket axis must divide the ring
        b = self.head_cfg.csoft_b
        return -(-b // n_dev) * n_dev

    def init(self, key, n_dev: int) -> HeadState:
        head = bl.init_mach(key, self.n_classes, self.d,
                            n_buckets=self._n_buckets(n_dev),
                            n_rep=self.head_cfg.csoft_r,
                            seed=self._hash_seed)
        return HeadState(params=head.w, aux=(head.hashes,))

    def eval_logits_local(self, f_all, params, aux, *, model_axis):
        (hashes,) = aux
        pred = bl.csoft_predict_local(f_all, params, hashes,
                                      model_axis=model_axis,
                                      agg=self.head_cfg.csoft_agg)
        return pred, None
