"""Process bootstrap shared by every launcher / script.

The CPU container fakes a multi-chip host via an XLA flag that must be set
BEFORE jax initializes; both launchers used to duplicate this dance. Call
``ensure_host_devices`` first thing in ``main()`` (before any jax import).
"""
from __future__ import annotations

import os
import sys
import warnings


def ensure_host_devices(n: int) -> None:
    """Request ``n`` fake host devices (no-op when n is falsy).

    Appends ``--xla_force_host_platform_device_count=n`` to XLA_FLAGS. Must
    run before jax first initializes its backends; if jax is already
    imported AND initialized with a different device count, warns instead
    of silently doing nothing.
    """
    if not n:
        return
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n}")
    jax = sys.modules.get("jax")
    if jax is not None:
        try:
            have = len(jax.devices())
        except Exception:
            return  # backends not initialized yet: the flag will apply
        if have != n:
            warnings.warn(
                f"jax already initialized with {have} devices; "
                f"--devices {n} has no effect in this process",
                RuntimeWarning, stacklevel=2)
