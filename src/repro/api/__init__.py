"""Public API: pluggable softmax-head strategies + the single ``Experiment``
entry point over the paper and zoo systems."""
from repro.api.bootstrap import ensure_host_devices
from repro.api.heads import (HEAD_REGISTRY, HeadState, SoftmaxHead,
                             make_head, register_head)
from repro.api.experiment import (Experiment, PaperExperiment,
                                  ZooExperiment, paper_model_config)

__all__ = [
    "HEAD_REGISTRY", "HeadState", "SoftmaxHead", "make_head",
    "register_head", "Experiment", "PaperExperiment", "ZooExperiment",
    "paper_model_config", "ensure_host_devices",
]
