"""Single ``Experiment`` entry point over the paper and zoo systems.

Collapses the two divergent launch paths into one façade:

  * ``system="paper"`` — the faithful hybrid-parallel trainer (FE data
    parallel + head model parallel on a 1-D ring) with ANY registered
    softmax head (full / knn / selective / mach / sampled / csoft), DGC
    and FCCS toggles.
  * ``system="zoo"`` — the GSPMD trainer for any assigned architecture,
    tensor/expert parallel on a (data, model) mesh, with the SAME head
    registry driving the loss, plus the batched greedy-decoding serve
    path.

Every experiment exposes ``.fit()``, ``.evaluate()``, ``.serve()``; the
launchers in ``repro.launch`` are thin argparse shims over this class.

  >>> exp = Experiment.from_config(system="paper", classes=4096,
  ...                              head=HeadConfig(softmax_impl="knn",
  ...                                              rebuild_every=50))
  >>> exp.fit(150)
  >>> exp.evaluate()
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                InputShape, ModelConfig, TrainConfig,
                                effective_vocab, get_model_config, pad_vocab)


def _validate_serve_args(n_classes: int, batch: Optional[int],
                         top_k: Optional[int]):
    """Reject bad serving knobs with a clear error instead of an opaque
    jit shape failure downstream (used by both systems AND both
    launchers)."""
    if batch is not None and batch <= 0:
        raise ValueError(
            f"serve batch must be a positive query count, got {batch}")
    if top_k is not None and not 0 < top_k <= n_classes:
        raise ValueError(
            f"top_k must be in [1, num_classes={n_classes}], got {top_k} "
            f"(retrieval cannot return more classes than exist)")


def paper_model_config(trunk: str = "feats", classes: int = 4096,
                       feat_dim: int = 64) -> ModelConfig:
    """The paper system's trunk config: raw features or the reduced
    SKU ResNet."""
    if trunk == "feats":
        return ModelConfig(name="paper-feats", family="feats", n_layers=0,
                           d_model=feat_dim, n_heads=0, n_kv_heads=0,
                           d_ff=0, vocab_size=classes, dtype="float32")
    if trunk == "cnn":
        from repro.configs import sku100m_resnet
        return dataclasses.replace(sku100m_resnet.reduced(classes),
                                   dtype="float32")
    raise ValueError(f"unknown paper trunk {trunk!r}")


class Experiment:
    """Facade over one configured training/serving system."""

    @staticmethod
    def from_config(*, system: str = "paper", **kw) -> "Experiment":
        if system == "paper":
            return PaperExperiment(**kw)
        if system == "zoo":
            return ZooExperiment(**kw)
        raise ValueError(f"unknown system {system!r} (paper | zoo)")

    def fit(self, steps: int, **kw):
        raise NotImplementedError

    def evaluate(self, inputs=None) -> float:
        raise NotImplementedError

    def serve(self, *args, **kw):
        raise NotImplementedError

    def serving_engine(self, *, top_k: Optional[int] = None, **kw):
        """A ``repro.serving.ServingEngine`` over this experiment's trained
        head: async ``submit()`` of single queries, coalesced into padded
        micro-batches, optional hot-query score cache (see
        docs/serving.md). Works on both systems (paper hybrid retrieval /
        zoo GSPMD feature classification)."""
        from repro.serving import ServingEngine
        _validate_serve_args(effective_vocab(self.model_cfg), None, top_k)
        return ServingEngine.for_experiment(self, top_k=top_k, **kw)

    def ivf_index(self, *, n_clusters: int = 0, nprobe: int = 0,
                  iters: int = 8, refit: bool = False):
        """The experiment's ``repro.serving.IVFIndex`` over its class
        shards, fit lazily and cached. The cached index is REFIT whenever
        ``weights_version`` has moved since the fit — the same seam that
        invalidates the serving score cache — so train steps, head
        refreshes, and checkpoint restores all retire a stale quantizer.
        ``refit=True`` forces a refit; explicit knobs only apply when a
        (re)fit happens."""
        from repro.serving import IVFIndex
        cur = getattr(self, "_ivf", None)
        if (refit or cur is None
                or tuple(cur.version) != tuple(self.weights_version)):
            cur = IVFIndex.fit(self, n_clusters=n_clusters, nprobe=nprobe,
                               iters=iters)
            self._ivf = cur
        return cur

    def install_ivf_index(self, index) -> None:
        """Install a restored ``IVFIndex`` (``state_from_restore``) so a
        resumed server skips the refit. The index still retires itself the
        moment ``weights_version`` moves past its fit-time snapshot."""
        self._ivf = index


# ---------------------------------------------------------------------------
# paper system
# ---------------------------------------------------------------------------


class PaperExperiment(Experiment):
    """The paper's end-to-end system with a pluggable softmax head."""

    def __init__(self, *, model: Optional[ModelConfig] = None,
                 head: Optional[HeadConfig] = None,
                 train: Optional[TrainConfig] = None,
                 trunk: str = "feats", classes: int = 4096,
                 feat_dim: int = 64, batch: int = 64,
                 data_fn: Optional[Callable[[int, int], dict]] = None,
                 mesh=None, lr_fn=None, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, ckpt_keep: int = 0,
                 log_every: int = 10, seed: int = 0, telemetry=None):
        from repro.train import hybrid
        from repro.train.trainer import PaperTrainer

        self.model_cfg = model or paper_model_config(trunk, classes, feat_dim)
        self.head_cfg = head or HeadConfig()
        self.train_cfg = train or TrainConfig(optimizer="sgd")
        self.mesh = mesh if mesh is not None else hybrid.make_hybrid_mesh()
        self.batch = batch
        if data_fn is None:
            data_fn = self._default_data_fn()
        self.data_fn = data_fn
        self.trainer = PaperTrainer(
            self.model_cfg, self.head_cfg, self.train_cfg, self.mesh,
            data_fn, hw_batch=batch, lr_fn=lr_fn,
            ckpt_dir=ckpt_dir or None, ckpt_every=ckpt_every,
            ckpt_keep=ckpt_keep, log_every=log_every, seed=seed,
            telemetry=telemetry)
        self._serve_step = None
        self._topk_steps: dict = {}
        self._engines: dict = {}

    def _default_data_fn(self):
        from repro.data.synthetic import (ClassificationStream,
                                          sku_feature_batch, sku_image_batch)
        n_classes = self.model_cfg.vocab_size
        if self.model_cfg.family == "feats":
            stream = ClassificationStream(n_classes, self.model_cfg.d_model)
            return lambda t, b: sku_feature_batch(t, b, stream)
        return lambda t, b: sku_image_batch(t, b, n_classes)

    @property
    def head(self):
        return self.trainer.head

    @property
    def state(self):
        return self.trainer.state

    @property
    def weights_version(self):
        """Serving-cache invalidation probe: changes whenever the served
        weights can have changed — on every train step AND on every
        restore. The restore counter is what makes a rewound-then-retrained
        run (step counter back at a previously-cached value, different
        weights) invalidate correctly (tests/test_serving.py)."""
        return (self.trainer.restores, int(self.trainer.state.step))

    def fit(self, steps: int, *, use_fccs_batch: bool = True,
            resume=False, step_hook=None, telemetry=None):
        """Train. ``steps`` is the number of steps to run from the current
        cursor; with ``resume=True`` the latest checkpoint under
        ``ckpt_dir`` is restored first (if any) and ``steps`` becomes the
        TOTAL step target — a killed 100-step run relaunched with
        ``fit(100, resume=True)`` replays only the lost tail.
        ``resume="reshard"`` additionally accepts a checkpoint written on
        a DIFFERENT mesh shape and re-shards it onto this experiment's
        ring (repro.elastic; launcher: ``--resume-reshard``).
        ``step_hook(t)`` fires before each step (fault injection —
        ``repro.resilience``); ``telemetry=`` installs a
        ``repro.telemetry.Tracer`` on the trainer for per-phase spans and
        the JSONL metrics stream (docs/telemetry.md)."""
        if telemetry is not None:
            self.trainer.telemetry = telemetry
        if resume:
            self.restore(missing_ok=True, reshard=(resume == "reshard"))
            steps = steps - self.trainer._t
        if steps > 0:
            self.trainer.run(steps, use_fccs_batch=use_fccs_batch,
                             step_hook=step_hook)
        return self.trainer.history

    def restore(self, step: Optional[int] = None, *,
                missing_ok: bool = False,
                reshard: bool = False) -> Optional[int]:
        """Restore the FULL trainer state (params, opt moments, head aux,
        DGC buffers, data cursor) from ``ckpt_dir``. ``reshard=True``
        accepts a checkpoint written on a different mesh shape
        (repro.elastic). Returns the restored step, or None when
        ``missing_ok`` and no checkpoint exists."""
        from repro import checkpoint as ckpt
        if not self.trainer.ckpt_dir:
            raise ValueError("experiment has no ckpt_dir to restore from")
        if step is None and ckpt.latest_step(self.trainer.ckpt_dir) is None:
            if missing_ok:
                return None
            raise FileNotFoundError(
                f"no checkpoints under {self.trainer.ckpt_dir}")
        return self.trainer.restore_checkpoint(step, reshard=reshard)

    def evaluate(self, inputs=None, *, eval_batch: Optional[int] = None
                 ) -> float:
        if inputs is None:
            inputs = self.data_fn(10**6, eval_batch or 4 * self.batch)
        return self.trainer.evaluate(inputs)

    def serve(self, inputs=None, *, batch: Optional[int] = None,
              top_k: Optional[int] = None, return_scores: bool = False,
              index: Optional[str] = None, nprobe: Optional[int] = None,
              telemetry=None):
        """Deploy-style retrieval (§4.5): nearest-class (or hashed-vote)
        predictions for a batch of inputs.

        Greedy mode (default) returns [b] class ids. ``top_k=k`` switches to
        k-best retrieval with scores — each shard's local top-k (ref:
        ``lax.top_k``; pallas: the divide-and-conquer ``ops.topk_rows``
        kernel) merged over the ring — returning ids [b, k] (descending), or
        (ids, scores) when ``return_scores`` is set. ``index="ivf"``
        (top-k only) serves through the experiment's ``IVFIndex``: probe
        the ``nprobe`` nearest centroids per shard and rerank only their
        member rows — sublinear in V (see docs/serving.md).

        Without explicit ``inputs`` the call is routed through the
        ``repro.serving`` engine (per-query submit -> one padded
        micro-batch -> batched serve step); results are bitwise-identical
        to the pre-engine path and to per-query submission
        (tests/test_serving.py). Explicit ``inputs`` keep the legacy
        single-shot jitted step (batch must then divide the ring) — except
        under ``index="ivf"``, which always serves through the engine."""
        import jax

        from repro.train import hybrid

        _validate_serve_args(effective_vocab(self.model_cfg), batch, top_k)
        if index not in (None, "none", "ivf"):
            raise ValueError(f"unknown serving index {index!r}; "
                             f"expected 'none' or 'ivf'")
        if index == "ivf" and top_k is None:
            raise ValueError("index='ivf' serves top-k retrieval; "
                             "pass top_k=...")
        if inputs is None or index == "ivf":
            queries = None
            if inputs is not None:
                import numpy as np
                qkey = next(k for k in inputs if k != "labels")
                queries = np.asarray(inputs[qkey])
                batch = queries.shape[0]
            return self._serve_via_engine(batch or self.batch, top_k,
                                          return_scores, index=index,
                                          nprobe=nprobe, queries=queries,
                                          telemetry=telemetry)
        from repro.telemetry import NULL_TRACER
        tr = telemetry or NULL_TRACER
        if top_k is not None:
            if top_k not in self._topk_steps:
                self._topk_steps[top_k] = hybrid.make_topk_serve_step(
                    self.model_cfg, self.head_cfg, self.mesh, self.state,
                    top_k, head=self.trainer.head)
            with jax.set_mesh(self.mesh), tr.span("serve.compute"):
                vals, ids = jax.device_get(
                    self._topk_steps[top_k](self.state, inputs))
            return (ids, vals) if return_scores else ids
        if self._serve_step is None:
            self._serve_step = hybrid.make_serve_step(
                self.model_cfg, self.head_cfg, self.mesh, self.state,
                head=self.trainer.head)
        with jax.set_mesh(self.mesh), tr.span("serve.compute"):
            return jax.device_get(self._serve_step(self.state, inputs))

    def _serve_via_engine(self, batch: int, top_k: Optional[int],
                          return_scores: bool, *,
                          index: Optional[str] = None,
                          nprobe: Optional[int] = None, queries=None,
                          telemetry=None):
        """Batched serving through the ``repro.serving`` engine: one
        engine per (top_k, batch, index, nprobe) shape, all queries
        submitted then drained as a single full micro-batch. No cache on
        this path (a synchronous facade call wants fresh scores, and
        determinism)."""
        import numpy as np

        key = (top_k, batch, index, nprobe)
        eng = self._engines.get(key)
        if eng is None:
            # max_batch >= 2 keeps even a 1-query call on the batched-gemm
            # bucket shapes every other path uses (bitwise consistency)
            eng = self.serving_engine(top_k=top_k,
                                      max_batch=max(batch, 2),
                                      max_wait_ms=0.0, cache=None,
                                      index=index, nprobe=nprobe)
            self._engines[key] = eng
        if telemetry is not None:
            eng.telemetry = telemetry
        if queries is None:
            inputs = self.data_fn(10**6, batch)
            qkey = next(k for k in inputs if k != "labels")
            queries = np.asarray(inputs[qkey])
        for i in range(batch):
            eng.submit(queries[i])
        done = sorted(eng.drain(), key=lambda r: r.rid)
        assert len(done) == batch
        if top_k is None:
            return np.stack([r.ids for r in done]).astype(np.int32)
        ids = np.stack([r.ids for r in done])
        if return_scores:
            return ids, np.stack([r.scores for r in done])
        return ids


# ---------------------------------------------------------------------------
# zoo system (GSPMD trainer + decode serving)
# ---------------------------------------------------------------------------


class ZooExperiment(Experiment):
    """GSPMD training/serving for any assigned architecture, with ANY
    registered softmax head: the loss is routed through the
    ``repro.api.SoftmaxHead`` registry (``gspmd.make_head_train_step``), so
    full / knn / selective / mach / sampled / csoft all train under the zoo
    mesh. W-heads train the model's own class matrix (tied embedding or
    ``params["head"]``); sketch heads (mach / csoft) thread their bucket
    weights as head-owned trainable state. Per-head aux (KNN graph, LSH
    tables, bucket hashes) lives in ``self.head_state.aux`` and is rebuilt
    by ``refresh_head`` on the head's ``rebuild_every`` cadence."""

    def __init__(self, *, arch: str = "smollm_135m", reduced: bool = False,
                 head: Optional[HeadConfig] = None,
                 train: Optional[TrainConfig] = None,
                 batch: int = 64, seq: int = 64, n_model: Optional[int] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 ckpt_keep: int = 0, log_every: int = 10,
                 seed: int = 0, telemetry=None):
        import jax
        from jax.sharding import NamedSharding

        from repro.api.heads import HeadState, make_head
        from repro.launch.mesh import (make_host_mesh,
                                       make_host_parallel_config)
        from repro.models import lm

        n_dev = len(jax.devices())
        n_model = n_model or min(4, n_dev)
        n_data = max(1, n_dev // n_model)
        self.mesh = make_host_mesh(n_data, n_model)
        self.par = make_host_parallel_config(n_data, n_model)
        cfg = get_model_config(arch, reduced=reduced)
        if reduced:
            cfg = dataclasses.replace(cfg, dtype="float32")
        self.model_cfg = pad_vocab(cfg, n_model)
        self.head_cfg = head or HeadConfig()
        if (self.head_cfg.softmax_impl == "full"
                and self.model_cfg.family not in ("cnn", "feats")):
            # historical zoo numerics: the full softmax on LM trunks trains
            # RAW logits, matching the raw-argmax prefill/serve decode path;
            # cnn/feats trunks and the other heads keep their configured
            # cosine scale
            self.head_cfg = dataclasses.replace(self.head_cfg,
                                                cosine_scale=0.0)
        self.train_cfg = train or TrainConfig(optimizer="sgd")
        self.batch, self.seq = batch, seq
        self.ckpt_dir = ckpt_dir or None
        self.ckpt_every = ckpt_every
        self.ckpt_keep = ckpt_keep
        self.log_every = log_every
        self.shape = InputShape("experiment", seq, batch, "train")
        self.history: list = []
        self._t = 0          # data cursor: next global step fit() will take
        self.restores = 0    # bumped on every restore (serving-cache probe)
        self.last_reshard = None   # stats dict of the last elastic restore
        self.telemetry = telemetry  # Tracer, or None = NULL_TRACER

        from repro.train import gspmd
        self._gspmd = gspmd
        self.head = make_head(self.model_cfg, self.head_cfg)
        self._maxis, _, _ = gspmd.vocab_axes(self.par)
        n_shards = gspmd.n_vocab_shards(self.par)
        self._n_vocab_shards = n_shards
        self._n_data = n_data
        with jax.set_mesh(self.mesh):
            params = lm.init_model(jax.random.PRNGKey(seed), self.model_cfg)
            shards = gspmd.param_shardings(self.model_cfg, self.par,
                                           self.mesh)
            self.params = jax.tree.map(jax.device_put, params, shards)
            # head-owned state: W-heads init only aux (their class matrix
            # IS the model's — no throwaway [V, D] draw); sketch heads keep
            # their [R, B, D] bucket weights as trainable extras
            def put(tree, spec):
                return jax.tree.map(
                    lambda a, s: jax.device_put(
                        a, NamedSharding(self.mesh, s)), tree, spec)

            hkey = jax.random.PRNGKey(seed + 1)
            if self.head.params_are_class_weights:
                hp = ()
                aux = self.head.init_aux(hkey, n_shards)
            else:
                hs = self.head.init(hkey, n_shards)
                hp = put(hs.params, self.head.params_spec(self._maxis))
                aux = hs.aux
            aux = put(aux, self.head.aux_spec(self._maxis))
            self.head_state = HeadState(hp, aux)
        # optimizer moments / train step are built lazily on first fit()
        # so a serve-only Experiment stays at params-only cost
        self.opt_state = None
        self._train_step = None
        self._eval_step = None
        self._refreshed = False

    @property
    def graph(self):
        """Back-compat: the knn head's compressed-graph aux tuple."""
        return self.head_state.aux if self.head.name == "knn" else None

    @graph.setter
    def graph(self, value):
        """Back-compat: ``exp.graph = None`` forces a rebuild before the
        next fit/evaluate; a tuple installs it as the head's aux."""
        from repro.api.heads import HeadState
        if value is None:
            self._refreshed = False
        else:
            self.head_state = HeadState(self.head_state.params, tuple(value))
            self._refreshed = True

    def refresh_head(self):
        """Rebuild the head's aux state (KNN graph / LSH tables) from the
        CURRENT class weights on the training mesh — the zoo counterpart of
        the paper trainer's head refresh. No-op for heads without periodic
        work."""
        import jax

        from repro.api.heads import HeadState
        from repro.models import lm

        with jax.set_mesh(self.mesh):
            w = (lm.head_weight(self.params, self.model_cfg)
                 if self.head.params_are_class_weights
                 else self.head_state.params)
            hs = self.head.refresh(self.mesh, HeadState(w, self.head_state.aux),
                                   model_axis=self._maxis)
            self.head_state = HeadState(self.head_state.params, hs.aux)
        self._refreshed = True
        return self.head_state

    def rebuild_graph(self):
        """Back-compat (pre-registry API): refresh the head and return the
        knn graph tuple (offsets, neighbors, ranks)."""
        self.refresh_head()
        return self.graph

    def _batch(self, t: int):
        import jax
        import jax.numpy as jnp

        from repro.data.synthetic import lm_batch
        cfg = self.model_cfg
        inputs = lm_batch(t, self.batch, self.seq,
                          cfg.real_vocab_size or cfg.vocab_size)
        if cfg.family == "encdec":
            inputs["frames"] = jax.random.normal(
                jax.random.PRNGKey(t),
                (self.batch, cfg.enc_seq, cfg.d_model), jnp.float32)
        return inputs

    def _ensure_opt(self):
        """Lazy optimizer-state / train-step build (a serve-only Experiment
        stays at params-only cost). Also the restore path's template
        source: the snapshot structure needs ``opt_state`` to exist."""
        import jax

        from repro.optim import make_optimizer
        if self.opt_state is None:
            self.opt_state = make_optimizer(self.train_cfg).init(
                (self.params, self.head_state.params))
        if self._train_step is None:
            self._train_step = jax.jit(self._gspmd.make_head_train_step(
                self.model_cfg, self.head_cfg, self.par, self.train_cfg,
                self.mesh, self.shape, head=self.head))

    @property
    def weights_version(self):
        """Serving-cache invalidation probe — see
        ``PaperExperiment.weights_version``."""
        return (self.restores, self._t)

    # -- full-state checkpoint / restore ----------------------------------

    def _snapshot(self):
        """Checkpoint pytree: model params, head-owned trainable params
        (sketch heads' bucket weights), head aux (KNN graph / LSH tables /
        hashes), optimizer moments, and the data cursor. Same contract as
        the paper trainer's snapshot (docs/resilience.md)."""
        import jax.numpy as jnp

        from repro.api.heads import HeadState
        self._ensure_opt()
        return {
            "model": self.params,
            "head": self.head.state_to_save(
                HeadState(self.head_state.params, self.head_state.aux)),
            "opt": self.opt_state,
            "extra": {"t": jnp.asarray(self._t, jnp.int32),
                      "seed": jnp.asarray(0, jnp.int32)},
        }

    def geometry(self):
        """This experiment's ``repro.elastic.MeshGeometry``: the model
        axis counts vocab row shards; classes are the REAL (unpadded)
        vocabulary, which is mesh-invariant — padding is recorded
        separately in the checkpoint meta."""
        from repro.elastic import MeshGeometry
        return MeshGeometry(n_model=self._n_vocab_shards,
                            n_data=self._n_data,
                            n_classes=effective_vocab(self.model_cfg))

    def save_checkpoint(self) -> str:
        assert self.ckpt_dir, "experiment has no ckpt_dir"
        from repro import checkpoint as ckpt
        meta = {"system": "zoo", **self.geometry().meta(),
                "padded_vocab": self.model_cfg.vocab_size}
        return ckpt.save(self.ckpt_dir, self._snapshot(), step=self._t,
                         keep=self.ckpt_keep or None, meta=meta)

    def restore(self, step: Optional[int] = None, *,
                missing_ok: bool = False,
                reshard: bool = False) -> Optional[int]:
        """Refill model + head + optimizer state from ``ckpt_dir`` and move
        the data cursor. Restored aux is installed as-is (NOT rebuilt): a
        run killed mid-refresh-interval resumes with the exact graph /
        tables the killed run was using. ``reshard=True`` accepts a
        checkpoint written on a different (data, model) mesh and
        re-shards it onto this one (repro.elastic)."""
        import jax
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro import checkpoint as ckpt
        from repro.api.heads import HeadState
        if not self.ckpt_dir:
            raise ValueError("experiment has no ckpt_dir to restore from")
        if step is None and ckpt.latest_step(self.ckpt_dir) is None:
            if missing_ok:
                return None
            raise FileNotFoundError(f"no checkpoints under {self.ckpt_dir}")
        from repro.telemetry import NULL_TRACER
        tr = self.telemetry or NULL_TRACER
        with tr.span("train.restore"):
            return self._do_restore(step, NamedSharding, P, tr, reshard)

    def _do_restore(self, step, NamedSharding, P, tr,
                    reshard: bool = False) -> int:
        import time

        import jax

        from repro import checkpoint as ckpt
        from repro import elastic
        from repro.api.heads import HeadState
        dst = self.geometry()
        src = ckpt.validate_restore(self.ckpt_dir, dst, step,
                                    reshard=reshard)
        src_meta = ckpt.read_meta(self.ckpt_dir, step) or {}
        tree, step = ckpt.restore(self.ckpt_dir, self._snapshot(), step)
        needs_refresh = False
        if (src.n_model, src.n_data) != (dst.n_model, dst.n_data):
            t0 = time.perf_counter()
            with tr.span("train.reshard",
                         attrs={"src": src.describe(),
                                "dst": dst.describe()}):
                tree, needs_refresh, led = elastic.reshard_zoo_snapshot(
                    tree, self.head, self.model_cfg, src, dst,
                    padded_vocab_src=int(
                        src_meta.get("padded_vocab",
                                     self.model_cfg.vocab_size)))
            tr.count("reshard.bytes_moved", led.total_bytes())
            self.last_reshard = {
                "src": src, "dst": dst, "bytes_moved": led.total_bytes(),
                "ledger": led, "seconds": time.perf_counter() - t0}
        with jax.set_mesh(self.mesh):
            shards = self._gspmd.param_shardings(self.model_cfg, self.par,
                                                 self.mesh)
            self.params = jax.tree.map(jax.device_put, tree["model"], shards)
            hs = self.head.state_from_restore(tree["head"], self.mesh,
                                              model_axis=self._maxis)
            self.head_state = HeadState(hs.params, hs.aux)
            # optimizer moments mirror (model params, head params)
            hp_sh = jax.tree.map(
                lambda s: NamedSharding(self.mesh, s),
                self.head.params_spec(self._maxis)) \
                if jax.tree.leaves(self.head_state.params) else ()
            rep = NamedSharding(self.mesh, P())
            moment_sh = (shards, hp_sh)
            opt_sh = type(self.opt_state)(
                step=rep, mu=moment_sh,
                nu=(moment_sh if getattr(self.opt_state, "nu", None)
                    is not None else None))
            self.opt_state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree["opt"], opt_sh)
        self._t = int(tree["extra"]["t"])
        self.restores += 1
        tr.count("train.restores")
        # aux came from the snapshot; do NOT rebuild it before the next
        # step — unless the elastic path asked for the head's own refresh
        self._refreshed = not needs_refresh
        return step

    def fit(self, steps: int, *, lr: float = 0.5, resume=False,
            step_hook=None, telemetry=None):
        """Train ``steps`` steps from the current cursor. ``resume=True``
        restores the latest checkpoint first (if any) and treats ``steps``
        as the TOTAL target, like ``PaperExperiment.fit``;
        ``resume="reshard"`` additionally accepts a checkpoint written on
        a different mesh (repro.elastic). ``step_hook(t)``
        is the fault-injection seam (``repro.resilience``); ``telemetry=``
        installs a ``repro.telemetry.Tracer`` for per-phase spans and the
        JSONL metrics stream (docs/telemetry.md)."""
        import jax

        from repro.telemetry import NULL_TRACER

        if telemetry is not None:
            self.telemetry = telemetry
        tr = self.telemetry or NULL_TRACER
        if resume:
            self.restore(missing_ok=True, reshard=(resume == "reshard"))
            steps = steps - self._t
            if steps <= 0:
                return self.history
        if not self._refreshed:
            # heads with derived aux (KNN graph, LSH tables) rebuild it from
            # the real class weights before the first step; a no-op for the
            # rest. Done before jit so aux shapes are final.
            self.refresh_head()
        self._ensure_opt()
        refresh_every = self.head.refresh_every
        start = self._t
        with jax.set_mesh(self.mesh):
            for t in range(start, start + steps):
                if step_hook is not None:
                    step_hook(t)
                with tr.span("train.data"):
                    inputs = self._batch(t)
                with tr.span("train.step"):
                    self.params, self.head_state, self.opt_state, loss, \
                        metrics = self._train_step(
                            self.params, self.head_state, self.opt_state,
                            inputs, lr)
                    if tr.enabled:
                        jax.block_until_ready(loss)
                tr.count("train.steps")
                self._t = t + 1
                if refresh_every and (t + 1) % refresh_every == 0:
                    with tr.span("train.refresh"):
                        self.refresh_head()
                    tr.count("train.refreshes")
                if self.ckpt_dir and self.ckpt_every and \
                        (t + 1) % self.ckpt_every == 0:
                    with tr.span("train.checkpoint"):
                        self.save_checkpoint()
                    tr.count("train.checkpoints")
                row = {"step": t, "loss": float(loss),
                       "acc": float(metrics["accuracy"])}
                self.history.append(row)
                tr.log_metrics(row)
                if self.log_every and t % self.log_every == 0:
                    print(f"[zoo] step={t} loss={row['loss']:.4f} "
                          f"acc={row['acc']:.3f}")
        tr.record_peak_memory()
        if self.ckpt_dir:
            # end-of-fit snapshot: full state (bucket weights included —
            # sketch heads' output layer must not be lost), resumable
            self.save_checkpoint()
            print(f"[zoo] checkpoint written to {self.ckpt_dir}")
        return self.history

    def evaluate(self, inputs=None) -> float:
        """Deploy-style top-1 accuracy on a held-out (late-stream) batch,
        through the head's own ``eval_logits_local`` (§4.5 retrieval for
        W-heads, hashed-bucket decode for mach/csoft)."""
        import jax
        if not self._refreshed:
            self.refresh_head()
        if inputs is None:
            inputs = self._batch(10**6)
        if self._eval_step is None:
            self._eval_step = jax.jit(self._gspmd.make_head_eval_step(
                self.model_cfg, self.head_cfg, self.par, self.mesh,
                head=self.head))
        with jax.set_mesh(self.mesh):
            return float(self._eval_step(self.params, self.head_state.params,
                                         self.head_state.aux, inputs))

    def serve(self, *, prompt_len: int = 32, gen: int = 16,
              batch: Optional[int] = None, top_k: Optional[int] = None,
              queries=None, return_scores: bool = False,
              index: Optional[str] = None, nprobe: Optional[int] = None,
              telemetry=None):
        """Batched greedy decoding: prefill once, then single-token decode
        steps through the KV/SSM cache and the sharded-vocab argmax.
        Returns generated tokens [batch, gen].

        ``top_k=k`` switches to feature retrieval against the model's
        class matrix (same contract as ``PaperExperiment.serve(top_k=...)``,
        W-heads only): ``queries`` [b, d_model] embeddings (a deterministic
        synthetic pool when omitted) -> ids [b, k] (or (ids, scores)).
        ``index="ivf"`` routes it through the experiment's ``IVFIndex``."""
        import jax
        import jax.numpy as jnp

        from repro.data.synthetic import lm_batch
        from repro.models import decoder as dec_lib
        from repro.models import lm
        from repro.telemetry import NULL_TRACER

        tr = telemetry or NULL_TRACER
        _validate_serve_args(effective_vocab(self.model_cfg), batch, top_k)
        if index not in (None, "none", "ivf"):
            raise ValueError(f"unknown serving index {index!r}; "
                             f"expected 'none' or 'ivf'")
        if index == "ivf" and top_k is None:
            raise ValueError("index='ivf' serves top-k retrieval; "
                             "pass top_k=...")
        if top_k is not None:
            import numpy as np
            if queries is None:
                b = batch or self.batch
                queries = np.random.default_rng(0).standard_normal(
                    (b, self.model_cfg.d_model)).astype(np.float32)
            queries = np.asarray(queries, np.float32)
            b = queries.shape[0]
            engines = getattr(self, "_engines", None)
            if engines is None:
                engines = self._engines = {}
            key = (top_k, b, index, nprobe)
            eng = engines.get(key)
            if eng is None:
                eng = self.serving_engine(top_k=top_k, max_batch=max(b, 2),
                                          max_wait_ms=0.0, cache=None,
                                          index=index, nprobe=nprobe)
                engines[key] = eng
            if telemetry is not None:
                eng.telemetry = telemetry
            for i in range(b):
                eng.submit(queries[i])
            done = sorted(eng.drain(), key=lambda r: r.rid)
            assert len(done) == b
            ids = np.stack([r.ids for r in done])
            if return_scores:
                return ids, np.stack([r.scores for r in done])
            return ids
        if prompt_len <= 0 or gen <= 0:
            raise ValueError(
                f"prompt_len and gen must be positive, got "
                f"prompt_len={prompt_len} gen={gen}")
        cfg = self.model_cfg
        if cfg.family == "encdec":
            raise NotImplementedError(
                "serve() supports decoder-only archs; whisper decoding is "
                "exercised in tests")
        if not self.head.params_are_class_weights:
            # greedy decode argmaxes over the model's own [V, D] head,
            # which the sketch heads never train — refuse loudly rather
            # than emit tokens unrelated to the trained head
            raise NotImplementedError(
                f"zoo serve() decodes with the model's [V, D] head weight, "
                f"which the {self.head.name!r} head does not train; use "
                f"evaluate() (hashed-bucket decode) or a W-head "
                f"(full/knn/selective/sampled) for token serving")
        gspmd = self._gspmd
        batch = batch or self.batch
        total = prompt_len + gen
        dshape = InputShape("serve-decode", total, batch, "decode")
        with jax.set_mesh(self.mesh):
            prompts = lm_batch(0, batch, prompt_len,
                               cfg.real_vocab_size or cfg.vocab_size)
            window = lm.decode_window(cfg, total)
            prefill = jax.jit(gspmd.make_prefill_step(cfg, self.par,
                                                      self.mesh, dshape))
            serve = jax.jit(gspmd.make_serve_step(cfg, self.par, self.mesh,
                                                  dshape))
            with tr.span("serve.prefill"):
                tok, caches = prefill(self.params,
                                      {"tokens": prompts["tokens"]})
                if tr.enabled:
                    jax.block_until_ready(tok)

            def grow(c):
                if c.ndim >= 3 and c.shape[2] == prompt_len:
                    pad = [(0, 0)] * c.ndim
                    pad[2] = (0, window - prompt_len)
                    return jnp.pad(c, pad)
                return c
            if cfg.family != "ssm":
                caches = jax.tree.map(grow, caches)
            slots = dec_lib.init_cache_slots(
                cfg, window, prefill_positions=jnp.arange(prompt_len))
            out = [tok]
            tok = tok[:, None]
            with tr.span("serve.decode"):
                for _ in range(gen - 1):
                    tok, caches, slots = serve(self.params, caches, slots,
                                               tok)
                    out.append(tok[:, 0])
                toks = jax.device_get(jnp.stack(out, axis=1))
            tr.count("serve.decoded_tokens", float(toks.shape[0] * gen))
            return toks
