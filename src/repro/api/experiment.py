"""Single ``Experiment`` entry point over the paper and zoo systems.

Collapses the two divergent launch paths into one façade:

  * ``system="paper"`` — the faithful hybrid-parallel trainer (FE data
    parallel + head model parallel on a 1-D ring) with ANY registered
    softmax head (full / knn / selective / mach), DGC and FCCS toggles.
  * ``system="zoo"`` — the GSPMD trainer for any assigned architecture,
    tensor/expert parallel on a (data, model) mesh, plus the batched
    greedy-decoding serve path.

Every experiment exposes ``.fit()``, ``.evaluate()``, ``.serve()``; the
launchers in ``repro.launch`` are thin argparse shims over this class.

  >>> exp = Experiment.from_config(system="paper", classes=4096,
  ...                              head=HeadConfig(softmax_impl="knn",
  ...                                              rebuild_every=50))
  >>> exp.fit(150)
  >>> exp.evaluate()
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                InputShape, ModelConfig, TrainConfig,
                                get_model_config, pad_vocab)


def paper_model_config(trunk: str = "feats", classes: int = 4096,
                       feat_dim: int = 64) -> ModelConfig:
    """The paper system's trunk config: raw features or the reduced
    SKU ResNet."""
    if trunk == "feats":
        return ModelConfig(name="paper-feats", family="feats", n_layers=0,
                           d_model=feat_dim, n_heads=0, n_kv_heads=0,
                           d_ff=0, vocab_size=classes, dtype="float32")
    if trunk == "cnn":
        from repro.configs import sku100m_resnet
        return dataclasses.replace(sku100m_resnet.reduced(classes),
                                   dtype="float32")
    raise ValueError(f"unknown paper trunk {trunk!r}")


class Experiment:
    """Facade over one configured training/serving system."""

    @staticmethod
    def from_config(*, system: str = "paper", **kw) -> "Experiment":
        if system == "paper":
            return PaperExperiment(**kw)
        if system == "zoo":
            return ZooExperiment(**kw)
        raise ValueError(f"unknown system {system!r} (paper | zoo)")

    def fit(self, steps: int, **kw):
        raise NotImplementedError

    def evaluate(self, inputs=None) -> float:
        raise NotImplementedError

    def serve(self, *args, **kw):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# paper system
# ---------------------------------------------------------------------------


class PaperExperiment(Experiment):
    """The paper's end-to-end system with a pluggable softmax head."""

    def __init__(self, *, model: Optional[ModelConfig] = None,
                 head: Optional[HeadConfig] = None,
                 train: Optional[TrainConfig] = None,
                 trunk: str = "feats", classes: int = 4096,
                 feat_dim: int = 64, batch: int = 64,
                 data_fn: Optional[Callable[[int, int], dict]] = None,
                 mesh=None, lr_fn=None, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, log_every: int = 10, seed: int = 0):
        from repro.train import hybrid
        from repro.train.trainer import PaperTrainer

        self.model_cfg = model or paper_model_config(trunk, classes, feat_dim)
        self.head_cfg = head or HeadConfig()
        self.train_cfg = train or TrainConfig(optimizer="sgd")
        self.mesh = mesh if mesh is not None else hybrid.make_hybrid_mesh()
        self.batch = batch
        if data_fn is None:
            data_fn = self._default_data_fn()
        self.data_fn = data_fn
        self.trainer = PaperTrainer(
            self.model_cfg, self.head_cfg, self.train_cfg, self.mesh,
            data_fn, hw_batch=batch, lr_fn=lr_fn,
            ckpt_dir=ckpt_dir or None, ckpt_every=ckpt_every,
            log_every=log_every, seed=seed)
        self._serve_step = None

    def _default_data_fn(self):
        from repro.data.synthetic import (ClassificationStream,
                                          sku_feature_batch, sku_image_batch)
        n_classes = self.model_cfg.vocab_size
        if self.model_cfg.family == "feats":
            stream = ClassificationStream(n_classes, self.model_cfg.d_model)
            return lambda t, b: sku_feature_batch(t, b, stream)
        return lambda t, b: sku_image_batch(t, b, n_classes)

    @property
    def head(self):
        return self.trainer.head

    @property
    def state(self):
        return self.trainer.state

    def fit(self, steps: int, *, use_fccs_batch: bool = True):
        return self.trainer.run(steps, use_fccs_batch=use_fccs_batch)

    def evaluate(self, inputs=None, *, eval_batch: Optional[int] = None
                 ) -> float:
        if inputs is None:
            inputs = self.data_fn(10**6, eval_batch or 4 * self.batch)
        return self.trainer.evaluate(inputs)

    def serve(self, inputs=None, *, batch: Optional[int] = None):
        """Deploy-style retrieval (§4.5): nearest-class (or hashed-vote)
        predictions for a batch of inputs. Returns [b] class ids."""
        import jax

        from repro.train import hybrid

        if inputs is None:
            inputs = self.data_fn(10**6, batch or self.batch)
        if self._serve_step is None:
            self._serve_step = hybrid.make_serve_step(
                self.model_cfg, self.head_cfg, self.mesh, self.state,
                head=self.trainer.head)
        with jax.set_mesh(self.mesh):
            return jax.device_get(self._serve_step(self.state, inputs))


# ---------------------------------------------------------------------------
# zoo system (GSPMD trainer + decode serving)
# ---------------------------------------------------------------------------


class ZooExperiment(Experiment):
    """GSPMD training/serving for any assigned architecture."""

    def __init__(self, *, arch: str = "smollm_135m", reduced: bool = False,
                 head: Optional[HeadConfig] = None,
                 train: Optional[TrainConfig] = None,
                 batch: int = 64, seq: int = 64, n_model: Optional[int] = None,
                 ckpt_dir: Optional[str] = None, log_every: int = 10,
                 seed: int = 0):
        import jax

        from repro.launch.mesh import (make_host_mesh,
                                       make_host_parallel_config)
        from repro.models import lm

        n_dev = len(jax.devices())
        n_model = n_model or min(4, n_dev)
        n_data = max(1, n_dev // n_model)
        self.mesh = make_host_mesh(n_data, n_model)
        self.par = make_host_parallel_config(n_data, n_model)
        cfg = get_model_config(arch, reduced=reduced)
        if reduced:
            cfg = dataclasses.replace(cfg, dtype="float32")
        self.model_cfg = pad_vocab(cfg, n_model)
        self.head_cfg = head or HeadConfig()
        if self.head_cfg.softmax_impl not in ("full", "knn"):
            # the GSPMD trainer threads only the knn graph today; failing
            # loudly beats silently training full softmax under another name
            raise ValueError(
                f"zoo system supports softmax_impl 'full' or 'knn', got "
                f"{self.head_cfg.softmax_impl!r} (selective/mach run on the "
                f"paper system; see ROADMAP open items)")
        self.train_cfg = train or TrainConfig(optimizer="sgd")
        self.batch, self.seq = batch, seq
        self.ckpt_dir = ckpt_dir or None
        self.log_every = log_every
        self.shape = InputShape("experiment", seq, batch, "train")
        self.history: list = []

        from repro.train import gspmd
        self._gspmd = gspmd
        with jax.set_mesh(self.mesh):
            params = lm.init_model(jax.random.PRNGKey(seed), self.model_cfg)
            shards = gspmd.param_shardings(self.model_cfg, self.par,
                                           self.mesh)
            self.params = jax.tree.map(jax.device_put, params, shards)
        # optimizer moments / train step are built lazily on first fit()
        # so a serve-only Experiment stays at params-only cost
        self.opt_state = None
        self._train_step = None
        self._eval_loss = None
        self.graph = None        # knn head: sharded CompressedGraph
        self._uses_knn = self.head_cfg.softmax_impl == "knn"

    @property
    def _m_local(self) -> int:
        n_model = self.mesh.shape["model"]
        v_loc = self.model_cfg.vocab_size // n_model
        return max(8, int(v_loc * self.head_cfg.active_frac))

    def rebuild_graph(self):
        """KNN head: ring-build the exact graph of the CURRENT head weights
        on the training mesh and compress it per vocab shard (the zoo
        counterpart of the paper trainer's head refresh)."""
        import jax
        import numpy as np
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.core import knn_graph as kg
        from repro.models import lm

        n_model = self.mesh.shape["model"]
        with jax.set_mesh(self.mesh):
            w = lm.head_weight(self.params, self.model_cfg)
            graph = kg.build_graph_distributed(
                self.mesh, w, k=self.head_cfg.knn_k,
                kprime=self.head_cfg.knn_kprime, model_axis="model")
            cg = kg.compress_graph(np.asarray(jax.device_get(graph)),
                                   n_model)
            sh = NamedSharding(self.mesh, P("model", None))
            self.graph = tuple(jax.device_put(a, sh)
                               for a in (cg.offsets, cg.neighbors, cg.ranks))
        return self.graph

    def _batch(self, t: int):
        import jax
        import jax.numpy as jnp

        from repro.data.synthetic import lm_batch
        cfg = self.model_cfg
        inputs = lm_batch(t, self.batch, self.seq,
                          cfg.real_vocab_size or cfg.vocab_size)
        if cfg.family == "encdec":
            inputs["frames"] = jax.random.normal(
                jax.random.PRNGKey(t),
                (self.batch, cfg.enc_seq, cfg.d_model), jnp.float32)
        return inputs

    def fit(self, steps: int, *, lr: float = 0.5):
        import jax

        from repro.optim import make_optimizer
        if self._uses_knn and self.graph is None:
            self.rebuild_graph()
        if self._train_step is None:
            self.opt_state = make_optimizer(self.train_cfg).init(self.params)
            self._train_step = jax.jit(self._gspmd.make_train_step(
                self.model_cfg, self.head_cfg, self.par, self.train_cfg,
                self.mesh, self.shape))
        refresh_every = (self.head_cfg.rebuild_every
                         if self._uses_knn else 0)
        with jax.set_mesh(self.mesh):
            for t in range(steps):
                args = ((self._batch(t), self.graph, lr) if self._uses_knn
                        else (self._batch(t), lr))
                self.params, self.opt_state, loss, metrics = \
                    self._train_step(self.params, self.opt_state, *args)
                if refresh_every and (t + 1) % refresh_every == 0:
                    self.rebuild_graph()
                row = {"step": t, "loss": float(loss),
                       "acc": float(metrics["accuracy"])}
                self.history.append(row)
                if self.log_every and t % self.log_every == 0:
                    print(f"[zoo] step={t} loss={row['loss']:.4f} "
                          f"acc={row['acc']:.3f}")
        if self.ckpt_dir:
            from repro import checkpoint as ckpt
            ckpt.save(self.ckpt_dir, self.params, step=len(self.history))
            print(f"[zoo] checkpoint written to {self.ckpt_dir}")
        return self.history

    def evaluate(self, inputs=None) -> float:
        """Next-token accuracy on a held-out (late-stream) batch."""
        import jax
        if self._uses_knn and self.graph is None:
            self.rebuild_graph()
        if inputs is None:
            inputs = self._batch(10**6)
        # the CE normalizer is baked into the loss fn: rebuild per token count
        tokens = int(jax.numpy.size(inputs["labels"]))
        if self._eval_loss is None or self._eval_loss[0] != tokens:
            loss_fn = self._gspmd.make_loss_fn(
                self.model_cfg, self.head_cfg, self.par, self.mesh,
                global_tokens=tokens, m_local=self._m_local)
            self._eval_loss = (tokens, jax.jit(loss_fn))
        with jax.set_mesh(self.mesh):
            args = (inputs, self.graph) if self._uses_knn else (inputs,)
            _, metrics = self._eval_loss[1](self.params, *args)
            return float(metrics["accuracy"])

    def serve(self, *, prompt_len: int = 32, gen: int = 16,
              batch: Optional[int] = None):
        """Batched greedy decoding: prefill once, then single-token decode
        steps through the KV/SSM cache and the sharded-vocab argmax.
        Returns generated tokens [batch, gen]."""
        import jax
        import jax.numpy as jnp

        from repro.data.synthetic import lm_batch
        from repro.models import decoder as dec_lib
        from repro.models import lm

        cfg = self.model_cfg
        if cfg.family == "encdec":
            raise NotImplementedError(
                "serve() supports decoder-only archs; whisper decoding is "
                "exercised in tests")
        gspmd = self._gspmd
        batch = batch or self.batch
        total = prompt_len + gen
        dshape = InputShape("serve-decode", total, batch, "decode")
        with jax.set_mesh(self.mesh):
            prompts = lm_batch(0, batch, prompt_len,
                               cfg.real_vocab_size or cfg.vocab_size)
            window = lm.decode_window(cfg, total)
            prefill = jax.jit(gspmd.make_prefill_step(cfg, self.par,
                                                      self.mesh, dshape))
            serve = jax.jit(gspmd.make_serve_step(cfg, self.par, self.mesh,
                                                  dshape))
            tok, caches = prefill(self.params, {"tokens": prompts["tokens"]})

            def grow(c):
                if c.ndim >= 3 and c.shape[2] == prompt_len:
                    pad = [(0, 0)] * c.ndim
                    pad[2] = (0, window - prompt_len)
                    return jnp.pad(c, pad)
                return c
            if cfg.family != "ssm":
                caches = jax.tree.map(grow, caches)
            slots = dec_lib.init_cache_slots(
                cfg, window, prefill_positions=jnp.arange(prompt_len))
            out = [tok]
            tok = tok[:, None]
            for _ in range(gen - 1):
                tok, caches, slots = serve(self.params, caches, slots, tok)
                out.append(tok[:, 0])
            return jax.device_get(jnp.stack(out, axis=1))
