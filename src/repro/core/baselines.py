"""Baseline softmax approximations the paper compares against (§4.1).

* Selective softmax [Zhang et al., AAAI'18] — HF-A flavored: active classes
  are chosen by locality-sensitive hashing of the *features* (random
  hyperplane tables over the normalized weights, queried by each sample's
  feature hash). Unlike the KNN graph, LSH recall is imperfect, so the true
  label may be missing from the active set — we force-include it (as HF-A's
  class-level updates effectively do) but neighbors can be lost, which is
  the accuracy gap Table 2 shows.

* MACH [Medini et al., NeurIPS'19] — R independent hash functions map N
  classes to B buckets; train R B-way softmaxes; score class j at inference
  by averaging P_r(hash_r(j)). Log-memory, but lossy (Table 2).

* Sampled softmax [Jean et al., ACL'15] — CE over the true label plus a
  drawn negative set with the standard logQ correction. Uniform mode draws
  stratified per-shard negatives WITHOUT replacement, so at full sample
  count it recovers the exact full softmax; log-uniform mode draws Zipfian
  negatives with replacement (the classic LM sampler).

* CSoft count-min sketch — R pairwise-independent hash rows of B buckets
  (a count-min sketch over class ids). Training is identical to MACH's R
  small softmaxes; decoding takes the MIN over the rows' log-probabilities
  (each row over-counts a class by its bucket collisions, so the min is the
  tightest estimate — the count-min principle), or the mean (geometric mean
  of probabilities).

All are implemented as real trainable heads so the Table-2-style benchmark
can train every method under identical conditions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.core.sharded_softmax import (NEG_INF, _finish_ce,
                                        _finish_ce_stats, _flat_axis_index,
                                        _normalize)

# ---------------------------------------------------------------------------
# selective softmax (LSH active classes)
# ---------------------------------------------------------------------------


class LSHTables(NamedTuple):
    planes: jax.Array      # [R, D, n_bits] random hyperplanes
    offsets: jax.Array     # [R, n_buckets+1] CSR per table
    classes: jax.Array     # [R, nnz] class ids sorted by bucket


def build_lsh_tables(key, w, n_tables: int, n_bits: int) -> LSHTables:
    n, d = w.shape
    planes = jax.random.normal(key, (n_tables, d, n_bits), jnp.float32)
    wn = _normalize(w).astype(jnp.float32)
    bits = (jnp.einsum("nd,rdb->rnb", wn, planes) > 0)
    bucket = jnp.sum(bits * (1 << jnp.arange(n_bits)), axis=-1)  # [R, N]
    n_buckets = 1 << n_bits
    order = jnp.argsort(bucket, axis=1)
    classes = jnp.take_along_axis(
        jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (n_tables, n)),
        order, axis=1)
    sorted_b = jnp.take_along_axis(bucket, order, axis=1)
    offsets = jax.vmap(
        lambda sb: jnp.searchsorted(sb, jnp.arange(n_buckets + 1))
    )(sorted_b).astype(jnp.int32)
    return LSHTables(planes, offsets, classes)


def selective_active(f, labels, tables: LSHTables, *, m: int, cap: int):
    """Active classes for a batch: union of LSH buckets hit by each feature,
    plus the labels themselves. Returns (ids [m], valid [m])."""
    fn = _normalize(f).astype(jnp.float32)
    bits = jnp.einsum("bd,rdk->rbk", fn, tables.planes) > 0
    bucket = jnp.sum(bits * (1 << jnp.arange(tables.planes.shape[-1])), axis=-1)
    lo = jnp.take_along_axis(tables.offsets, bucket, axis=1)       # [R, b]
    hi = jnp.take_along_axis(tables.offsets, bucket + 1, axis=1)
    iota = jnp.arange(cap, dtype=jnp.int32)
    take = lo[..., None] + iota                                     # [R,b,cap]
    nnz = tables.classes.shape[1]
    r_idx = jnp.arange(tables.classes.shape[0])[:, None, None]
    cand = tables.classes[r_idx, jnp.clip(take, 0, nnz - 1)]        # [R,b,cap]
    valid_c = take < hi[..., None]
    cand = jnp.where(valid_c, cand, -1).reshape(-1)
    cand = jnp.concatenate([labels.astype(jnp.int32), cand])  # force labels in
    sid = jnp.sort(cand)
    first = jnp.concatenate([jnp.array([True]), sid[1:] != sid[:-1]])
    valid = first & (sid >= 0)
    ylab = jnp.sort(labels.astype(jnp.int32))
    pos = jnp.searchsorted(ylab, sid)
    is_label = ylab[jnp.clip(pos, 0, ylab.shape[0] - 1)] == sid
    score = jnp.where(valid, jnp.where(is_label, 2, 1), 0)  # labels always kept
    top_score, top_pos = jax.lax.top_k(score, m)
    ids = jnp.where(top_score > 0, sid[top_pos], 0)
    return ids.astype(jnp.int32), top_score > 0


def selective_softmax_ce(f, labels, w, tables: LSHTables, *, m: int, cap: int,
                         cosine_scale: float = 16.0):
    """Single-device selective-softmax CE (benchmark-scale)."""
    ids, valid = selective_active(f, labels, tables, m=m, cap=cap)
    fn = _normalize(f).astype(jnp.float32)
    wa = _normalize(w[ids]).astype(jnp.float32)
    logits = fn @ wa.T * cosine_scale
    logits = jnp.where(valid[None, :], logits, -1e30)
    hit = ids[None, :] == labels[:, None]
    pos = jnp.argmax(hit, axis=1)
    corr = jnp.take_along_axis(logits, pos[:, None], axis=1)[:, 0]
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - corr)


# ---------------------------------------------------------------------------
# MACH
# ---------------------------------------------------------------------------


class MACHHead(NamedTuple):
    hashes: jax.Array   # [R, N] int32 bucket of each class per repetition
    w: jax.Array        # [R, B_buckets, D]


def mach_hashes(n_classes: int, n_buckets: int, *, n_rep: int,
                seed: int = 0):
    """Static class->bucket tables [R, n_classes] int32 via universal
    hashing on host: (a*j + b) mod p mod B. The (a, b) draw depends only
    on (seed, n_rep) — NOT on the modulus — so the same family can be
    re-evaluated at a new bucket count (elastic re-bucketing,
    ``repro.elastic.reshard.rebucket_sketch``) and reproduces the stored
    tables exactly when the count is unchanged."""
    import numpy as np
    rng = np.random.default_rng(seed)
    p = 2_147_483_647
    a = rng.integers(1, p // 2, size=(n_rep, 1)).astype(np.int64) * 2 + 1
    b = rng.integers(0, p, size=(n_rep, 1)).astype(np.int64)
    j = np.arange(n_classes, dtype=np.int64)[None, :]
    return ((a * j + b) % p % n_buckets).astype(np.int32)


def init_mach(key, n_classes: int, d: int, *, n_buckets: int, n_rep: int,
              seed: int = 0):
    hashes = jnp.asarray(mach_hashes(n_classes, n_buckets, n_rep=n_rep,
                                     seed=seed))
    w = jax.random.normal(key, (n_rep, n_buckets, d), jnp.float32) / jnp.sqrt(d)
    return MACHHead(hashes, w)


def mach_loss(head: MACHHead, f, labels):
    """Sum of R bucket-level CE losses."""
    fl = f.astype(jnp.float32)
    logits = jnp.einsum("bd,rkd->rbk", fl, head.w)  # [R, batch, B]
    ybuck = head.hashes[:, labels]                  # [R, batch]
    logz = jax.nn.logsumexp(logits, axis=-1)
    corr = jnp.take_along_axis(logits, ybuck[:, :, None], axis=2)[:, :, 0]
    return jnp.mean(jnp.sum(logz - corr, axis=0))


def mach_predict(head: MACHHead, f):
    """argmax_j mean_r P_r(hash_r(j) | f) — [batch] class predictions."""
    fl = f.astype(jnp.float32)
    logits = jnp.einsum("bd,rkd->rbk", fl, head.w)
    probs = jax.nn.softmax(logits, axis=-1)         # [R, batch, B]
    class_scores = jnp.mean(
        jnp.take_along_axis(
            probs[:, :, :], head.hashes[:, None, :].repeat(f.shape[0], 1),
            axis=2),
        axis=0)                                     # [batch, N]
    return jnp.argmax(class_scores, axis=-1)


# ---------------------------------------------------------------------------
# distributed (shard_map) counterparts — hybrid-parallel baselines so the
# Table-2 comparison trains every head under identical mesh conditions
# ---------------------------------------------------------------------------


def build_sharded_lsh_tables(key, w, n_shards: int, n_tables: int,
                             n_bits: int):
    """Per-model-shard LSH tables over the row shards of ``w`` [V, D].

    One shared set of hyperplanes (so every shard hashes features the same
    way); per-shard bucket CSR over LOCAL class ids. Each local class lands
    in exactly one bucket per table, so nnz per (shard, table) is exactly
    V_loc — the CSR needs no padding.

    Returns arrays placeable on the mesh:
      planes  [R, D, n_bits]          replicated
      offsets [P, R, n_buckets+1]     sharded over the model axis
      classes [P, R, V_loc]           sharded over the model axis
    """
    v, d = w.shape
    assert v % n_shards == 0, f"V={v} not divisible by shards={n_shards}"
    v_loc = v // n_shards
    planes = jax.random.normal(key, (n_tables, d, n_bits), jnp.float32)
    n_buckets = 1 << n_bits

    def one_shard(wp):
        wn = _normalize(wp).astype(jnp.float32)
        bits = jnp.einsum("nd,rdb->rnb", wn, planes) > 0
        bucket = jnp.sum(bits * (1 << jnp.arange(n_bits)), axis=-1)  # [R,V_loc]
        order = jnp.argsort(bucket, axis=1)
        classes = jnp.take_along_axis(
            jnp.broadcast_to(jnp.arange(v_loc, dtype=jnp.int32)[None],
                             (n_tables, v_loc)), order, axis=1)
        sorted_b = jnp.take_along_axis(bucket, order, axis=1)
        offsets = jax.vmap(
            lambda sb: jnp.searchsorted(sb, jnp.arange(n_buckets + 1))
        )(sorted_b).astype(jnp.int32)
        return offsets, classes

    offsets, classes = jax.vmap(one_shard)(
        w.astype(jnp.float32).reshape(n_shards, v_loc, d))
    return planes, offsets, classes


def selective_softmax_local(
    f_loc, y_loc, w_loc, planes, offsets_loc, classes_loc, *,
    model_axis, batch_axes, global_batch: int, m_local: int, cap: int,
    cosine_scale: float = 16.0, backend: str = "ref", block_a: int = 128,
):
    """shard_map body for the selective-softmax loss (HF-A flavored),
    counterpart of ``full_softmax_local``.

    Each model shard selects up to ``m_local`` active LOCAL classes: the
    union of the LSH buckets hit by every feature in the (gathered) batch,
    force-including the labels this shard owns, then completes the
    distributed CE with the usual pmax/psum pair. LSH recall is imperfect,
    so non-label neighbors can be missing from Z — the accuracy gap the
    paper's Table 2 shows.

    offsets_loc [1, R, n_buckets+1] / classes_loc [1, R, V_loc] arrive with
    the leading model-shard axis; planes [R, D, n_bits] are replicated.
    """
    offsets = offsets_loc.reshape(offsets_loc.shape[-2:])
    classes = classes_loc.reshape(classes_loc.shape[-2:])
    v_loc = w_loc.shape[0]
    v_start = _flat_axis_index(model_axis) * v_loc
    y_rel = (y_loc - v_start).astype(jnp.int32)
    owned_label = (y_rel >= 0) & (y_rel < v_loc)
    y_local = jnp.where(owned_label, y_rel, -1)

    # hash every feature through the shared planes, gather local candidates
    fn = _normalize(f_loc).astype(jnp.float32)
    n_bits = planes.shape[-1]
    bits = jnp.einsum("bd,rdk->rbk", fn, planes) > 0
    bucket = jnp.sum(bits * (1 << jnp.arange(n_bits)), axis=-1)      # [R, b]
    lo = jnp.take_along_axis(offsets, bucket, axis=1)
    hi = jnp.take_along_axis(offsets, bucket + 1, axis=1)
    iota = jnp.arange(cap, dtype=jnp.int32)
    take = lo[..., None] + iota                                      # [R,b,cap]
    nnz = classes.shape[1]
    r_idx = jnp.arange(classes.shape[0])[:, None, None]
    cand = classes[r_idx, jnp.clip(take, 0, nnz - 1)]
    cand = jnp.where(take < hi[..., None], cand, -1).reshape(-1)
    cand = jnp.concatenate([y_local, cand])          # force owned labels in

    # dedup; keep labels unconditionally, then highest-score candidates
    sid = jnp.sort(cand)
    first = jnp.concatenate([jnp.array([True]), sid[1:] != sid[:-1]])
    valid = first & (sid >= 0)
    ylab = jnp.sort(y_local)
    pos = jnp.searchsorted(ylab, sid)
    is_label = ylab[jnp.clip(pos, 0, ylab.shape[0] - 1)] == sid
    score = jnp.where(valid, jnp.where(is_label, 2, 1), 0)
    take_n = min(m_local, score.shape[0])
    top_score, top_pos = jax.lax.top_k(score, take_n)
    ids = sid[top_pos]
    mask = top_score > 0
    if take_n < m_local:
        pad = m_local - take_n
        ids = jnp.concatenate([ids, jnp.zeros((pad,), ids.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros((pad,), bool)])
    ids = jnp.where(mask, ids, 0).astype(jnp.int32)

    hit = (ids[None, :] == y_rel[:, None]) & mask[None, :]
    owned = owned_label & jnp.any(hit, axis=1)

    if backend == "pallas":
        # fused active-class sparse CE: gather + online softmax in one
        # streamed sweep — the dense [b, m_local] logit tensor never forms
        f = _normalize(f_loc).astype(jnp.float32)
        wn = _normalize(w_loc).astype(jnp.float32)
        gids = v_start + ids
        bias = jnp.zeros((ids.shape[0],), jnp.float32)
        m, z, corr, amax = ops.sparse_ce_stats(
            f, wn, ids, gids, bias, mask.astype(jnp.int32), y_loc,
            cosine_scale, block_a, False)
        corr = jnp.where(owned, corr, 0.0)
        pred_gid = jnp.where(amax >= 0, gids[jnp.maximum(amax, 0)], -1)
        loss, metrics = _finish_ce_stats(m, z, corr, pred_gid, y_loc, owned,
                                         model_axis, tuple(batch_axes),
                                         1.0 / global_batch)
    else:
        dt = f_loc.dtype
        f = _normalize(f_loc)
        w_act = _normalize(w_loc[ids])
        logits = jnp.einsum("bd,md->bm", f, w_act.astype(dt),
                            preferred_element_type=jnp.float32) * cosine_scale
        logits = jnp.where(mask[None, :], logits, -1e30)
        lpos = jnp.argmax(hit, axis=1).astype(jnp.int32)
        loss, metrics = _finish_ce(logits, lpos, owned, model_axis,
                                   tuple(batch_axes), 1.0 / global_batch)
    max_t = model_axis if isinstance(model_axis, tuple) else (model_axis,)
    metrics["active_frac"] = jax.lax.pmean(
        jnp.mean(mask.astype(jnp.float32)), max_t + tuple(batch_axes))
    found = jax.lax.psum(owned.astype(jnp.float32), model_axis)
    metrics["label_recall"] = jax.lax.psum(
        jnp.sum(found), tuple(batch_axes)) / global_batch
    return loss, metrics


def mach_softmax_local(f_loc, y_loc, w_loc, hashes, *, model_axis,
                       batch_axes, global_batch: int, backend: str = "ref",
                       block_v: int = 512):
    """shard_map body for the MACH loss: R independent B-way softmaxes with
    the BUCKET axis sharded over the model axis (log-memory per device).

    w_loc [R, B_loc, D] local bucket shards; hashes [R, N] replicated. Each
    rep's CE is completed distributedly by folding the rep axis into the
    batch of the shared CE tail; the returned loss matches ``mach_loss``
    (mean over samples of the sum of R bucket CEs). ``backend="pallas"``
    streams each rep's bucket scoring through the fused-CE kernel instead
    of the dense [R, b, B_loc] einsum.
    """
    fl = f_loc.astype(jnp.float32)
    n_rep, b_loc = w_loc.shape[0], w_loc.shape[1]
    b = f_loc.shape[0]
    b_start = _flat_axis_index(model_axis) * b_loc
    ybuck = hashes[:, y_loc]                                  # [R, b] global
    rel = (ybuck - b_start).astype(jnp.int32)
    owned = (rel >= 0) & (rel < b_loc)

    if backend == "pallas":
        limit = jnp.asarray(b_loc, jnp.int32)
        stats = [ops.ce_shard_stats(
                     fl, w_loc[r].astype(jnp.float32),
                     jnp.where(owned[r], rel[r], -1), limit, 1.0,
                     min(block_v, max(8, b_loc)))
                 for r in range(n_rep)]                       # R small
        m, z, corr, amax = (jnp.concatenate([s[i] for s in stats])
                            for i in range(4))
        pred_gid = jnp.where(amax >= 0, b_start + amax, -1)
        loss, metrics = _finish_ce_stats(
            m, z, corr, pred_gid, ybuck.reshape(n_rep * b),
            owned.reshape(n_rep * b), model_axis, tuple(batch_axes),
            1.0 / global_batch)
    else:
        logits = jnp.einsum("bd,rkd->rbk", fl, w_loc.astype(jnp.float32),
                            preferred_element_type=jnp.float32)  # [R,b,B_loc]
        loss, metrics = _finish_ce(
            logits.reshape(n_rep * b, b_loc),
            jnp.clip(rel, 0, b_loc - 1).reshape(n_rep * b),
            owned.reshape(n_rep * b), model_axis, tuple(batch_axes),
            1.0 / global_batch)
    metrics = dict(metrics)
    # CE-tail accuracy counted one hit per (rep, sample): report the
    # per-rep mean bucket accuracy
    metrics["accuracy"] = metrics["accuracy"] / n_rep
    return loss, metrics


def mach_predict_local(f_loc, w_loc, hashes, *, model_axis):
    """Distributed MACH inference: [b] class predictions.

    Per-rep distributed softmax over the sharded buckets (pmax/psum), then
    each shard contributes P_r(hash_r(j)) for the classes whose bucket it
    owns; one psum over the model axis assembles the full [b, N] score.
    """
    fl = f_loc.astype(jnp.float32)
    logits = jnp.einsum("bd,rkd->rbk", fl, w_loc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)  # [R, b, B_loc]
    b_loc = logits.shape[-1]
    m = jax.lax.pmax(jnp.max(logits, axis=-1), model_axis)    # [R, b]
    z = jax.lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                     model_axis)
    probs = jnp.exp(logits - m[..., None]) / z[..., None]     # local buckets
    b_start = _flat_axis_index(model_axis) * b_loc
    rel = hashes - b_start                                    # [R, N]
    local = (rel >= 0) & (rel < b_loc)
    idx = jnp.clip(rel, 0, b_loc - 1)
    # accumulate per rep: peak memory [b, N], not [R, b, N] (MACH's whole
    # point is log-memory — don't give it back at eval time)
    scores = jnp.zeros((probs.shape[1], hashes.shape[1]), jnp.float32)
    for r in range(probs.shape[0]):
        sc = probs[r][:, idx[r]]                              # [b, N]
        scores = scores + jnp.where(local[r][None, :], sc, 0.0)
    scores = jax.lax.psum(scores, model_axis)                 # [b, N]
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# sampled softmax [Jean et al., ACL'15] — logQ-corrected negative sampling
# ---------------------------------------------------------------------------


def _axis_prod(axis) -> int:
    """Static total size of one axis name or a tuple of axis names."""
    if isinstance(axis, str):
        return jax.lax.axis_size(axis)
    n = 1
    for a in axis:
        n *= jax.lax.axis_size(a)
    return n


def sampled_softmax_local(
    f_loc, y_loc, w_loc, *, model_axis, batch_axes, global_batch: int,
    n_samples: int, distribution: str = "uniform", seed: int = 17,
    cosine_scale: float = 16.0, n_valid: int = 0, step=None,
    backend: str = "ref", block_a: int = 128,
):
    """shard_map body for sampled-softmax CE, counterpart of
    ``full_softmax_local``: the true label plus a drawn negative set, with
    the standard logQ correction (logits minus the log expected count of
    each candidate under the proposal distribution).

    Two proposal modes (selected at trace time):

    * ``"uniform"`` — each class shard draws ``n_samples / n_shards`` LOCAL
      classes without replacement (a stratified draw over the class axis, so
      no candidate ids ever cross devices). The inclusion probability
      m_loc/V_loc is a constant, so the correction cancels in the softmax;
      at ``n_samples >= V`` every class is drawn and the loss equals the
      full softmax exactly.
    * ``"log_uniform"`` — the classic Zipfian LM sampler: all shards draw
      the SAME ``n_samples`` global ids with replacement (identical PRNG
      key along the model axis), each shard scores the ids it owns, and the
      correction uses log(n_samples * q(j)).

    Sampler randomness is derived from (seed, step, labels): ``step`` is the
    replicated training-step scalar threaded by the trainers (None falls
    back to labels-only salting), and folding the label sum keeps negatives
    varying across micro-batches within one step.
    """
    v_loc = w_loc.shape[0]
    n_shards = _axis_prod(model_axis)
    n_eff = n_valid or v_loc * n_shards
    shard = _flat_axis_index(model_axis)
    v_start = shard * v_loc
    y_rel = (y_loc - v_start).astype(jnp.int32)
    owned = (y_rel >= 0) & (y_rel < v_loc)

    # identical salt on every model shard (y_loc is replicated along it)
    salt = jnp.sum(y_loc.astype(jnp.uint32))
    if step is not None:
        salt = salt + step.astype(jnp.uint32) * jnp.uint32(2654435761)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), salt)

    if distribution == "uniform":
        m_loc = max(1, min(v_loc, n_samples // n_shards))
        perm = jax.random.permutation(jax.random.fold_in(key, shard), v_loc)
        ids = perm[:m_loc].astype(jnp.int32)           # local, distinct
        samp_valid = jnp.ones((m_loc,), bool)
        if n_valid:
            samp_valid &= (v_start + ids) < n_valid
        # inclusion probability of a draw without replacement
        logq = jnp.full((m_loc,), jnp.log(m_loc / v_loc), jnp.float32)
        logq_y = jnp.log(jnp.float32(m_loc) / v_loc)
        sample_frac = jnp.asarray(m_loc * n_shards / n_eff, jnp.float32)
    elif distribution == "log_uniform":
        m = n_samples
        u = jax.random.uniform(key, (m,), jnp.float32)  # same on all shards
        gid = (jnp.exp(u * jnp.log(float(n_eff + 1))) - 1.0).astype(jnp.int32)
        gid = jnp.clip(gid, 0, n_eff - 1)
        q = jnp.log((gid + 2.0) / (gid + 1.0)) / jnp.log(float(n_eff + 1))
        logq = jnp.log(jnp.float32(m) * q)              # log expected count
        rel = gid - v_start
        samp_valid = (rel >= 0) & (rel < v_loc)         # ownership mask
        ids = jnp.clip(rel, 0, v_loc - 1)
        qy = (jnp.log((y_loc + 2.0) / (y_loc + 1.0))
              / jnp.log(float(n_eff + 1)))
        logq_y = jnp.log(jnp.float32(m) * qy)
        sample_frac = jnp.asarray(min(m, n_eff) / n_eff, jnp.float32)
    else:
        raise ValueError(f"unknown sampled distribution {distribution!r}")

    dt = f_loc.dtype
    f, w = ((_normalize(f_loc), _normalize(w_loc)) if cosine_scale > 0
            else (f_loc, w_loc.astype(dt)))
    scale = cosine_scale if cosine_scale > 0 else 1.0

    # the true label: scored by its owning shard, same correction applied
    w_y = w[jnp.clip(y_rel, 0, v_loc - 1)]
    logit_y = (jnp.einsum("bd,bd->b", f, w_y.astype(dt),
                          preferred_element_type=jnp.float32) * scale
               - logq_y)
    logit_y = jnp.where(owned, logit_y, NEG_INF)

    if backend == "pallas":
        # fused candidate-set CE with the logQ correction as a per-column
        # bias; accidental hits (a sampled id equal to the row's own label)
        # are masked IN-KERNEL (mask_hits) so z never double-counts a class.
        # The [b, m] candidate logit tensor never forms; the label column is
        # folded into the per-row online stats below.
        gids = v_start + ids
        m_s, z_s, _, amax_s = ops.sparse_ce_stats(
            f.astype(jnp.float32), w.astype(jnp.float32), ids, gids,
            -logq, samp_valid.astype(jnp.int32), y_loc, scale, block_a,
            True)
        m_row = jax.lax.stop_gradient(jnp.maximum(m_s, logit_y))
        z_resc = jnp.where(jnp.isfinite(m_s), jnp.exp(
            jax.lax.stop_gradient(m_s) - m_row), 0.0)
        z_row = (z_s * z_resc
                 + jnp.where(owned, jnp.exp(logit_y - m_row), 0.0))
        corr_row = jnp.where(owned, logit_y, 0.0)
        best_is_label = owned & (logit_y >= m_s)
        pred_gid = jnp.where(
            best_is_label, y_loc,
            jnp.where(amax_s >= 0, gids[jnp.maximum(amax_s, 0)], -1))
        loss, metrics = _finish_ce_stats(m_row, z_row, corr_row, pred_gid,
                                         y_loc, owned, model_axis,
                                         tuple(batch_axes),
                                         1.0 / global_batch)
    else:
        logits_s = jnp.einsum("bd,md->bm", f, w[ids].astype(dt),
                              preferred_element_type=jnp.float32) * scale
        logits_s = logits_s - logq[None, :]
        # drop invalid columns and accidental hits (a sampled id equal to
        # the row's own label would double-count that class in Z)
        acc_hit = (v_start + ids)[None, :] == y_loc[:, None]
        logits_s = jnp.where(samp_valid[None, :] & ~acc_hit, logits_s,
                             NEG_INF)
        logits = jnp.concatenate([logits_s, logit_y[:, None]], axis=1)
        label_col = jnp.full((f_loc.shape[0],), logits_s.shape[1], jnp.int32)
        loss, metrics = _finish_ce(logits, label_col, owned, model_axis,
                                   tuple(batch_axes), 1.0 / global_batch)
    metrics = dict(metrics)
    metrics["sample_frac"] = sample_frac
    return loss, metrics


# ---------------------------------------------------------------------------
# CSoft count-min sketch decode (training reuses mach_softmax_local: the
# sketch is trained as R small softmaxes, exactly MACH's loss)
# ---------------------------------------------------------------------------


def csoft_predict_local(f_loc, w_loc, hashes, *, model_axis, agg: str = "min"):
    """Distributed count-min-sketch decode: [b] class predictions.

    Per-row distributed LOG-softmax over the sharded buckets, then class j
    is scored by aggregating log P_r(hash_r(j)) across the R hash rows:
    ``agg="min"`` takes the count-min lower bound (every row over-counts j
    by whatever collides into its bucket, so the min is the tightest
    estimate); ``agg="mean"`` is the geometric mean of the row
    probabilities. Peak memory is [b, N] per rep, not [R, b, N].
    """
    fl = f_loc.astype(jnp.float32)
    logits = jnp.einsum("bd,rkd->rbk", fl, w_loc.astype(jnp.float32),
                        preferred_element_type=jnp.float32)  # [R, b, B_loc]
    b_loc = logits.shape[-1]
    m = jax.lax.pmax(jnp.max(logits, axis=-1), model_axis)    # [R, b]
    z = jax.lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1),
                     model_axis)
    logp = logits - m[..., None] - jnp.log(z)[..., None]      # local buckets
    b_start = _flat_axis_index(model_axis) * b_loc
    rel = hashes - b_start                                    # [R, N]
    local = (rel >= 0) & (rel < b_loc)
    idx = jnp.clip(rel, 0, b_loc - 1)
    scores = None
    for r in range(logp.shape[0]):
        sc = logp[r][:, idx[r]]                               # [b, N]
        sc = jax.lax.psum(jnp.where(local[r][None, :], sc, 0.0), model_axis)
        if scores is None:
            scores = sc
        elif agg == "min":
            scores = jnp.minimum(scores, sc)
        else:
            scores = scores + sc
    if agg == "mean":
        scores = scores / logp.shape[0]
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)
