"""Baseline softmax approximations the paper compares against (§4.1).

* Selective softmax [Zhang et al., AAAI'18] — HF-A flavored: active classes
  are chosen by locality-sensitive hashing of the *features* (random
  hyperplane tables over the normalized weights, queried by each sample's
  feature hash). Unlike the KNN graph, LSH recall is imperfect, so the true
  label may be missing from the active set — we force-include it (as HF-A's
  class-level updates effectively do) but neighbors can be lost, which is
  the accuracy gap Table 2 shows.

* MACH [Medini et al., NeurIPS'19] — R independent hash functions map N
  classes to B buckets; train R B-way softmaxes; score class j at inference
  by averaging P_r(hash_r(j)). Log-memory, but lossy (Table 2).

Both are implemented as real trainable heads so the Table-2-style benchmark
can train all four methods under identical conditions.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sharded_softmax import _normalize

# ---------------------------------------------------------------------------
# selective softmax (LSH active classes)
# ---------------------------------------------------------------------------


class LSHTables(NamedTuple):
    planes: jax.Array      # [R, D, n_bits] random hyperplanes
    offsets: jax.Array     # [R, n_buckets+1] CSR per table
    classes: jax.Array     # [R, nnz] class ids sorted by bucket


def build_lsh_tables(key, w, n_tables: int, n_bits: int) -> LSHTables:
    n, d = w.shape
    planes = jax.random.normal(key, (n_tables, d, n_bits), jnp.float32)
    wn = _normalize(w).astype(jnp.float32)
    bits = (jnp.einsum("nd,rdb->rnb", wn, planes) > 0)
    bucket = jnp.sum(bits * (1 << jnp.arange(n_bits)), axis=-1)  # [R, N]
    n_buckets = 1 << n_bits
    order = jnp.argsort(bucket, axis=1)
    classes = jnp.take_along_axis(
        jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (n_tables, n)),
        order, axis=1)
    sorted_b = jnp.take_along_axis(bucket, order, axis=1)
    offsets = jax.vmap(
        lambda sb: jnp.searchsorted(sb, jnp.arange(n_buckets + 1))
    )(sorted_b).astype(jnp.int32)
    return LSHTables(planes, offsets, classes)


def selective_active(f, labels, tables: LSHTables, *, m: int, cap: int):
    """Active classes for a batch: union of LSH buckets hit by each feature,
    plus the labels themselves. Returns (ids [m], valid [m])."""
    fn = _normalize(f).astype(jnp.float32)
    bits = jnp.einsum("bd,rdk->rbk", fn, tables.planes) > 0
    bucket = jnp.sum(bits * (1 << jnp.arange(tables.planes.shape[-1])), axis=-1)
    lo = jnp.take_along_axis(tables.offsets, bucket, axis=1)       # [R, b]
    hi = jnp.take_along_axis(tables.offsets, bucket + 1, axis=1)
    iota = jnp.arange(cap, dtype=jnp.int32)
    take = lo[..., None] + iota                                     # [R,b,cap]
    nnz = tables.classes.shape[1]
    r_idx = jnp.arange(tables.classes.shape[0])[:, None, None]
    cand = tables.classes[r_idx, jnp.clip(take, 0, nnz - 1)]        # [R,b,cap]
    valid_c = take < hi[..., None]
    cand = jnp.where(valid_c, cand, -1).reshape(-1)
    cand = jnp.concatenate([labels.astype(jnp.int32), cand])  # force labels in
    sid = jnp.sort(cand)
    first = jnp.concatenate([jnp.array([True]), sid[1:] != sid[:-1]])
    valid = first & (sid >= 0)
    ylab = jnp.sort(labels.astype(jnp.int32))
    pos = jnp.searchsorted(ylab, sid)
    is_label = ylab[jnp.clip(pos, 0, ylab.shape[0] - 1)] == sid
    score = jnp.where(valid, jnp.where(is_label, 2, 1), 0)  # labels always kept
    top_score, top_pos = jax.lax.top_k(score, m)
    ids = jnp.where(top_score > 0, sid[top_pos], 0)
    return ids.astype(jnp.int32), top_score > 0


def selective_softmax_ce(f, labels, w, tables: LSHTables, *, m: int, cap: int,
                         cosine_scale: float = 16.0):
    """Single-device selective-softmax CE (benchmark-scale)."""
    ids, valid = selective_active(f, labels, tables, m=m, cap=cap)
    fn = _normalize(f).astype(jnp.float32)
    wa = _normalize(w[ids]).astype(jnp.float32)
    logits = fn @ wa.T * cosine_scale
    logits = jnp.where(valid[None, :], logits, -1e30)
    hit = ids[None, :] == labels[:, None]
    pos = jnp.argmax(hit, axis=1)
    corr = jnp.take_along_axis(logits, pos[:, None], axis=1)[:, 0]
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - corr)


# ---------------------------------------------------------------------------
# MACH
# ---------------------------------------------------------------------------


class MACHHead(NamedTuple):
    hashes: jax.Array   # [R, N] int32 bucket of each class per repetition
    w: jax.Array        # [R, B_buckets, D]


def init_mach(key, n_classes: int, d: int, *, n_buckets: int, n_rep: int,
              seed: int = 0):
    import numpy as np
    # universal hashing on host: (a*j + b) mod p mod B (static tables)
    rng = np.random.default_rng(seed)
    p = 2_147_483_647
    a = rng.integers(1, p // 2, size=(n_rep, 1)).astype(np.int64) * 2 + 1
    b = rng.integers(0, p, size=(n_rep, 1)).astype(np.int64)
    j = np.arange(n_classes, dtype=np.int64)[None, :]
    hashes = jnp.asarray(((a * j + b) % p % n_buckets).astype(np.int32))
    w = jax.random.normal(key, (n_rep, n_buckets, d), jnp.float32) / jnp.sqrt(d)
    return MACHHead(hashes, w)


def mach_loss(head: MACHHead, f, labels):
    """Sum of R bucket-level CE losses."""
    fl = f.astype(jnp.float32)
    logits = jnp.einsum("bd,rkd->rbk", fl, head.w)  # [R, batch, B]
    ybuck = head.hashes[:, labels]                  # [R, batch]
    logz = jax.nn.logsumexp(logits, axis=-1)
    corr = jnp.take_along_axis(logits, ybuck[:, :, None], axis=2)[:, :, 0]
    return jnp.mean(jnp.sum(logz - corr, axis=0))


def mach_predict(head: MACHHead, f):
    """argmax_j mean_r P_r(hash_r(j) | f) — [batch] class predictions."""
    fl = f.astype(jnp.float32)
    logits = jnp.einsum("bd,rkd->rbk", fl, head.w)
    probs = jax.nn.softmax(logits, axis=-1)         # [R, batch, B]
    class_scores = jnp.mean(
        jnp.take_along_axis(
            probs[:, :, :], head.hashes[:, None, :].repeat(f.shape[0], 1),
            axis=2),
        axis=0)                                     # [batch, N]
    return jnp.argmax(class_scores, axis=-1)
