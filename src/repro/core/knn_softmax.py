"""KNN softmax (paper §3.2): active-class selection + sparse distributed CE.

Per step, each model shard scores only M_local active classes instead of its
full V_local shard. The active set is Algorithm 1, re-expressed with fixed
shapes for TPU:

  1. quick access: capped CSR gather of each local label's neighbor list
     from the *compressed* graph (paper's custom CUDA kernel -> XLA gather);
  2. dedup keeping the best (lowest) graph rank per class (paper's ranking
     score) via lexsort + first-occurrence masking;
  3. top-M_local by rank; underfull slots are padded with pseudo-random
     non-selected classes (paper line 7) or masked out (``pad_random=False``).

Because W is L2-normalized, each label's own class is neighbor 0 of its own
list, so rank-0 entries always win selection — the lossless-inclusion
property the paper relies on. Normalization of X and W (the paper's
"normalization strategy") makes the logits cosine similarities; a fixed
``cosine_scale`` recovers a usable logit range.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.core.sharded_softmax import (_finish_ce, _finish_ce_stats,
                                        _flat_axis_index, _normalize)

BIG_RANK = 1 << 20


def select_active(
    y_loc, offsets, neighbors, *, v_loc, m_local: int, k_cap: int,
    pad_random: bool = True, seed_salt=0, ranks=None,
):
    """Fixed-shape Algorithm 1 on one model shard.

    y_loc: [b] global labels of this device's batch rows.
    offsets: [N+1] CSR row offsets of the local compressed graph.
    neighbors: [nnz_cap] local class ids.
    ranks: [nnz_cap] ORIGINAL neighbor-list positions (Algorithm 1's ranking
    score). If None, the compressed position is used — only safe when every
    shard sees full rows (uncompressed graphs / tests).
    Returns (active_ids [m_local] local ids, valid [m_local] bool).
    """
    b = y_loc.shape[0]
    lens = (offsets[y_loc + 1] - offsets[y_loc]).astype(jnp.int32)  # [b]
    iota = jnp.arange(k_cap, dtype=jnp.int32)
    take = offsets[y_loc][:, None] + iota[None, :]
    safe_take = jnp.clip(take, 0, neighbors.shape[0] - 1)
    cand = neighbors[safe_take]
    in_row = iota[None, :] < jnp.minimum(lens, k_cap)[:, None]
    cand = jnp.where(in_row, cand, -1)                    # [b, k_cap] local ids
    if ranks is not None:
        rank = jnp.where(in_row, ranks[safe_take], BIG_RANK - 1)
    else:
        rank = jnp.broadcast_to(iota[None, :], cand.shape)  # compressed pos

    flat_id = cand.reshape(-1)
    flat_rank = jnp.where(flat_id >= 0, rank.reshape(-1), BIG_RANK)
    # sort by (id, rank); first occurrence per id = best rank
    order = jnp.lexsort((flat_rank, flat_id))
    sid = flat_id[order]
    srank = flat_rank[order]
    first = jnp.concatenate([jnp.array([True]), sid[1:] != sid[:-1]])
    valid = first & (sid >= 0)
    score = jnp.where(valid, BIG_RANK - srank, -1)
    take = min(m_local, score.shape[0])
    top_score, top_pos = jax.lax.top_k(score, take)
    ids = sid[top_pos]
    mask = top_score >= 0
    if take < m_local:  # fewer candidates than budget: pad (paper line 7)
        pad = m_local - take
        ids = jnp.concatenate([ids, jnp.zeros((pad,), ids.dtype)])
        mask = jnp.concatenate([mask, jnp.zeros((pad,), bool)])

    if pad_random:
        # paper line 7: fill with pseudo-random non-chosen classes. Collisions
        # with chosen classes are masked (a collision would double-count a
        # class in Z). Deterministic per (labels, salt) so recompute-in-bwd
        # under remat is stable.
        key = jax.random.fold_in(jax.random.PRNGKey(17), seed_salt)
        key = jax.random.fold_in(key, jnp.sum(y_loc) % (1 << 30))
        fillers = jax.random.randint(key, (m_local,), 0, v_loc, jnp.int32)
        sorted_ids = jnp.sort(jnp.where(mask, ids, -1))
        pos = jnp.searchsorted(sorted_ids, fillers)
        dup = sorted_ids[jnp.clip(pos, 0, m_local - 1)] == fillers
        ids = jnp.where(mask, ids, fillers)
        mask = mask | ~dup
    ids = jnp.where(mask, ids, 0)
    return ids.astype(jnp.int32), mask


def knn_softmax_local(
    f_loc, y_loc, w_loc, offsets_loc, neighbors_loc, ranks_loc=None, *,
    model_axis: str, batch_axes: Sequence[str], global_batch: int,
    m_local: int, k_cap: int, cosine_scale: float = 16.0,
    pad_random: bool = True, n_valid: int = 0, backend: str = "ref",
    block_a: int = 128,
):
    """shard_map body for the KNN-softmax loss (counterpart of
    full_softmax_local). offsets_loc [1, N+1] / neighbors_loc / ranks_loc
    [1, nnz] arrive with the leading model-shard axis from the sharded
    CompressedGraph. ``backend="pallas"`` replaces the dense
    gather-then-softmax (w_loc[ids] -> [b, m_local] logits) with the fused
    active-class sparse-CE kernel (``ops.sparse_ce_stats``): the gather and
    the online softmax run in one streamed sweep and neither the gathered
    weights nor the logit tensor reach HBM."""
    offsets = offsets_loc.reshape(-1)
    neighbors = neighbors_loc.reshape(-1)
    ranks = ranks_loc.reshape(-1) if ranks_loc is not None else None
    v_loc = w_loc.shape[0]
    v_start = _flat_axis_index(model_axis) * v_loc

    ids, valid = select_active(
        y_loc, offsets, neighbors, v_loc=v_loc, m_local=m_local,
        k_cap=k_cap, pad_random=pad_random, ranks=ranks)
    if n_valid:  # mask padded vocab rows that slipped in as random fillers
        valid = valid & ((v_start + ids) < n_valid)

    # label position within the active set (owner shard only)
    y_rel = (y_loc - v_start).astype(jnp.int32)
    owned = (y_rel >= 0) & (y_rel < v_loc)
    hit = (ids[None, :] == y_rel[:, None]) & valid[None, :]
    owned = owned & jnp.any(hit, axis=1)  # label must be in the active set

    if backend == "pallas":
        f = _normalize(f_loc).astype(jnp.float32)
        wn = _normalize(w_loc).astype(jnp.float32)  # rows; == gather-then-norm
        gids = v_start + ids
        bias = jnp.zeros((ids.shape[0],), jnp.float32)
        m, z, corr, amax = ops.sparse_ce_stats(
            f, wn, ids, gids, bias, valid.astype(jnp.int32), y_loc,
            cosine_scale, block_a, False)
        corr = jnp.where(owned, corr, 0.0)
        pred_gid = jnp.where(amax >= 0, gids[jnp.maximum(amax, 0)], -1)
        loss, metrics = _finish_ce_stats(m, z, corr, pred_gid, y_loc, owned,
                                         model_axis, tuple(batch_axes),
                                         1.0 / global_batch)
    else:
        dt = f_loc.dtype
        f = _normalize(f_loc)
        w_act = _normalize(w_loc[ids])  # [m_local,D]; bwd = scatter-add to W
        logits = jnp.einsum("bd,md->bm", f, w_act.astype(dt),
                            preferred_element_type=jnp.float32) * cosine_scale
        logits = jnp.where(valid[None, :], logits, -1e30)
        pos = jnp.argmax(hit, axis=1).astype(jnp.int32)
        loss, metrics = _finish_ce(logits, pos, owned, model_axis,
                                   tuple(batch_axes), 1.0 / global_batch)
    max_t = model_axis if isinstance(model_axis, tuple) else (model_axis,)
    metrics["active_frac"] = jax.lax.pmean(
        jnp.mean(valid.astype(jnp.float32)), max_t + tuple(batch_axes))
    found = jax.lax.psum(owned.astype(jnp.float32), model_axis)  # [b] 0/1
    metrics["label_recall"] = jax.lax.psum(
        jnp.sum(found), tuple(batch_axes)) / global_batch
    return loss, metrics


def knn_softmax_ref(features, labels, w, graph, *, m: int,
                    cosine_scale: float = 16.0, pad_random: bool = False):
    """Single-device oracle of the KNN-softmax loss (graph: [N, k] global
    ids). Mirrors the selection semantics with one "shard" owning all of W."""
    n = w.shape[0]
    cand = graph[labels]                       # [b, k]
    rank = jnp.broadcast_to(jnp.arange(graph.shape[1])[None], cand.shape)
    flat_id = cand.reshape(-1)
    flat_rank = rank.reshape(-1)
    order = jnp.lexsort((flat_rank, flat_id))
    sid, srank = flat_id[order], flat_rank[order]
    first = jnp.concatenate([jnp.array([True]), sid[1:] != sid[:-1]])
    score = jnp.where(first, BIG_RANK - srank, -1)
    top_score, top_pos = jax.lax.top_k(score, m)
    ids = jnp.where(top_score >= 0, sid[top_pos], 0)
    maskv = top_score >= 0

    f = features.astype(jnp.float32)
    f = f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-12)
    wa = w[ids].astype(jnp.float32)
    wa = wa / (jnp.linalg.norm(wa, axis=-1, keepdims=True) + 1e-12)
    logits = f @ wa.T * cosine_scale
    logits = jnp.where(maskv[None, :], logits, -1e30)
    hit = ids[None, :] == labels[:, None]
    pos = jnp.argmax(hit, axis=1)
    corr = jnp.take_along_axis(logits, pos[:, None], axis=1)[:, 0]
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - corr)
