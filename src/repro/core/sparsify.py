"""Layer-wise top-k gradient sparsification (paper §3.3.2, DGC-style).

Exact DGC semantics — momentum correction, error accumulation (feedback),
momentum factor masking — applied to the *data-parallel* (feature extraction)
gradients only; the model-parallel fc gradients never cross devices (§3.1).

TPU adaptation (DESIGN.md §2): XLA has no sparse all-reduce, so the exchange
is a masked-dense psum whose *wire* bytes are accounted analytically
(``wire_bytes``: k × (4B value + 4B index) per tensor) for the roofline and
the Table-4 model; the top-k *selection* — the part the paper spends §3.3.2
optimizing — is real compute and runs through the divide-and-conquer
selector. ``DGCConfig.backend`` picks the stage-1 implementation:
``"pallas"`` runs the ``kernels.ops.topk_threshold`` kernel, ``"ref"`` the
pure-jnp formulation below (same chunked algorithm, ``lax.top_k`` stage 1).

"Grouping tensors with similar size" (Fig. 5) is implemented by packing
flattened leaves into ~equal byte buckets and running one selection per
bucket.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import DGCConfig


class DGCState(NamedTuple):
    u: dict  # momentum-corrected accumulator (per FE leaf)
    v: dict  # error-feedback residual (per FE leaf)


def init_dgc_state(fe_params) -> DGCState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), fe_params)
    return DGCState(u=z, v=jax.tree.map(jnp.copy, z))


# ---------------------------------------------------------------------------
# top-k selection backends
# ---------------------------------------------------------------------------


def topk_threshold_ref(flat_abs: jax.Array, k: int) -> jax.Array:
    """|v| threshold keeping exactly the top-k entries (jnp oracle)."""
    vals, _ = jax.lax.top_k(flat_abs, k)
    return vals[-1]


def topk_threshold_dc(flat_abs: jax.Array, k: int, chunk: int = 2048) -> jax.Array:
    """Divide-and-conquer top-k (paper Fig. 5), pure-jnp formulation:
    chunk -> per-chunk top-k (parallel) -> top-k of the M*k survivors.
    EXACT for thresholding: the global k-th largest is always within the
    per-chunk top-k survivors. The Pallas TPU kernel implements stage 1;
    see repro.kernels.topk_dc."""
    n = flat_abs.shape[0]
    if n <= chunk:
        return topk_threshold_ref(flat_abs, min(k, n))
    pad = (-n) % chunk
    x = jnp.pad(flat_abs, (0, pad), constant_values=-jnp.inf)
    chunks = x.reshape(-1, chunk)
    kk = min(k, chunk)
    sub, _ = jax.lax.top_k(chunks, kk)          # [M, kk] parallel stage
    merged = sub.reshape(-1)
    vals, _ = jax.lax.top_k(merged, min(k, merged.shape[0]))
    return vals[-1]


# ---------------------------------------------------------------------------
# tensor grouping
# ---------------------------------------------------------------------------


def group_leaves(leaves: Sequence[jax.Array], group_bytes: int):
    """Pack leaf indices into buckets of ~group_bytes (paper's grouping)."""
    groups, cur, cur_bytes = [], [], 0
    for i, leaf in enumerate(leaves):
        nbytes = leaf.size * 4
        if cur and cur_bytes + nbytes > group_bytes:
            groups.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nbytes
    if cur:
        groups.append(cur)
    return groups


# ---------------------------------------------------------------------------
# the exchange
# ---------------------------------------------------------------------------


def dgc_exchange(
    grads, state: DGCState, cfg: DGCConfig, *,
    batch_axes: Optional[Sequence[str]] = None,
    n_workers: int = 1,
    topk_fn: Optional[Callable] = None,
):
    """One DGC round on the FE gradient pytree.

    Inside a shard_map over the data axes, pass batch_axes to psum the masked
    tensors; outside (single device / tests), batch_axes=None skips comm.

    Returns (averaged dense update pytree, new state, info dict with wire
    accounting).
    """
    if topk_fn is not None:
        topk = topk_fn
    elif cfg.backend == "pallas":
        from repro.kernels import ops
        topk = functools.partial(ops.topk_threshold, chunk=cfg.chunk)
    else:
        topk = functools.partial(topk_threshold_dc, chunk=cfg.chunk)
    leaves, treedef = jax.tree.flatten(grads)
    u_leaves = treedef.flatten_up_to(state.u)
    v_leaves = treedef.flatten_up_to(state.v)

    groups = group_leaves(leaves, cfg.group_bytes)
    out, new_u, new_v = [None] * len(leaves), [None] * len(leaves), [None] * len(leaves)
    wire_bytes = jnp.zeros((), jnp.float32)
    dense_bytes = 0

    for grp in groups:
        flats, us, vs = [], [], []
        for i in grp:
            g = leaves[i].astype(jnp.float32).reshape(-1)
            u = cfg.momentum * u_leaves[i].reshape(-1) + g   # momentum corr.
            v = v_leaves[i].reshape(-1) + u                  # error feedback
            flats.append(g)
            us.append(u)
            vs.append(v)
        vflat = jnp.concatenate(vs) if len(vs) > 1 else vs[0]
        n = vflat.shape[0]
        k = max(1, int(n * (1.0 - cfg.sparsity)))
        thr = topk(jnp.abs(vflat), k)
        mask = jnp.abs(vflat) >= thr
        send = jnp.where(mask, vflat, 0.0)
        if batch_axes:
            agg = jax.lax.psum(send, tuple(batch_axes)) / n_workers
        else:
            agg = send
        resid = jnp.where(mask, 0.0, vflat)
        wire_bytes = wire_bytes + jnp.sum(mask.astype(jnp.float32)) * 8.0
        dense_bytes += n * 4

        off = 0
        for j, i in enumerate(grp):
            sz = leaves[i].size
            sl = slice(off, off + sz)
            out[i] = agg[sl].reshape(leaves[i].shape)
            new_v[i] = resid[sl].reshape(leaves[i].shape)
            um = us[j]
            if cfg.factor_masking:
                um = jnp.where(mask[sl], 0.0, um)            # factor masking
            new_u[i] = um.reshape(leaves[i].shape)
            off += sz

    info = {"wire_bytes": wire_bytes,
            "dense_bytes": jnp.asarray(dense_bytes, jnp.float32),
            "compression": jnp.asarray(dense_bytes, jnp.float32)
            / jnp.maximum(wire_bytes, 1.0)}
    return (treedef.unflatten(out),
            DGCState(u=treedef.unflatten(new_u), v=treedef.unflatten(new_v)),
            info)


def dense_exchange(grads, *, batch_axes: Optional[Sequence[str]] = None,
                   n_workers: int = 1):
    """Baseline dense all-reduce of FE grads (paper's no-DGC path)."""
    if not batch_axes:
        return grads
    return jax.tree.map(
        lambda g: jax.lax.psum(g, tuple(batch_axes)) / n_workers, grads)
