"""Fast continuous convergence strategy — FCCS (paper §3.4).

Global policy:
  * learning rate: linear warm-up to eta0 over T_warm, then CONSTANT —
    decay is replaced by batch growth (Smith et al. '17);
  * batch size: B0 until T_ini, then a continuous cosine ramp from B^1_min
    to B^1_max (= 64·B^1_min in the paper's experiments).

NOTE on the cosine sign: the paper's printed f(t) starts at B_max and falls
to B_min, contradicting both its prose ("batch size increases quickly") and
Fig. 7. We implement the increasing ramp (1 - cos)/2 that matches the prose
and figures; the printed form is recoverable with ``decreasing=True``.

Local policy = LARS (optim/lars.py). Batch growth is realized with gradient
accumulation: n(t) = ceil(B_t / B_hw) micro-steps per update, which also cuts
data-parallel communication to ~1/n(t) (§3.4 last paragraph).
"""
from __future__ import annotations

import math

from repro.configs.base import FCCSConfig


def learning_rate(t: int, cfg: FCCSConfig) -> float:
    if t < cfg.t_warm:
        return cfg.eta0 * (t + 1) / cfg.t_warm
    return cfg.eta0


def batch_size(t: int, cfg: FCCSConfig, *, decreasing: bool = False) -> int:
    if t < cfg.t_ini:
        return cfg.b0
    if t >= cfg.t_final:
        return cfg.b_min if decreasing else cfg.b_max
    phase = math.pi * (t - cfg.t_ini) / (cfg.t_final - cfg.t_ini)
    c = math.cos(phase)
    if decreasing:  # paper's printed formula
        f = cfg.b_min + 0.5 * (cfg.b_max - cfg.b_min) * (1 + c)
    else:           # paper's described/plotted behavior
        f = cfg.b_min + 0.5 * (cfg.b_max - cfg.b_min) * (1 - c)
    return int(f)


def accum_steps(t: int, cfg: FCCSConfig, hw_batch: int) -> int:
    """Gradient-accumulation factor n(t) realizing B_t on a fixed device
    batch (paper: 'the actual batch size can be considered as n × b')."""
    return max(1, -(-batch_size(t, cfg) // hw_batch))


def piecewise_decay_lr(t: int, *, eta0: float, steps_per_epoch: int,
                       decay_epochs: int = 5, factor: float = 0.1) -> float:
    """Baseline: decay by 10x every `decay_epochs` epochs (paper §4.3)."""
    epoch = t // max(steps_per_epoch, 1)
    return eta0 * (factor ** (epoch // decay_epochs))


def schedule_summary(cfg: FCCSConfig, total_steps: int, hw_batch: int,
                     every: int = 1):
    """(t, lr, B_t, n_accum) table — used by the Fig. 6/7 benchmark."""
    rows = []
    for t in range(0, total_steps, every):
        rows.append((t, learning_rate(t, cfg), batch_size(t, cfg),
                     accum_steps(t, cfg, hw_batch)))
    return rows
