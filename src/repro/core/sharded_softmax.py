"""Hybrid-parallel distributed softmax cross-entropy (paper §3.1).

The extreme-classification head W [N, D] is split row-wise (by class) across
the ``model`` mesh axis; features arrive batch-sharded over the data axes and
replicated along ``model`` (the all-gather the paper overlaps in §3.3.1 is
what produced that replication). Each device scores its local class shard and
the softmax is completed with two tiny collectives:

    global max  = pmax over "model"   (numerical stability)
    global Z    = psum over "model"   (partition function)
    label logit = psum over "model"   (each class owned by exactly one shard)

The fc gradient stays local to its shard (the paper's key memory/comm win);
only the feature gradient crosses the model axis (inside autodiff of the
einsum) and the scalar loss is averaged over the data axes.

These are *shard_map bodies*: they see local shards and use lax collectives
explicitly, so the paper's communication pattern is visible in the HLO.

Every body takes ``backend="ref" | "pallas"``: ``ref`` is the plain-XLA
einsum path below; ``pallas`` streams the local scoring through the fused
kernels in ``repro.kernels`` (``ops.ce_shard_stats``) so the [B, V_local]
logit tensor never materializes, then completes the softmax with the same
two collectives via ``_finish_ce_stats``. Loss and grads agree to fp32
tolerance (tests/test_backend_parity.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# single-device oracle
# ---------------------------------------------------------------------------


def ce_ref(features, labels, w, *, cosine_scale: float = 0.0,
           label_smoothing: float = 0.0):
    """Plain full-softmax cross entropy. features [T,D], labels [T], w [N,D].
    cosine_scale > 0 switches to normalized (cosine) logits — the paper's
    normalization strategy (§3.2.1)."""
    f = features.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if cosine_scale > 0:
        f = f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-12)
        wf = wf / (jnp.linalg.norm(wf, axis=-1, keepdims=True) + 1e-12)
    logits = f @ wf.T
    if cosine_scale > 0:
        logits = logits * cosine_scale
    logz = jax.nn.logsumexp(logits, axis=-1)
    corr = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    if label_smoothing > 0:
        mean_logit = jnp.mean(logits, axis=-1)
        corr = (1 - label_smoothing) * corr + label_smoothing * mean_logit
    loss = jnp.mean(logz - corr)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, {"accuracy": acc, "logz": jnp.mean(logz)}


# ---------------------------------------------------------------------------
# shard_map body: full softmax
# ---------------------------------------------------------------------------


def _normalize(x):
    xf = x.astype(jnp.float32)
    return (xf / (jnp.linalg.norm(xf, axis=-1, keepdims=True) + 1e-12)).astype(x.dtype)


def _flat_axis_index(axis):
    """Row-major flat index over one axis name or a tuple of axis names
    (vocab sharded over several mesh axes — the paper's 1-D layout where
    every chip is an fc shard)."""
    if isinstance(axis, str):
        return jax.lax.axis_index(axis)
    idx = jnp.zeros((), jnp.int32)
    for a in axis:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _finish_ce(logits, owned_label_pos, owned, model_axis,
               batch_axes, batch_weight):
    """Shared distributed-CE tail.

    logits: [b, C_local] fp32 (already scaled); owned_label_pos [b] column of
    each sample's label in the local shard (only meaningful where ``owned``);
    owned [b] bool — exactly one device per model group owns each label.
    Returns (loss scalar replicated, metrics dict).
    """
    b = logits.shape[0]
    m_loc = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    m = jax.lax.pmax(m_loc, model_axis)
    z_loc = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
    z = jax.lax.psum(z_loc, model_axis)
    corr_loc = jnp.take_along_axis(
        logits, owned_label_pos[:, None].astype(jnp.int32), axis=1)[:, 0]
    corr_loc = jnp.where(owned, corr_loc, 0.0)
    corr = jax.lax.psum(corr_loc, model_axis)  # [b] label logit
    per_sample = jnp.log(z) + m - corr
    loss = jax.lax.psum(jnp.sum(per_sample) * batch_weight, batch_axes)

    # distributed top-1 accuracy (metrics only — no gradient)
    logits = jax.lax.stop_gradient(logits)
    amax_loc = jnp.argmax(logits, axis=-1)
    vmax_loc = jnp.take_along_axis(logits, amax_loc[:, None], axis=1)[:, 0]
    vmax = jax.lax.pmax(vmax_loc, model_axis)
    is_best = vmax_loc >= vmax  # ties: >=; duplicates across shards unlikely
    pred_here = owned & is_best & (amax_loc == owned_label_pos)
    correct = jax.lax.psum(pred_here.astype(jnp.float32), model_axis) > 0
    acc = jax.lax.psum(jnp.sum(correct.astype(jnp.float32)) * batch_weight,
                       batch_axes)
    logz = jax.lax.pmean(jnp.mean(jnp.log(z) + m), batch_axes)
    return loss, {"accuracy": acc, "logz": logz}


def _finish_ce_stats(m_loc, z_loc, corr_loc, pred_gid, y, owned, model_axis,
                     batch_axes, batch_weight):
    """Distributed-CE tail from per-shard ONLINE-SOFTMAX STATS (the Pallas
    backend's counterpart of ``_finish_ce``, which takes dense logits).

    m_loc/z_loc/corr_loc [b]: each shard's running max, partition sum
    relative to it, and label-logit contribution (0 off the owner shard).
    pred_gid [b]: the shard's best candidate as a GLOBAL class id (-1 when
    the shard scored nothing). Gradients flow through z_loc/corr_loc into
    the streaming backward kernels; m_loc is a non-differentiable statistic
    (ops module doc), so the pmax below needs no explicit stop_gradient —
    its cotangent is discarded exactly.
    """
    m_sg = jax.lax.stop_gradient(m_loc)
    m = jax.lax.pmax(m_sg, model_axis)
    z_resc = jnp.where(jnp.isfinite(m_sg), jnp.exp(m_sg - m), 0.0)
    z = jax.lax.psum(z_loc * z_resc, model_axis)
    corr = jax.lax.psum(corr_loc, model_axis)  # [b] label logit
    per_sample = jnp.log(z) + m - corr
    loss = jax.lax.psum(jnp.sum(per_sample) * batch_weight,
                        tuple(batch_axes))

    # distributed top-1 accuracy (metrics only — no gradient)
    is_best = m_sg >= m  # ties: >=; duplicates across shards unlikely
    pred_here = owned & is_best & (pred_gid == y)
    correct = jax.lax.psum(pred_here.astype(jnp.float32), model_axis) > 0
    acc = jax.lax.psum(jnp.sum(correct.astype(jnp.float32)) * batch_weight,
                       tuple(batch_axes))
    logz = jax.lax.pmean(jnp.mean(jnp.log(z) + m), tuple(batch_axes))
    return loss, {"accuracy": acc, "logz": logz}


def _shard_limit(v_start, v_loc: int, n_valid: int):
    """Valid-column count of this shard (traced): masks Megatron-style vocab
    padding inside the fused kernels. n_valid == 0 means no padding."""
    if not n_valid:
        return jnp.asarray(v_loc, jnp.int32)
    return jnp.clip(n_valid - v_start, 0, v_loc).astype(jnp.int32)


def full_softmax_local(
    f_loc, y_loc, w_loc, *, model_axis: str,
    batch_axes: Sequence[str], global_batch: int, cosine_scale: float = 0.0,
    n_valid: int = 0, backend: str = "ref", block_v: int = 512,
):
    """shard_map body. f_loc [b,D] (replicated along model), y_loc [b] global
    class ids, w_loc [V_loc, D] this device's class shard (row offset derived
    from the device's model-axis index). n_valid > 0 masks padded vocab rows
    (Megatron-style padding) out of the partition function. ``backend``
    routes the [b, V_loc] scoring through XLA (ref) or the streaming fused-CE
    kernel (pallas — the logit tensor never hits HBM)."""
    if backend == "pallas":
        f, w = ((_normalize(f_loc), _normalize(w_loc)) if cosine_scale > 0
                else (f_loc, w_loc))
        scale = cosine_scale if cosine_scale > 0 else 1.0
        v_loc = w_loc.shape[0]
        v_start = _flat_axis_index(model_axis) * v_loc
        pos = (y_loc - v_start).astype(jnp.int32)
        owned = (pos >= 0) & (pos < v_loc)
        y_local = jnp.where(owned, pos, -1)
        limit = _shard_limit(v_start, v_loc, n_valid)
        m, z, corr, amax = ops.ce_shard_stats(
            f.astype(jnp.float32), w.astype(jnp.float32), y_local, limit,
            scale, block_v)
        pred_gid = jnp.where(amax >= 0, v_start + amax, -1)
        return _finish_ce_stats(m, z, corr, pred_gid, y_loc, owned,
                                model_axis, tuple(batch_axes),
                                1.0 / global_batch)
    dt = f_loc.dtype
    f, w = ((_normalize(f_loc), _normalize(w_loc)) if cosine_scale > 0
            else (f_loc, w_loc.astype(dt)))
    logits = jnp.einsum("bd,vd->bv", f, w.astype(dt),
                        preferred_element_type=jnp.float32)
    if cosine_scale > 0:
        logits = logits * cosine_scale
    v_loc = w_loc.shape[0]
    v_start = _flat_axis_index(model_axis) * v_loc
    if n_valid:
        col = v_start + jnp.arange(v_loc)
        logits = jnp.where((col < n_valid)[None, :], logits, NEG_INF)
    pos = (y_loc - v_start).astype(jnp.int32)
    owned = (pos >= 0) & (pos < v_loc)
    pos = jnp.clip(pos, 0, v_loc - 1)
    return _finish_ce(logits, pos, owned, model_axis, tuple(batch_axes),
                      1.0 / global_batch)


def _combine_argmax(vmax, gid, model_axis):
    """One winner per row across model shards: lowest shard index among
    ties. vmax [b] local best value, gid [b] its global class id."""
    gmax = jax.lax.pmax(vmax, model_axis)
    shard_idx = _flat_axis_index(model_axis)
    is_best = vmax >= gmax
    winner_shard = jax.lax.pmin(
        jnp.where(is_best, shard_idx, jnp.iinfo(jnp.int32).max), model_axis)
    mine = is_best & (shard_idx == winner_shard)
    return jax.lax.psum(jnp.where(mine, gid, 0), model_axis).astype(jnp.int32)


def serve_argmax_local(f_loc, w_loc, *, model_axis: str, n_valid: int = 0,
                       block_v: int = 512):
    """Pallas-backend greedy decode: distributed argmax token ids WITHOUT
    materializing the [b, V_loc] logit tensor — the streaming kernel's
    (max, argmax) stats plus one pmax/pmin/psum combine. Counterpart of
    ``serve_logits_local`` (which returns the dense local logits too)."""
    v_loc = w_loc.shape[0]
    v_start = _flat_axis_index(model_axis) * v_loc
    limit = _shard_limit(v_start, v_loc, n_valid)
    b = f_loc.shape[0]
    y_none = jnp.full((b,), -1, jnp.int32)
    m, _, _, amax = ops.ce_shard_stats(
        f_loc.astype(jnp.float32), w_loc.astype(jnp.float32), y_none, limit,
        1.0, block_v)
    gid = v_start + jnp.maximum(amax, 0)
    vmax = jnp.where(amax >= 0, m, -jnp.inf)
    return _combine_argmax(vmax, gid, model_axis), None


def _merge_topk_ring(vals, gids, k: int, model_axis):
    """Merge per-shard local top-k candidates into the global top-k: one
    all-gather over the model axis, then a tiny [b, P*k] ``lax.top_k``.
    Shared by the exact scan (``serve_topk_local``) and the IVF index path
    (``serve_topk_ivf_local``). Returns (vals [b, k] desc, gids [b, k]),
    replicated along the model axis."""
    all_v = jax.lax.all_gather(vals, model_axis, axis=0)   # [P, b, k]
    all_g = jax.lax.all_gather(gids, model_axis, axis=0)
    b = vals.shape[0]
    flat_v = jnp.moveaxis(all_v, 0, 1).reshape(b, -1)      # [b, P*k]
    flat_g = jnp.moveaxis(all_g, 0, 1).reshape(b, -1)
    top_v, pos = jax.lax.top_k(flat_v, k)
    return top_v, jnp.take_along_axis(flat_g, pos, axis=1)


def serve_topk_local(f_loc, w_loc, k: int, *, model_axis: str,
                     n_valid: int = 0, backend: str = "ref",
                     chunk: int = 2048):
    """Top-k retrieval with scores (ROADMAP "serving beyond greedy argmax").

    Each shard scores its class block ([b, V_loc] — serving's product IS the
    scores), selects its local top-k per row (``ref``: lax.top_k; ``pallas``:
    the divide-and-conquer stage-1 kernel via ``ops.topk_rows`` — paper
    Fig. 5 applied to retrieval), then one all-gather over the model axis
    merges the P*k survivors. Returns (vals [b,k] desc, gids [b,k] int32),
    replicated along the model axis.
    """
    logits = jnp.einsum("bd,vd->bv", f_loc, w_loc.astype(f_loc.dtype),
                        preferred_element_type=jnp.float32)
    v_loc = w_loc.shape[0]
    v_start = _flat_axis_index(model_axis) * v_loc
    if n_valid:
        col = v_start + jnp.arange(v_loc)
        logits = jnp.where((col < n_valid)[None, :], logits, NEG_INF)
    kk = min(k, v_loc)
    if backend == "pallas":
        vals, idx = ops.topk_rows(logits, kk, chunk=chunk)
    else:
        vals, idx = jax.lax.top_k(logits, kk)
    gids = v_start + idx.astype(jnp.int32)
    if kk < k:  # more slots than local classes: pad before the merge
        pad = k - kk
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        gids = jnp.pad(gids, ((0, 0), (0, pad)), constant_values=-1)
    return _merge_topk_ring(vals, gids, k, model_axis)


def mask_padded_rows(x, n_queries, fill):
    """Serving-tier padding mask: rows >= ``n_queries`` of a fixed-shape
    micro-batch are coalescer padding, not real queries — force them to
    ``fill`` so batch shape never leaks into results. Works for [b] and
    [b, k] outputs; ``n_queries`` may be traced (one jit per bucket shape,
    NOT per occupancy)."""
    b = x.shape[0]
    keep = (jnp.arange(b) < n_queries).reshape((b,) + (1,) * (x.ndim - 1))
    return jnp.where(keep, x, fill)


def serve_topk_batched_local(f_loc, w_loc, k: int, n_queries, *,
                             model_axis: str, n_valid: int = 0,
                             backend: str = "ref", chunk: int = 2048):
    """Multi-query serving entry point (the serving tier's hot path).

    ``f_loc`` is a PADDED micro-batch [b_pad, D] REPLICATED along the model
    axis (the engine feeds every shard the full batch — no ring gather on
    the serve path) with only the first ``n_queries`` rows real. Scoring is
    row-independent, so padding never perturbs real rows; padded rows come
    back as (-inf, -1). Returns (vals [b_pad, k] desc, gids [b_pad, k])."""
    vals, gids = serve_topk_local(f_loc, w_loc, k, model_axis=model_axis,
                                  n_valid=n_valid, backend=backend,
                                  chunk=chunk)
    return (mask_padded_rows(vals, n_queries, -jnp.inf),
            mask_padded_rows(gids, n_queries, -1))


def serve_topk_ivf_local(f_loc, w_loc, cent_loc, members_loc, k: int,
                         nprobe: int, *, model_axis: str,
                         backend: str = "ref", block_a: int = 128):
    """IVF top-k retrieval (sublinear in the class count, ROADMAP "learned
    ANN index"): probe the query's top-``nprobe`` k-means centroids of this
    shard, rerank ONLY the member rows of the probed clusters, then merge
    across shards with the same one-ring all-gather as the exact scan.

    f_loc [b, D] replicated along the model axis; w_loc [V_loc, D] the
    class shard; cent_loc [C, D] unit centroids fit over the shard
    (``repro.serving.index``); members_loc [C, cap] int32 local row ids per
    cluster, -1 padded (every valid class appears in exactly one cluster,
    so ``nprobe == C`` recovers the exact scan). The rerank scores raw
    ``f @ w.T`` dot products — identical to the exact path — over
    A = nprobe * cap candidates instead of V_loc columns (``ref``: gather +
    ``lax.top_k``; ``pallas``: the fused ``ops.ivf_rerank`` kernel). The
    probe always uses the normalized query against the unit centroids
    (cluster membership is directional); cosine heads normalize f/w before
    calling, exactly like the exact serve steps.
    """
    c, cap = members_loc.shape
    v_loc = w_loc.shape[0]
    v_start = _flat_axis_index(model_axis) * v_loc
    f = f_loc.astype(jnp.float32)
    b = f.shape[0]
    fq = _normalize(f)
    n_probe = min(nprobe, c)
    _, probe = jax.lax.top_k(fq @ cent_loc.astype(jnp.float32).T, n_probe)
    cand = jnp.take(members_loc, probe, axis=0).reshape(b, -1)  # [b, A]
    kk = min(k, cand.shape[1])
    if backend == "pallas":
        vals, lids = ops.ivf_rerank(f, w_loc.astype(jnp.float32), cand, kk,
                                    block_a=block_a)
    else:
        safe = jnp.clip(cand, 0, v_loc - 1)
        wc = jnp.take(w_loc.astype(jnp.float32), safe, axis=0)  # [b, A, D]
        s = jnp.einsum("bd,bad->ba", f, wc,
                       preferred_element_type=jnp.float32)
        s = jnp.where(cand >= 0, s, -jnp.inf)
        vals, pos = jax.lax.top_k(s, kk)
        lids = jnp.take_along_axis(cand, pos, axis=1)
    gids = jnp.where(lids >= 0, v_start + lids, -1).astype(jnp.int32)
    vals = jnp.where(lids >= 0, vals, -jnp.inf)
    if kk < k:  # fewer candidates than slots: pad before the merge
        pad = k - kk
        vals = jnp.pad(vals, ((0, 0), (0, pad)), constant_values=-jnp.inf)
        gids = jnp.pad(gids, ((0, 0), (0, pad)), constant_values=-1)
    return _merge_topk_ring(vals, gids, k, model_axis)


def serve_topk_ivf_batched_local(f_loc, w_loc, cent_loc, members_loc, k: int,
                                 nprobe: int, n_queries, *, model_axis: str,
                                 backend: str = "ref", block_a: int = 128):
    """Serving-tier entry for the IVF path: padded micro-batch [b_pad, D]
    with only the first ``n_queries`` rows real (traced — one jit per
    bucket). Scoring is row-independent, so padding never perturbs real
    rows; padded rows come back as (-inf, -1), like the exact path."""
    vals, gids = serve_topk_ivf_local(
        f_loc, w_loc, cent_loc, members_loc, k, nprobe,
        model_axis=model_axis, backend=backend, block_a=block_a)
    return (mask_padded_rows(vals, n_queries, -jnp.inf),
            mask_padded_rows(gids, n_queries, -1))


def serve_logits_local(f_loc, w_loc, *, model_axis: str, n_valid: int = 0):
    """Decode-time local logits [b, V_loc] + distributed argmax token ids.

    Greedy sampling: each shard proposes (best val, global id); combined with
    one pmax + one psum along "model"."""
    logits = jnp.einsum("bd,vd->bv", f_loc, w_loc.astype(f_loc.dtype),
                        preferred_element_type=jnp.float32)
    if n_valid:
        v_loc = w_loc.shape[0]
        col = _flat_axis_index(model_axis) * v_loc + jnp.arange(v_loc)
        logits = jnp.where((col < n_valid)[None, :], logits, NEG_INF)
    amax = jnp.argmax(logits, axis=-1)
    vmax = jnp.take_along_axis(logits, amax[:, None], axis=1)[:, 0]
    v_loc = w_loc.shape[0]
    gid = _flat_axis_index(model_axis) * v_loc + amax.astype(jnp.int32)
    return _combine_argmax(vmax, gid, model_axis), logits
