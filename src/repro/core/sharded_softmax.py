"""Hybrid-parallel distributed softmax cross-entropy (paper §3.1).

The extreme-classification head W [N, D] is split row-wise (by class) across
the ``model`` mesh axis; features arrive batch-sharded over the data axes and
replicated along ``model`` (the all-gather the paper overlaps in §3.3.1 is
what produced that replication). Each device scores its local class shard and
the softmax is completed with two tiny collectives:

    global max  = pmax over "model"   (numerical stability)
    global Z    = psum over "model"   (partition function)
    label logit = psum over "model"   (each class owned by exactly one shard)

The fc gradient stays local to its shard (the paper's key memory/comm win);
only the feature gradient crosses the model axis (inside autodiff of the
einsum) and the scalar loss is averaged over the data axes.

These are *shard_map bodies*: they see local shards and use lax collectives
explicitly, so the paper's communication pattern is visible in the HLO.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# single-device oracle
# ---------------------------------------------------------------------------


def ce_ref(features, labels, w, *, cosine_scale: float = 0.0,
           label_smoothing: float = 0.0):
    """Plain full-softmax cross entropy. features [T,D], labels [T], w [N,D].
    cosine_scale > 0 switches to normalized (cosine) logits — the paper's
    normalization strategy (§3.2.1)."""
    f = features.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    if cosine_scale > 0:
        f = f / (jnp.linalg.norm(f, axis=-1, keepdims=True) + 1e-12)
        wf = wf / (jnp.linalg.norm(wf, axis=-1, keepdims=True) + 1e-12)
    logits = f @ wf.T
    if cosine_scale > 0:
        logits = logits * cosine_scale
    logz = jax.nn.logsumexp(logits, axis=-1)
    corr = jnp.take_along_axis(logits, labels[:, None], axis=1)[:, 0]
    if label_smoothing > 0:
        mean_logit = jnp.mean(logits, axis=-1)
        corr = (1 - label_smoothing) * corr + label_smoothing * mean_logit
    loss = jnp.mean(logz - corr)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, {"accuracy": acc, "logz": jnp.mean(logz)}


# ---------------------------------------------------------------------------
# shard_map body: full softmax
# ---------------------------------------------------------------------------


def _normalize(x):
    xf = x.astype(jnp.float32)
    return (xf / (jnp.linalg.norm(xf, axis=-1, keepdims=True) + 1e-12)).astype(x.dtype)


def _flat_axis_index(axis):
    """Row-major flat index over one axis name or a tuple of axis names
    (vocab sharded over several mesh axes — the paper's 1-D layout where
    every chip is an fc shard)."""
    if isinstance(axis, str):
        return jax.lax.axis_index(axis)
    idx = jnp.zeros((), jnp.int32)
    for a in axis:
        idx = idx * jax.lax.axis_size(a) + jax.lax.axis_index(a)
    return idx


def _finish_ce(logits, owned_label_pos, owned, model_axis,
               batch_axes, batch_weight):
    """Shared distributed-CE tail.

    logits: [b, C_local] fp32 (already scaled); owned_label_pos [b] column of
    each sample's label in the local shard (only meaningful where ``owned``);
    owned [b] bool — exactly one device per model group owns each label.
    Returns (loss scalar replicated, metrics dict).
    """
    b = logits.shape[0]
    m_loc = jax.lax.stop_gradient(jnp.max(logits, axis=-1))
    m = jax.lax.pmax(m_loc, model_axis)
    z_loc = jnp.sum(jnp.exp(logits - m[:, None]), axis=-1)
    z = jax.lax.psum(z_loc, model_axis)
    corr_loc = jnp.take_along_axis(
        logits, owned_label_pos[:, None].astype(jnp.int32), axis=1)[:, 0]
    corr_loc = jnp.where(owned, corr_loc, 0.0)
    corr = jax.lax.psum(corr_loc, model_axis)  # [b] label logit
    per_sample = jnp.log(z) + m - corr
    loss = jax.lax.psum(jnp.sum(per_sample) * batch_weight, batch_axes)

    # distributed top-1 accuracy (metrics only — no gradient)
    logits = jax.lax.stop_gradient(logits)
    amax_loc = jnp.argmax(logits, axis=-1)
    vmax_loc = jnp.take_along_axis(logits, amax_loc[:, None], axis=1)[:, 0]
    vmax = jax.lax.pmax(vmax_loc, model_axis)
    is_best = vmax_loc >= vmax  # ties: >=; duplicates across shards unlikely
    pred_here = owned & is_best & (amax_loc == owned_label_pos)
    correct = jax.lax.psum(pred_here.astype(jnp.float32), model_axis) > 0
    acc = jax.lax.psum(jnp.sum(correct.astype(jnp.float32)) * batch_weight,
                       batch_axes)
    logz = jax.lax.pmean(jnp.mean(jnp.log(z) + m), batch_axes)
    return loss, {"accuracy": acc, "logz": logz}


def full_softmax_local(
    f_loc, y_loc, w_loc, *, model_axis: str,
    batch_axes: Sequence[str], global_batch: int, cosine_scale: float = 0.0,
    n_valid: int = 0,
):
    """shard_map body. f_loc [b,D] (replicated along model), y_loc [b] global
    class ids, w_loc [V_loc, D] this device's class shard (row offset derived
    from the device's model-axis index). n_valid > 0 masks padded vocab rows
    (Megatron-style padding) out of the partition function."""
    dt = f_loc.dtype
    f, w = ((_normalize(f_loc), _normalize(w_loc)) if cosine_scale > 0
            else (f_loc, w_loc.astype(dt)))
    logits = jnp.einsum("bd,vd->bv", f, w.astype(dt),
                        preferred_element_type=jnp.float32)
    if cosine_scale > 0:
        logits = logits * cosine_scale
    v_loc = w_loc.shape[0]
    v_start = _flat_axis_index(model_axis) * v_loc
    if n_valid:
        col = v_start + jnp.arange(v_loc)
        logits = jnp.where((col < n_valid)[None, :], logits, NEG_INF)
    pos = (y_loc - v_start).astype(jnp.int32)
    owned = (pos >= 0) & (pos < v_loc)
    pos = jnp.clip(pos, 0, v_loc - 1)
    return _finish_ce(logits, pos, owned, model_axis, tuple(batch_axes),
                      1.0 / global_batch)


def serve_logits_local(f_loc, w_loc, *, model_axis: str, n_valid: int = 0):
    """Decode-time local logits [b, V_loc] + distributed argmax token ids.

    Greedy sampling: each shard proposes (best val, global id); combined with
    one pmax + one psum along "model"."""
    logits = jnp.einsum("bd,vd->bv", f_loc, w_loc.astype(f_loc.dtype),
                        preferred_element_type=jnp.float32)
    if n_valid:
        v_loc = w_loc.shape[0]
        col = _flat_axis_index(model_axis) * v_loc + jnp.arange(v_loc)
        logits = jnp.where((col < n_valid)[None, :], logits, NEG_INF)
    amax = jnp.argmax(logits, axis=-1)
    vmax = jnp.take_along_axis(logits, amax[:, None], axis=1)[:, 0]
    gmax = jax.lax.pmax(vmax, model_axis)
    shard_idx = _flat_axis_index(model_axis)
    v_loc = w_loc.shape[0]
    gid = shard_idx * v_loc + amax
    # exactly-one winner: the lowest shard index among ties
    is_best = vmax >= gmax
    winner_shard = jax.lax.pmin(
        jnp.where(is_best, shard_idx, jnp.iinfo(jnp.int32).max), model_axis)
    mine = is_best & (shard_idx == winner_shard)
    token = jax.lax.psum(jnp.where(mine, gid, 0), model_axis)
    return token.astype(jnp.int32), logits
