"""Hybrid parallel pipelining (paper §3.3.1) + gradient accumulation.

The paper splits each mini-batch into micro-batches so the fc shards can
all-gather micro-batch i's features while the FE computes micro-batch i+1
(and symmetrically in backward). In XLA there are no manual streams: we
express the same structure — per-micro-batch FE -> all-gather -> head -> and
accumulate — as a lax.scan, and the async-collective latency-hiding
scheduler overlaps hops across scan iterations on TPU. The micro-batch split
also cuts peak activation memory exactly as the paper notes.

``grad_accum`` additionally implements FCCS's n× batch enlargement: n scan
steps of micro-grad accumulation per optimizer update, which divides
data-parallel gradient traffic by n.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def split_microbatches(inputs: dict, n_micro: int) -> dict:
    """[B, ...] -> [n_micro, B/n_micro, ...] for every input leaf."""
    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, f"batch {b} % micro {n_micro} != 0"
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])
    return jax.tree.map(split, inputs)


def microbatched_value_and_grad(
    loss_fn: Callable, params, inputs: dict, n_micro: int,
):
    """Mean loss/grads over n_micro micro-batches via lax.scan.

    loss_fn(params, micro_inputs) -> (loss, metrics). Gradients accumulate in
    fp32. Metrics are averaged. This is the pipelined/accumulated step body:
    with n_micro=1 it degenerates to the paper's Fig. 4(a) baseline.
    """
    if n_micro == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, inputs)
        return (loss, metrics), grads

    micro = split_microbatches(inputs, n_micro)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)

    def body(carry, micro_inputs):
        acc_g, acc_l, acc_m = carry
        (loss, metrics), grads = gfn(params, micro_inputs)
        acc_g = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32) / n_micro, acc_g, grads)
        acc_m = jax.tree.map(lambda a, m: a + m / n_micro, acc_m, metrics)
        return (acc_g, acc_l + loss / n_micro, acc_m), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    first = jax.tree.map(lambda x: x[0], micro)
    m0 = jax.tree.map(lambda _: jnp.zeros((), jnp.float32),
                      jax.eval_shape(lambda: gfn(params, first)[0][1]))
    (grads, loss, metrics), _ = jax.lax.scan(
        body, (g0, jnp.zeros((), jnp.float32), m0), micro)
    return (loss, metrics), grads
