"""Distributed exact KNN graph over the class weights (paper §3.2.2).

The paper builds an *exact* (linear-search) KNN graph of W_norm because ANN
recall losses translate into accuracy loss. W is row-sharded over "model", so
the build uses a ring: each device's block of W_norm visits every other
device via collective_permute; each hop contributes a [N_loc × N_loc] bf16
matmul (TensorCore in the paper, MXU here) merged into a running top-k'. A
second fp32 pass re-ranks the k' candidates (paper's mixed-precision scheme)
before the final k are kept. Self is always neighbor 0 (W is normalized, so
w_y ranks first in its own list — the property Algorithm 1 relies on).

Graph compression (paper §3.2.3-i): each device keeps, for ALL N rows, only
the neighbor entries that point to classes stored on that device — CSR
(offsets [N+1], values [nnz]) with *local* column ids. ``quick access``
(§3.2.3-ii) becomes a capped CSR gather (see knn_softmax.select_active).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


class CompressedGraph(NamedTuple):
    """Per-model-shard CSR of local neighbors. Leading axis = model shard when
    used as a global (sharded) array. ``ranks`` preserves each entry's
    position in the ORIGINAL (uncompressed) neighbor list — Algorithm 1's
    ranking score. Without it, the first local entry of every row would tie
    at rank 0 with true self-entries and selection could drop labels."""
    offsets: jax.Array    # [P, N+1] int32
    neighbors: jax.Array  # [P, nnz_cap] int32 local ids (pad = -1)
    ranks: jax.Array      # [P, nnz_cap] int32 original positions (pad = -1)


# ---------------------------------------------------------------------------
# reference (single device, fp32, exact)
# ---------------------------------------------------------------------------


def knn_graph_ref(w, k: int):
    """Exact top-k cosine neighbors (self included, ranked first).
    w: [N, D] -> ids [N, k] int32."""
    wn = w.astype(jnp.float32)
    wn = wn / (jnp.linalg.norm(wn, axis=-1, keepdims=True) + 1e-12)
    scores = wn @ wn.T
    _, ids = jax.lax.top_k(scores, k)
    return ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# distributed ring build (shard_map body over the "model" axis)
# ---------------------------------------------------------------------------


def _merge_topk(best_v, best_i, new_v, new_i, k):
    v = jnp.concatenate([best_v, new_v], axis=1)
    i = jnp.concatenate([best_i, new_i], axis=1)
    top_v, pos = jax.lax.top_k(v, k)
    return top_v, jnp.take_along_axis(i, pos, axis=1)


def ring_knn_local(w_loc, *, k: int, kprime: int, model_axis: str, n_shards: int,
                   compute_dtype=jnp.bfloat16, backend: str = "ref"):
    """shard_map body: exact KNN of the full W from per-device blocks.

    w_loc: [N_loc, D] local rows. Returns global neighbor ids [N_loc, k].
    Pass 1: bf16 ring scoring into a running top-k'. Pass 2: fp32 re-rank of
    the k' survivors (recomputed against the traveling block).

    ``backend="pallas"`` fuses each hop's score + top-k' through the
    ``kernels.ops.dist_topk`` kernel (the [N_loc, N_loc] score tile stays in
    VMEM); ``ref`` keeps the einsum + merge-sweep formulation.
    """
    n_loc, d = w_loc.shape
    wn = w_loc.astype(jnp.float32)
    wn = wn / (jnp.linalg.norm(wn, axis=-1, keepdims=True) + 1e-12)
    w16 = wn.astype(compute_dtype)
    my = jax.lax.axis_index(model_axis)
    perm = [(i, (i + 1) % n_shards) for i in range(n_shards)]

    # ---- pass 1: bf16 scoring, running top-k' ---------------------------
    def hop(step, carry):
        block, bv, bi = carry
        src = (my - step) % n_shards  # owner of the block we hold now
        if backend == "pallas":
            # fused score + per-hop top-k'; the traveling block's local ids
            # are shifted to global AFTER the kernel (src is traced, block
            # geometry is static)
            hv, hi = ops.dist_topk(w16, block, kprime,
                                   block_q=min(128, n_loc),
                                   block_n=min(128, n_loc))
            hi = jnp.where(hi >= 0, hi + src * n_loc, -1)
            bv, bi = _merge_topk(bv, bi, hv, hi, kprime)
        else:
            scores = jnp.einsum("nd,md->nm", w16, block,
                                preferred_element_type=jnp.float32)
            ids = (src * n_loc + jnp.arange(n_loc, dtype=jnp.int32))[None, :]
            ids = jnp.broadcast_to(ids, scores.shape)
            bv, bi = _merge_topk(bv, bi, scores, ids, kprime)
        block = jax.lax.ppermute(block, model_axis, perm)
        return block, bv, bi

    def _vary(x):  # mark as device-varying along the ring axis (scan carry)
        return jax.lax.pcast(x, (model_axis,), to="varying")

    bv0 = _vary(jnp.full((n_loc, kprime), -jnp.inf, jnp.float32))
    bi0 = _vary(jnp.full((n_loc, kprime), -1, jnp.int32))
    _, bv, bi = jax.lax.fori_loop(0, n_shards, hop, (w16, bv0, bi0))

    # ---- pass 2: fp32 re-rank of the k' candidates ----------------------
    def hop32(step, carry):
        block, acc = carry
        src = (my - step) % n_shards
        lo = src * n_loc
        rel = bi - lo                       # candidate position in this block
        here = (rel >= 0) & (rel < n_loc)
        cand = block[jnp.clip(rel, 0, n_loc - 1)]       # [N_loc, k', D] fp32
        s = jnp.einsum("nd,nkd->nk", wn, cand)
        acc = jnp.where(here, s, acc)
        block = jax.lax.ppermute(block, model_axis, perm)
        return block, acc

    acc0 = _vary(jnp.full((n_loc, kprime), -jnp.inf, jnp.float32))
    _, exact = jax.lax.fori_loop(0, n_shards, hop32, (wn, acc0))
    exact = jnp.where(bi >= 0, exact, -jnp.inf)
    _, pos = jax.lax.top_k(exact, k)
    return jnp.take_along_axis(bi, pos, axis=1)


def build_graph_distributed(mesh, w_sharded, *, k: int, kprime: int,
                            model_axis: str = "model", backend: str = "ref"):
    """Run the ring build under shard_map on a W sharded over ``model``.
    Returns the global graph [N, k] (row-sharded the same way)."""
    from jax.sharding import PartitionSpec as P

    n_shards = mesh.shape[model_axis]
    body = functools.partial(ring_knn_local, k=k, kprime=kprime,
                             model_axis=model_axis, n_shards=n_shards,
                             backend=backend)
    fn = jax.shard_map(body, mesh=mesh, in_specs=P(model_axis, None),
                       out_specs=P(model_axis, None), check_vma=False)
    return jax.jit(fn)(w_sharded)


# ---------------------------------------------------------------------------
# compression (paper §3.2.3): host-side CSR build, per model shard
# ---------------------------------------------------------------------------


def compress_graph(graph: np.ndarray, n_shards: int) -> CompressedGraph:
    """graph: [N, k] global neighbor ids (host numpy).

    For shard p, keep only neighbors owned by p (id // n_loc == p), stored as
    LOCAL ids, CSR over all N rows. Shards are padded to a common nnz cap so
    the result is one [P, ...] array shardable over "model".

    This is the paper's per-node graph compression: average storage drops
    from N·k to N·k/P per device.
    """
    graph = np.asarray(graph)
    n, k = graph.shape
    assert n % n_shards == 0, f"N={n} not divisible by shards={n_shards}"
    n_loc = n // n_shards
    owner = graph // n_loc
    local = graph % n_loc
    col = np.broadcast_to(np.arange(k, dtype=np.int32), graph.shape)
    offsets = np.zeros((n_shards, n + 1), np.int32)
    values, rvalues = [], []
    for p in range(n_shards):
        mask = owner == p
        counts = mask.sum(axis=1)
        offsets[p, 1:] = np.cumsum(counts)
        values.append(local[mask].astype(np.int32))
        rvalues.append(col[mask].astype(np.int32))
    nnz_cap = max(int(v.size) for v in values)
    neigh = np.full((n_shards, nnz_cap), -1, np.int32)
    ranks = np.full((n_shards, nnz_cap), -1, np.int32)
    for p, (v, r) in enumerate(zip(values, rvalues)):
        neigh[p, : v.size] = v
        ranks[p, : r.size] = r
    return CompressedGraph(jnp.asarray(offsets), jnp.asarray(neigh),
                           jnp.asarray(ranks))


def graph_storage_bytes(cg: CompressedGraph) -> dict:
    """Storage accounting used by the Table-3-style benchmark."""
    per_shard = cg.neighbors.shape[1] * 4 + cg.offsets.shape[1] * 4
    return {"per_shard_bytes": per_shard,
            "total_bytes": per_shard * cg.offsets.shape[0]}
