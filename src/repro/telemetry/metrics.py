"""``MetricsSink`` — append-only JSONL metrics stream.

One JSON object per line; the file is opened in append mode so successive
runs (or a resumed run after a kill) extend the stream instead of
truncating it, and every write is flushed so a killed process loses at
most the in-flight row (``tests/test_telemetry.py`` gates both).
"""
from __future__ import annotations

import json
from typing import IO, Optional


class MetricsSink:
    def __init__(self, path: str):
        self.path = path
        self._f: Optional[IO[str]] = None
        self.n_rows = 0

    def write(self, row: dict) -> None:
        if self._f is None:
            self._f = open(self.path, "a")
        self._f.write(json.dumps(row, sort_keys=True) + "\n")
        self._f.flush()
        self.n_rows += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        self.close()
        return False
