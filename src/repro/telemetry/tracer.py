"""``Tracer`` — nestable low-overhead spans + counters/gauges + export.

Design constraints (docs/telemetry.md):

  * The hot path must pay ~nothing when telemetry is disabled: callers
    hold a ``Tracer`` OR the shared ``NULL_TRACER`` singleton behind the
    same interface, and every ``NULL_TRACER`` method is a constant-time
    no-op returning preallocated objects (``tests/test_telemetry.py``
    asserts zero ``_NullSpan`` allocations via the instance counter).
  * Spans nest: ``span()`` keeps an explicit stack and records the depth
    at exit, so the exported Chrome trace reconstructs the hierarchy
    without thread-local magic.
  * The clock is injectable (``clock_ns=``) so tests drive a fake clock
    and span timing is deterministic.

Export formats:
  * ``chrome_trace()`` / ``write_chrome_trace(path)`` — the Chrome
    tracing/Perfetto JSON object format (``traceEvents`` with complete
    "X" events, timestamps in microseconds); open at https://ui.perfetto.dev.
  * ``log_metrics(row)`` — one JSON object per line into the optional
    ``MetricsSink`` (``metrics_path=``).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, NamedTuple, Optional

from repro.telemetry.metrics import MetricsSink


class SpanEvent(NamedTuple):
    """One closed span: start/duration on the tracer's ns clock, nesting
    depth at entry (0 = top level), and optional attributes."""
    name: str
    start_ns: int
    dur_ns: int
    depth: int
    attrs: Optional[dict]


class _Span:
    """Context manager recording one span into its tracer on exit."""

    __slots__ = ("_tr", "name", "attrs", "start_ns", "depth")

    def __init__(self, tracer: "Tracer", name: str, attrs: Optional[dict]):
        self._tr = tracer
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self.depth = len(self._tr._stack)
        self._tr._stack.append(self.name)
        self.start_ns = self._tr.clock_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        end_ns = self._tr.clock_ns()
        self._tr._stack.pop()
        self._tr.events.append(SpanEvent(
            self.name, self.start_ns, end_ns - self.start_ns, self.depth,
            self.attrs))
        return False


class Tracer:
    """Span/counter/gauge registry. See module docstring."""

    enabled = True

    def __init__(self, *, clock_ns: Callable[[], int] = time.perf_counter_ns,
                 metrics_path: Optional[str] = None):
        self.clock_ns = clock_ns
        self.events: list[SpanEvent] = []
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, Any] = {}
        self._stack: list[str] = []
        self.sink = MetricsSink(metrics_path) if metrics_path else None

    # -- spans -------------------------------------------------------------

    def span(self, name: str, attrs: Optional[dict] = None) -> _Span:
        return _Span(self, name, attrs)

    def add_span(self, name: str, start_ns: int, dur_ns: int,
                 attrs: Optional[dict] = None, depth: int = 0) -> None:
        """Record an externally-timed interval (e.g. the serving engine's
        own ``perf_counter_ns`` compute window) as a span."""
        self.events.append(
            SpanEvent(name, int(start_ns), int(dur_ns), depth, attrs))

    def span_stats(self, name: str) -> dict:
        """{"count", "total_s"} over every recorded span named ``name``."""
        n, total_ns = 0, 0
        for e in self.events:
            if e.name == name:
                n += 1
                total_ns += e.dur_ns
        return {"count": n, "total_s": total_ns * 1e-9}

    # -- counters / gauges -------------------------------------------------

    def count(self, name: str, value: float = 1.0) -> float:
        v = self.counters.get(name, 0.0) + value
        self.counters[name] = v
        return v

    def gauge(self, name: str, value) -> None:
        self.gauges[name] = value

    def record_peak_memory(self, prefix: str = "mem.peak_bytes") -> dict:
        """Gauge the current peak-memory watermark per device (host RSS
        fallback on backends without ``memory_stats``)."""
        peaks = device_peak_memory()
        for dev, b in peaks.items():
            self.gauge(f"{prefix}.{dev}", b)
        return peaks

    # -- metrics sink ------------------------------------------------------

    def log_metrics(self, row: dict) -> None:
        if self.sink is not None:
            self.sink.write(row)

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()

    # -- export ------------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The Chrome tracing / Perfetto JSON object format. Timestamps
        are microseconds on the tracer's monotonic clock; counters and
        gauges ride along as (tolerated) extra top-level keys."""
        events = []
        for e in self.events:
            ev = {"name": e.name, "ph": "X", "ts": e.start_ns / 1e3,
                  "dur": e.dur_ns / 1e3, "pid": 0, "tid": 0,
                  "args": {"depth": e.depth, **(e.attrs or {})}}
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "counters": dict(self.counters), "gauges": dict(self.gauges)}

    def write_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f, indent=1, sort_keys=True)
            f.write("\n")
        return path


# ---------------------------------------------------------------------------
# disabled path
# ---------------------------------------------------------------------------

_ZERO_STATS = {"count": 0, "total_s": 0.0}


class _NullSpan:
    """The no-op span. Exactly ONE instance ever exists (the module-level
    ``_NULL_SPAN``); the class-level counter lets tests assert the hot
    path allocates nothing."""

    __slots__ = ()
    instances = 0

    def __new__(cls):
        cls.instances += 1
        return super().__new__(cls)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: same interface as ``Tracer``, every call a no-op
    that allocates nothing. Use the shared ``NULL_TRACER`` singleton."""

    enabled = False
    events: tuple = ()

    def span(self, name, attrs=None):
        return _NULL_SPAN

    def add_span(self, name, start_ns, dur_ns, attrs=None, depth=0):
        pass

    def span_stats(self, name):
        return _ZERO_STATS

    def count(self, name, value=1.0):
        return 0.0

    def gauge(self, name, value):
        pass

    def record_peak_memory(self, prefix="mem.peak_bytes"):
        return {}

    def log_metrics(self, row):
        pass

    def close(self):
        pass


NULL_TRACER = NullTracer()


# ---------------------------------------------------------------------------
# peak-memory watermarks
# ---------------------------------------------------------------------------


def device_peak_memory() -> dict:
    """Peak-memory watermark per jax device (``memory_stats`` where the
    backend reports it — TPU/GPU), with the process high-water RSS as the
    host fallback (this CPU container's fake devices share one heap)."""
    import jax

    peaks = {}
    for d in jax.devices():
        try:
            stats = d.memory_stats()
        except Exception:  # noqa: BLE001 — CPU backend has no stats
            stats = None
        if stats and "peak_bytes_in_use" in stats:
            peaks[str(d.id)] = int(stats["peak_bytes_in_use"])
    if not peaks:
        import resource
        peaks["host_rss"] = (
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024)
    return peaks
