"""``repro.telemetry`` — tracing/metrics with comm-volume accounting.

One seam for every layer's observability (docs/telemetry.md):

  * ``Tracer`` — nestable wall-clock spans (``with tr.span("train.step")``)
    over a monotonic ``perf_counter_ns`` clock, typed counters/gauges,
    device peak-memory watermarks, a JSONL metrics sink, and Chrome-trace
    (Perfetto) JSON export.
  * ``NULL_TRACER`` — the disabled singleton: every hot-path call is a
    constant-time no-op that allocates nothing, so instrumented code pays
    ~nothing when telemetry is off.
  * ``CommLedger`` / ``train_step_ledger`` — the analytic comm-volume
    model: bytes per collective per train step, derived from head config +
    mesh shape, cross-checkable against ``repro.roofline.hlo`` cost
    analysis on the compiled step (tests/test_telemetry.py).

Threaded through ``PaperTrainer``/``ZooExperiment`` fit loops,
``ServingEngine``, ``repro.resilience`` and the launchers
(``--trace-out``/``--metrics-out``).
"""
from repro.telemetry.ledger import (COLLECTIVE_KINDS, Collective, CommLedger,
                                    train_step_ledger)
from repro.telemetry.metrics import MetricsSink
from repro.telemetry.tracer import (NULL_TRACER, NullTracer, SpanEvent,
                                    Tracer, device_peak_memory)

__all__ = [
    "COLLECTIVE_KINDS", "Collective", "CommLedger", "MetricsSink",
    "NULL_TRACER", "NullTracer", "SpanEvent", "Tracer",
    "device_peak_memory", "train_step_ledger",
]
