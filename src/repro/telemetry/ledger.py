"""Analytic comm-volume ledger: bytes per collective per train step.

The paper's claim structure (§3.3, Table 4/8) makes the OUTPUT-LAYER
collectives, not FLOPs, the scale-limiting observable at 100M classes —
so the ledger charges them analytically from head config + mesh shape and
cross-checks against the compiled step's HLO (``repro.roofline.hlo``).

Model of one hybrid-parallel train step (``repro.train.hybrid``), P
devices on the ring, R global rows per step (features [R, D] f32,
labels [R] i32), ``n_micro`` micro-batches:

  all-gather       features R*D*4 + labels R*4 bytes (HLO charges the
                   gathered OUTPUT shape; the per-micro gathers tile to
                   the same per-step total).
  all-reduce (CE)  the distributed-softmax completion moves [b]-sized
                   terms per micro (b = R/n_micro): the ref backend's
                   ``_finish_ce`` psums/pmaxes 5 of them forward (m, z,
                   corr, vmax, pred_here), the pallas stats path 4 (vmax
                   is reused) — PLUS 2 backward terms either way: under
                   shard_map autodiff the transpose of ``psum`` is again
                   a ``psum`` (per-device cotangents of a replicated
                   value sum over the ring), so the differentiated z and
                   corr completions each charge one more [b]-sized
                   all-reduce. Total 7 ref / 6 pallas. The knn head adds
                   the label-recall psum [b] plus a scalar
                   active-fraction pmean per micro. ``batch_axes=()``
                   psums compile to nothing — they are NOT charged.
                   (At n_micro > 1 XLA CSE may merge the duplicate pmax
                   inside the scan body, shaving one [b] term — the
                   model is exact at n_micro=1 and ~7% high under the
                   scan; compare with a matching rtol.)
  reduce-scatter   backward of the feature all-gather: R*D*4/P bytes —
                   only when the FE trunk has trainable params (the
                   feats trunk's empty FE makes the whole backward
                   collective dead code, so it charges 0).
  all-reduce (fe)  dense gradient exchange: 4 bytes per FE param. DGC's
                   masked-dense psum moves the SAME dense bytes on the
                   wire — its sparse wire accounting (nnz * 8) is the
                   trainer's ``comm_wire_bytes`` metric, not an HLO
                   quantity.

``CommLedger.compare`` diffs the ledger against an HLO measurement BY
KIND AND BYTES, not op counts — XLA's all-reduce combiner merges same-kind
ops into tuple all-reduces (bytes preserved, counts not).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

# "reshard" is not an HLO collective: it charges the host/wire bytes an
# elastic restore moves when re-partitioning checkpoint rows onto a new
# mesh (repro.elastic). Train-step ledgers never add it, and ``compare``
# skips kinds that are zero on both sides, so HLO cross-checks are
# unaffected; BENCH_table8.json gates it via ``analytic_reshard_ledger``.
COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute", "reshard")

# heads whose per-step collective structure the ledger models exactly
LEDGER_HEADS = ("full", "knn")


@dataclass
class Collective:
    """One charged collective: ``bytes`` is the per-step total (HLO
    convention: output-shape bytes), ``count`` the number of launches."""
    kind: str
    label: str
    bytes: float
    count: int = 1


class CommLedger:
    """An itemized per-step comm bill; shape-compatible with
    ``repro.roofline.hlo`` ``Analysis.collectives`` via ``per_kind``."""

    def __init__(self, entries: Optional[list] = None):
        self.entries: list[Collective] = list(entries or [])

    def add(self, kind: str, label: str, nbytes: float,
            count: int = 1) -> "CommLedger":
        if kind not in COLLECTIVE_KINDS:
            raise ValueError(f"unknown collective kind {kind!r}; "
                             f"expected one of {COLLECTIVE_KINDS}")
        self.entries.append(Collective(kind, label, float(nbytes), count))
        return self

    def per_kind(self) -> dict:
        """{kind: {"bytes", "count"}} + "total_bytes" — the same shape
        ``roofline.hlo.analyze`` reports, so the two diff directly."""
        out: dict = {}
        for e in self.entries:
            slot = out.setdefault(e.kind, {"bytes": 0.0, "count": 0})
            slot["bytes"] += e.bytes
            slot["count"] += e.count
        out["total_bytes"] = sum(e.bytes for e in self.entries)
        return out

    def total_bytes(self) -> float:
        return sum(e.bytes for e in self.entries)

    def compare(self, measured: dict, *, rtol: float = 0.05) -> list:
        """Diff this ledger against an HLO-measured collectives dict
        (``Analysis.collectives``). Returns human-readable divergence
        strings for every kind whose BYTES disagree by more than
        ``rtol`` relative — empty means the analytic model matches the
        compiled step."""
        mine = self.per_kind()
        problems = []
        kinds = (set(mine) | set(measured)) - {"total_bytes"}
        for kind in sorted(kinds):
            a = float(mine.get(kind, {}).get("bytes", 0.0))
            b = float(measured.get(kind, {}).get("bytes", 0.0))
            if a == 0.0 and b == 0.0:
                continue
            rel = abs(a - b) / max(a, b)
            if rel > rtol:
                problems.append(
                    f"{kind}: ledger {a:.0f} B vs measured {b:.0f} B "
                    f"({rel:.1%} > rtol {rtol:.1%})")
        return problems


def train_step_ledger(*, n_dev: int, rows: int, feat_dim: int,
                      head: str = "full", backend: str = "ref",
                      n_micro: int = 1, fe_param_count: int = 0,
                      dtype_bytes: int = 4,
                      label_bytes: int = 4) -> CommLedger:
    """The analytic per-step ledger for one hybrid-parallel train step.

    ``rows`` is the GLOBAL rows per step (batch for the feats trunk,
    batch*seq for LM trunks), ``fe_param_count`` the trainable FE param
    count (0 for the feats trunk — no backward/exchange collectives).
    Cross-checked against compiled HLO in ``tests/test_telemetry.py`` and
    gated in ``benchmarks/table4_comm.py``.
    """
    if head not in LEDGER_HEADS:
        raise ValueError(
            f"ledger models heads {LEDGER_HEADS}, got {head!r} — extend "
            f"the model before charging it")
    if rows % n_micro:
        raise ValueError(f"rows={rows} not divisible by n_micro={n_micro}")
    led = CommLedger()
    led.add("all-gather", "features[R,D]", rows * feat_dim * dtype_bytes,
            count=n_micro)
    led.add("all-gather", "labels[R]", rows * label_bytes, count=n_micro)
    # distributed-softmax completion: [b]-sized terms per micro sum to
    # [R]-sized terms per step; forward 5 (ref) / 4 (pallas) plus the 2
    # backward transpose-of-psum terms (z, corr) — see module docstring
    ce_terms = 7 if backend == "ref" else 6
    led.add("all-reduce", f"softmax_ce({backend})",
            ce_terms * rows * dtype_bytes, count=ce_terms * n_micro)
    if head == "knn":
        led.add("all-reduce", "knn_label_recall", rows * dtype_bytes,
                count=n_micro)
        led.add("all-reduce", "knn_active_frac", dtype_bytes * n_micro,
                count=n_micro)
    if fe_param_count > 0:
        led.add("reduce-scatter", "d_features",
                rows * feat_dim * dtype_bytes // n_dev, count=n_micro)
        led.add("all-reduce", "fe_grad_exchange",
                fe_param_count * dtype_bytes)
    return led
