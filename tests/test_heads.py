"""Head-strategy API: every registered softmax head trains through the
head-agnostic hybrid trainer under identical conditions (the paper's §4.1
comparison as a parametrized test), and the full/knn heads match their
single-device oracles exactly."""
import jax
import jax.numpy as jnp
import pytest

from repro.api import Experiment, HEAD_REGISTRY, make_head
from repro.api.heads import HeadState
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.core import knn_graph as kg
from repro.core import knn_softmax as ks
from repro.core.sharded_softmax import ce_ref
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.train import hybrid

IMPLS = ["full", "knn", "selective", "mach"]
N, D, B = 256, 32, 64
LR = {"full": 4.0, "knn": 4.0, "selective": 4.0, "mach": 0.3}


def _model_cfg(n=N, d=D):
    return ModelConfig(name="feats", family="feats", n_layers=0, d_model=d,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=n,
                       dtype="float32")


def _head_cfg(impl, **kw):
    kw.setdefault("active_frac", 0.5)
    kw.setdefault("knn_k", 8)
    kw.setdefault("knn_kprime", 16)
    return HeadConfig(softmax_impl=impl, **kw)


def test_registry_covers_paper_comparison():
    assert set(IMPLS) <= set(HEAD_REGISTRY)
    with pytest.raises(ValueError):
        make_head(_model_cfg(), HeadConfig(softmax_impl="bogus"))


@pytest.mark.parametrize("impl", IMPLS)
def test_every_head_trains_on_hybrid_mesh(mesh8, impl):
    """Identical trainer, mesh, data and optimizer for all four heads: a few
    steps must produce finite, decreasing losses and a working eval path."""
    mcfg = _model_cfg()
    hcfg = _head_cfg(impl)
    tcfg = TrainConfig(optimizer="sgd", momentum=0.9)
    stream = ClassificationStream(N, D, seed=0)
    head = make_head(mcfg, hcfg)
    state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8,
                              head=head)
    step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, head=head,
                                  state_template=state)
    with jax.set_mesh(mesh8):
        state = hybrid.refresh_head_state(head, mesh8, state)
        losses = []
        for t in range(10):
            state, loss, m = step(state, sku_feature_batch(t, B, stream),
                                  LR[impl])
            losses.append(float(loss))
        ev = hybrid.make_eval_step(mcfg, hcfg, mesh8, state, head=head)
        acc = float(ev(state, sku_feature_batch(10**6, 2 * B, stream)))
    assert all(jnp.isfinite(jnp.asarray(losses))), losses
    assert losses[-1] < losses[0], losses
    assert 0.0 <= acc <= 1.0
    for key in head.metrics_spec():
        assert key in m


@pytest.fixture(scope="module")
def small_problem():
    key = jax.random.PRNGKey(3)
    kf, ky = jax.random.split(key)
    n, d, b = 64, 32, 16
    f = jax.random.normal(kf, (b, d), jnp.float32)
    y = jax.random.randint(ky, (b,), 0, n)
    return n, d, f, y


def _first_step_loss(mesh8, impl, small_problem, **hkw):
    n, d, f, y = small_problem
    mcfg = _model_cfg(n, d)
    hcfg = _head_cfg(impl, **hkw)
    tcfg = TrainConfig(optimizer="sgd", momentum=0.0)
    head = make_head(mcfg, hcfg)
    state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8,
                              head=head)
    step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, head=head,
                                  state_template=state)
    with jax.set_mesh(mesh8):
        state = hybrid.refresh_head_state(head, mesh8, state)
        w0 = jax.device_get(state.head_params)
        _, loss, _ = step(state, {"features": f, "labels": y}, 0.0)
    return float(loss), jnp.asarray(w0)


def test_full_head_matches_ce_ref(mesh8, small_problem):
    """Distributed full-softmax loss == single-device oracle."""
    n, d, f, y = small_problem
    loss, w0 = _first_step_loss(mesh8, "full", small_problem)
    loss_ref, _ = ce_ref(f, y, w0, cosine_scale=16.0)
    assert abs(loss - float(loss_ref)) < 1e-4


def test_knn_head_matches_oracle(mesh8, small_problem):
    """With every candidate kept (m_local = V_loc, no random padding) the
    distributed KNN-softmax loss equals the single-device oracle on the
    exact graph."""
    n, d, f, y = small_problem
    loss, w0 = _first_step_loss(mesh8, "knn", small_problem,
                                active_frac=1.0, knn_pad_random=False)
    graph = kg.knn_graph_ref(w0, 8)
    loss_ref = ks.knn_softmax_ref(f, y, w0, graph, m=min(f.shape[0] * 8, n),
                                  cosine_scale=16.0)
    assert abs(loss - float(loss_ref)) < 1e-4


def test_refresh_is_noop_for_heads_without_periodic_work(mesh8):
    """rebuild_every only drives heads that HAVE periodic work; for the
    others refresh must be an identity (the launch-shim regression)."""
    mcfg = _model_cfg()
    for impl, has_work in (("full", False), ("knn", True),
                           ("selective", True), ("mach", False)):
        hcfg = _head_cfg(impl, rebuild_every=100)
        head = make_head(mcfg, hcfg)
        assert head.refresh_every == (100 if has_work else 0), impl
        if not has_work:
            hs = head.init(jax.random.PRNGKey(0), 8)
            hs2 = head.refresh(mesh8, hs, model_axis=hybrid.AXIS)
            assert hs2 is hs


def test_paper_experiment_facade(mesh8):
    """Experiment.from_config -> fit/evaluate/serve, end to end."""
    exp = Experiment.from_config(
        system="paper", classes=N, feat_dim=D, batch=B, mesh=mesh8,
        head=_head_cfg("knn", rebuild_every=0), log_every=0)
    hist = exp.fit(8, use_fccs_batch=False)
    assert len(hist) == 8
    assert hist[-1]["loss"] < hist[0]["loss"]
    acc = exp.evaluate()
    assert 0.0 <= acc <= 1.0
    preds = exp.serve(batch=B)
    assert preds.shape == (B,)
    assert preds.dtype == jnp.int32
