"""Head-strategy API: every registered softmax head trains through the
head-agnostic hybrid trainer under identical conditions (the paper's §4.1
comparison as a parametrized test), and the full/knn heads match their
single-device oracles exactly."""
import jax
import jax.numpy as jnp
import pytest

from repro.api import Experiment, HEAD_REGISTRY, make_head
from repro.api.heads import HeadState
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.core import knn_graph as kg
from repro.core import knn_softmax as ks
from repro.core.sharded_softmax import ce_ref
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.train import hybrid

IMPLS = ["full", "knn", "selective", "mach", "sampled", "csoft"]
N, D, B = 256, 32, 64
LR = {"full": 4.0, "knn": 4.0, "selective": 4.0, "mach": 0.3,
      "sampled": 4.0, "csoft": 0.3}


def _model_cfg(n=N, d=D):
    return ModelConfig(name="feats", family="feats", n_layers=0, d_model=d,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=n,
                       dtype="float32")


def _head_cfg(impl, **kw):
    kw.setdefault("active_frac", 0.5)
    kw.setdefault("knn_k", 8)
    kw.setdefault("knn_kprime", 16)
    return HeadConfig(softmax_impl=impl, **kw)


def test_registry_covers_paper_comparison():
    assert set(IMPLS) <= set(HEAD_REGISTRY)
    with pytest.raises(ValueError):
        make_head(_model_cfg(), HeadConfig(softmax_impl="bogus"))


def test_head_config_validation_names_registered_keys():
    """An unknown softmax_impl fails at HeadConfig construction with an
    error naming every registered head key."""
    with pytest.raises(ValueError) as exc:
        HeadConfig(softmax_impl="bogus")
    for key in IMPLS:
        assert key in str(exc.value)
    with pytest.raises(ValueError):
        HeadConfig(sampled_dist="zipfish")
    with pytest.raises(ValueError):
        HeadConfig(csoft_agg="max")


@pytest.mark.parametrize("impl", IMPLS)
def test_every_head_trains_on_hybrid_mesh(mesh8, impl):
    """Identical trainer, mesh, data and optimizer for all four heads: a few
    steps must produce finite, decreasing losses and a working eval path."""
    mcfg = _model_cfg()
    hcfg = _head_cfg(impl)
    tcfg = TrainConfig(optimizer="sgd", momentum=0.9)
    stream = ClassificationStream(N, D, seed=0)
    head = make_head(mcfg, hcfg)
    state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8,
                              head=head)
    step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, head=head,
                                  state_template=state)
    with jax.set_mesh(mesh8):
        state = hybrid.refresh_head_state(head, mesh8, state)
        losses = []
        for t in range(10):
            state, loss, m = step(state, sku_feature_batch(t, B, stream),
                                  LR[impl])
            losses.append(float(loss))
        ev = hybrid.make_eval_step(mcfg, hcfg, mesh8, state, head=head)
        acc = float(ev(state, sku_feature_batch(10**6, 2 * B, stream)))
    assert all(jnp.isfinite(jnp.asarray(losses))), losses
    assert losses[-1] < losses[0], losses
    assert 0.0 <= acc <= 1.0
    for key in head.metrics_spec():
        assert key in m


@pytest.fixture(scope="module")
def small_problem():
    key = jax.random.PRNGKey(3)
    kf, ky = jax.random.split(key)
    n, d, b = 64, 32, 16
    f = jax.random.normal(kf, (b, d), jnp.float32)
    y = jax.random.randint(ky, (b,), 0, n)
    return n, d, f, y


def _first_step_loss(mesh8, impl, small_problem, **hkw):
    n, d, f, y = small_problem
    mcfg = _model_cfg(n, d)
    hcfg = _head_cfg(impl, **hkw)
    tcfg = TrainConfig(optimizer="sgd", momentum=0.0)
    head = make_head(mcfg, hcfg)
    state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8,
                              head=head)
    step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, head=head,
                                  state_template=state)
    with jax.set_mesh(mesh8):
        state = hybrid.refresh_head_state(head, mesh8, state)
        w0 = jax.device_get(state.head_params)
        _, loss, _ = step(state, {"features": f, "labels": y}, 0.0)
    return float(loss), jnp.asarray(w0)


def test_full_head_matches_ce_ref(mesh8, small_problem):
    """Distributed full-softmax loss == single-device oracle."""
    n, d, f, y = small_problem
    loss, w0 = _first_step_loss(mesh8, "full", small_problem)
    loss_ref, _ = ce_ref(f, y, w0, cosine_scale=16.0)
    assert abs(loss - float(loss_ref)) < 1e-4


def test_knn_head_matches_oracle(mesh8, small_problem):
    """With every candidate kept (m_local = V_loc, no random padding) the
    distributed KNN-softmax loss equals the single-device oracle on the
    exact graph."""
    n, d, f, y = small_problem
    loss, w0 = _first_step_loss(mesh8, "knn", small_problem,
                                active_frac=1.0, knn_pad_random=False)
    graph = kg.knn_graph_ref(w0, 8)
    loss_ref = ks.knn_softmax_ref(f, y, w0, graph, m=min(f.shape[0] * 8, n),
                                  cosine_scale=16.0)
    assert abs(loss - float(loss_ref)) < 1e-4


def test_refresh_is_noop_for_heads_without_periodic_work(mesh8):
    """rebuild_every only drives heads that HAVE periodic work; for the
    others refresh must be an identity (the launch-shim regression)."""
    mcfg = _model_cfg()
    for impl, has_work in (("full", False), ("knn", True),
                           ("selective", True), ("mach", False),
                           ("sampled", False), ("csoft", False)):
        hcfg = _head_cfg(impl, rebuild_every=100)
        head = make_head(mcfg, hcfg)
        assert head.refresh_every == (100 if has_work else 0), impl
        if not has_work:
            hs = head.init(jax.random.PRNGKey(0), 8)
            hs2 = head.refresh(mesh8, hs, model_axis=hybrid.AXIS)
            assert hs2 is hs


def test_sampled_loss_approaches_full_softmax(mesh8, small_problem):
    """The logQ-corrected sampled loss converges to the full-softmax loss
    as the sample count approaches the class count, matching it EXACTLY at
    full draw (uniform mode samples per-shard without replacement)."""
    n, d, f, y = small_problem
    diffs = []
    for m in (n // 4, n // 2, n):
        loss, w0 = _first_step_loss(mesh8, "sampled", small_problem,
                                    sampled_n=m)
        loss_ref, _ = ce_ref(f, y, jnp.asarray(w0), cosine_scale=16.0)
        diffs.append(abs(loss - float(loss_ref)))
    assert diffs[-1] < 1e-3, diffs
    assert diffs[0] > diffs[1] > diffs[2], diffs


def test_sampled_log_uniform_trains(mesh8):
    """The Zipfian (with-replacement, shared-draw) sampler also trains:
    finite decreasing losses and fresh negatives every step."""
    mcfg = _model_cfg()
    hcfg = _head_cfg("sampled", sampled_dist="log_uniform", sampled_n=128)
    tcfg = TrainConfig(optimizer="sgd", momentum=0.9)
    stream = ClassificationStream(N, D, seed=0)
    head = make_head(mcfg, hcfg)
    state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8,
                              head=head)
    step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, head=head,
                                  state_template=state)
    with jax.set_mesh(mesh8):
        losses = []
        for t in range(8):
            state, loss, m = step(state, sku_feature_batch(t, B, stream),
                                  4.0)
            losses.append(float(loss))
    assert all(jnp.isfinite(jnp.asarray(losses))), losses
    assert losses[-1] < losses[0], losses
    assert 0.0 < float(m["sample_frac"]) <= 1.0


def test_csoft_decode_roundtrips_labels(mesh8):
    """Count-min decode: encode each class's centroid into the sketch
    (bucket weight = superposition of the centroids hashing there), then
    the min-aggregated distributed decode recovers the class with high
    top-1 recovery on a small vocabulary."""
    n, d = 64, 32
    mcfg = _model_cfg(n, d)
    tcfg = TrainConfig(optimizer="sgd", momentum=0.0)
    cent = jax.random.normal(jax.random.PRNGKey(7), (n, d), jnp.float32)
    cent = cent / jnp.linalg.norm(cent, axis=-1, keepdims=True)
    for agg in ("min", "mean"):
        hcfg = _head_cfg("csoft", csoft_b=32, csoft_r=4, csoft_agg=agg)
        head = make_head(mcfg, hcfg)
        state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg,
                                  8, head=head)
        hashes = jnp.asarray(jax.device_get(state.head_aux[0]))  # [R, N]
        w = jnp.zeros(state.head_params.shape, jnp.float32)
        for r in range(hashes.shape[0]):
            w = w.at[r].set(w[r].at[hashes[r]].add(cent) * 16.0)
        state = state._replace(head_params=w)
        ev = hybrid.make_eval_step(mcfg, hcfg, mesh8, state, head=head)
        with jax.set_mesh(mesh8):
            acc = float(ev(state, {"features": cent,
                                   "labels": jnp.arange(n)}))
        assert acc >= 0.9, (agg, acc)


@pytest.mark.parametrize("impl", ["knn", "sampled", "csoft"])
def test_zoo_experiment_any_registry_head(impl):
    """ZooExperiment routes its loss through the head registry: graph-
    carrying, W-sampling and sketch heads all train + evaluate on the
    GSPMD mesh with no trainer changes."""
    kw = {"knn": dict(knn_k=8, active_frac=0.5, rebuild_every=2),
          "sampled": dict(sampled_n=256),
          "csoft": dict(csoft_b=64, csoft_r=2)}[impl]
    exp = Experiment.from_config(
        system="zoo", arch="smollm_135m", reduced=True, batch=8, seq=32,
        head=HeadConfig(softmax_impl=impl, **kw), log_every=0)
    hist = exp.fit(3, lr=0.2)
    assert len(hist) == 3
    assert all(jnp.isfinite(jnp.asarray([r["loss"] for r in hist])))
    acc = exp.evaluate()
    assert 0.0 <= acc <= 1.0


def test_zoo_registry_parity_with_hybrid(mesh8, mesh2x4, par2x4):
    """Same head (mach), same FE/head init keys, same repeated batch: the
    registry-routed zoo step and the hybrid trainer produce comparable
    decreasing loss trajectories (different meshes, same math)."""
    from jax.sharding import NamedSharding

    from repro.configs.base import InputShape
    from repro.data.synthetic import lm_batch
    from repro.models import lm
    from repro.optim import make_optimizer
    from repro.train import gspmd
    from tests.conftest import reduced_cfg

    cfg = reduced_cfg("smollm_135m")
    hcfg = HeadConfig(softmax_impl="mach", mach_b=64, mach_r=2)
    tcfg = TrainConfig(optimizer="sgd", momentum=0.0)
    inputs = lm_batch(0, 16, 32, cfg.vocab_size)
    steps, lr = 4, 0.2

    head = make_head(cfg, hcfg)
    state = hybrid.init_state(jax.random.PRNGKey(0), cfg, hcfg, tcfg, 8,
                              head=head)
    step = hybrid.make_train_step(cfg, hcfg, tcfg, mesh8, head=head,
                                  state_template=state)
    losses_h = []
    with jax.set_mesh(mesh8):
        for _ in range(steps):
            state, loss, _ = step(state, inputs, lr)
            losses_h.append(float(loss))

    # zoo side with the SAME init keys hybrid.init_state used
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    head_z = make_head(cfg, hcfg)
    with jax.set_mesh(mesh2x4):
        params = lm.init_model(k1, cfg)
        params = jax.tree.map(jax.device_put, params,
                              gspmd.param_shardings(cfg, par2x4, mesh2x4))
        hs = head_z.init(k2, 4)   # mach_b=64 divides 8 and 4: same arrays

        def put(tree, spec):
            return jax.tree.map(
                lambda a, s: jax.device_put(a, NamedSharding(mesh2x4, s)),
                tree, spec)

        hstate = HeadState(put(hs.params, head_z.params_spec("model")),
                           put(hs.aux, head_z.aux_spec("model")))
        opt_state = make_optimizer(tcfg).init((params, hstate.params))
        zstep = jax.jit(gspmd.make_head_train_step(
            cfg, hcfg, par2x4, tcfg, mesh2x4,
            InputShape("t", 32, 16, "train"), head=head_z))
        losses_z = []
        for _ in range(steps):
            params, hstate, opt_state, loss, _ = zstep(
                params, hstate, opt_state, inputs, lr)
            losses_z.append(float(loss))

    assert losses_h[-1] < losses_h[0], losses_h
    assert losses_z[-1] < losses_z[0], losses_z
    # identical starting loss (same init, same math) ...
    assert abs(losses_h[0] - losses_z[0]) < 1e-3, (losses_h, losses_z)
    # ... and comparable descent after updates (hybrid's dense_exchange
    # averages FE grads over the ring, so the paths drift slightly)
    for a, b in zip(losses_h, losses_z):
        assert abs(a - b) < 0.15 * losses_h[0], (losses_h, losses_z)


def test_paper_experiment_facade(mesh8):
    """Experiment.from_config -> fit/evaluate/serve, end to end."""
    exp = Experiment.from_config(
        system="paper", classes=N, feat_dim=D, batch=B, mesh=mesh8,
        head=_head_cfg("knn", rebuild_every=0), log_every=0)
    hist = exp.fit(8, use_fccs_batch=False)
    assert len(hist) == 8
    assert hist[-1]["loss"] < hist[0]["loss"]
    acc = exp.evaluate()
    assert 0.0 <= acc <= 1.0
    preds = exp.serve(batch=B)
    assert preds.shape == (B,)
    assert preds.dtype == jnp.int32
