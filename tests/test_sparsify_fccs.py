"""DGC sparsification (paper §3.3.2) + FCCS (paper §3.4) semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import DGCConfig, FCCSConfig
from repro.core import fccs
from repro.core import sparsify as sp


def _grads(key, shapes=((64, 32), (128,), (16, 16, 4))):
    ks = jax.random.split(key, len(shapes))
    return {f"p{i}": jax.random.normal(k, s)
            for i, (k, s) in enumerate(zip(ks, shapes))}


def test_first_step_conservation():
    """Step 1: communicated + residual == gradient exactly (error feedback
    loses nothing)."""
    g = _grads(jax.random.PRNGKey(0))
    cfg = DGCConfig(enabled=True, sparsity=0.9, momentum=0.9, chunk=64)
    st = sp.init_dgc_state(g)
    out, st2, info = sp.dgc_exchange(g, st, cfg)
    err = jax.tree.map(lambda o, r, orig: float(jnp.max(jnp.abs(o + r - orig))),
                       out, st2.v, g)
    assert max(jax.tree.leaves(err)) < 1e-6


def test_sparsity_level():
    g = _grads(jax.random.PRNGKey(1))
    n_total = sum(x.size for x in jax.tree.leaves(g))
    cfg = DGCConfig(enabled=True, sparsity=0.95, chunk=64,
                    group_bytes=1 << 30)
    st = sp.init_dgc_state(g)
    out, _, info = sp.dgc_exchange(g, st, cfg)
    kept = sum(int((jnp.abs(x) > 0).sum()) for x in jax.tree.leaves(out))
    assert kept <= int(n_total * 0.05) + len(jax.tree.leaves(g)) * 2
    assert float(info["compression"]) > 5.0


def test_momentum_factor_masking():
    """Selected coordinates must have their momentum buffer zeroed."""
    g = _grads(jax.random.PRNGKey(2))
    cfg = DGCConfig(enabled=True, sparsity=0.8, momentum=0.9, chunk=64,
                    factor_masking=True)
    st = sp.init_dgc_state(g)
    out, st2, _ = sp.dgc_exchange(g, st, cfg)
    for o, u in zip(jax.tree.leaves(out), jax.tree.leaves(st2.u)):
        sel = jnp.abs(o) > 0
        assert float(jnp.max(jnp.abs(jnp.where(sel, u, 0.0)))) == 0.0


def test_error_feedback_accumulates():
    """A coordinate below threshold eventually gets sent once its residual
    accumulates (momentum correction)."""
    cfg = DGCConfig(enabled=True, sparsity=0.75, momentum=0.0, chunk=8,
                    factor_masking=False)
    g = {"p": jnp.array([1.0, 0.4, 0.3, 0.2])}  # keep-1-of-4 -> only 1.0 sent
    st = sp.init_dgc_state(g)
    sent_history = []
    for _ in range(4):
        out, st, _ = sp.dgc_exchange(g, st, cfg)
        sent_history.append(np.asarray(out["p"]))
    total_sent = np.sum(sent_history, axis=0)
    total_grad = 4 * np.asarray(g["p"])
    resid = np.asarray(st.v["p"])
    np.testing.assert_allclose(total_sent + resid, total_grad, atol=1e-6)
    assert (np.abs(np.sum(sent_history, axis=0))[1:] > 0).any()


def test_dc_threshold_exact_vs_ref():
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (5000,)))
    for k in (1, 7, 100, 4999):
        assert float(sp.topk_threshold_dc(x, k, chunk=128)) == \
            float(sp.topk_threshold_ref(x, k))


def test_group_leaves_packing():
    leaves = [jnp.zeros((n,)) for n in (100, 200, 5000, 50, 50)]
    groups = sp.group_leaves(leaves, group_bytes=2048)
    flat = [i for g in groups for i in g]
    assert sorted(flat) == list(range(5))
    for g in groups[1:]:
        assert g  # non-empty


# ---------------------------------------------------------------------------
# FCCS
# ---------------------------------------------------------------------------

CFG = FCCSConfig(eta0=0.4, t_warm=10, b0=64, b_min=64, b_max=4096,
                 t_ini=20, t_final=120)


def test_warmup_then_constant():
    lrs = [fccs.learning_rate(t, CFG) for t in range(30)]
    assert lrs[0] < lrs[5] < lrs[9]
    assert all(abs(lr - 0.4) < 1e-9 for lr in lrs[10:])


def test_batch_monotone_increasing():
    bs = [fccs.batch_size(t, CFG) for t in range(0, 200, 5)]
    assert all(b2 >= b1 for b1, b2 in zip(bs, bs[1:]))
    assert bs[0] == 64 and bs[-1] == 4096


def test_paper_printed_formula_decreases():
    """The paper's printed f(t) is the decreasing branch (DESIGN.md notes the
    sign discrepancy with its own Fig. 7)."""
    b_start = fccs.batch_size(20, CFG, decreasing=True)
    b_end = fccs.batch_size(119, CFG, decreasing=True)
    assert b_start > b_end


def test_accum_steps_realize_batch():
    for t in (0, 50, 119, 150):
        n = fccs.accum_steps(t, CFG, hw_batch=64)
        assert n * 64 >= fccs.batch_size(t, CFG)
        assert (n - 1) * 64 < fccs.batch_size(t, CFG)


def test_piecewise_decay():
    lr = [fccs.piecewise_decay_lr(t, eta0=1.0, steps_per_epoch=10)
          for t in (0, 49, 50, 100)]
    assert lr[0] == 1.0 and lr[1] == 1.0
    assert lr[2] == pytest.approx(0.1) and lr[3] == pytest.approx(0.01)
