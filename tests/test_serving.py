"""Serving-tier tests: coalescer / cache / trace units, engine-vs-single
bitwise parity for every registry head x backend, weight-refresh
invalidation, and the launcher/facade argument validation."""
import json

import numpy as np
import pytest

from benchmarks.common import write_bench
from repro.api import Experiment
from repro.api.heads import HEAD_REGISTRY, make_head  # noqa: F401
from repro.configs.base import HeadConfig
from repro.serving import (Coalescer, Request, ScoreCache, ServingEngine,
                           TraceConfig, VirtualClock, bucket_for,
                           generate_trace, latency_stats, make_query_pool,
                           replay_trace)

ALL_HEADS = ["full", "knn", "selective", "mach", "sampled", "csoft"]
N, D = 128, 16


def _head_cfg(impl, backend="ref"):
    return HeadConfig(softmax_impl=impl, backend=backend, active_frac=0.5,
                      knn_k=8, knn_kprime=16, sampled_n=64, csoft_b=32,
                      csoft_r=4)


# ---------------------------------------------------------------------------
# coalescer
# ---------------------------------------------------------------------------


def test_bucket_for_pow2_floor_cap():
    assert [bucket_for(n, 2, 64) for n in (1, 2, 3, 4, 5, 9, 63)] == \
        [2, 2, 4, 4, 8, 16, 64]
    assert bucket_for(64, 2, 64) == 64
    assert bucket_for(200, 2, 64) == 64        # overflow clamps to max
    assert bucket_for(1, 1, 64) == 1           # min_bucket=1 allows matvec
    assert bucket_for(3, 2, 48) == 4           # non-pow2 cap: pow2 below it
    assert bucket_for(50, 2, 48) == 48         # ...full batch runs at cap


def _req(rid, t):
    return Request(rid=rid, query=np.float32([rid]), t_submit=t)


def test_coalescer_full_batch_cuts_immediately():
    c = Coalescer(max_batch=4, max_wait=10.0)
    for i in range(9):
        c.put(_req(i, t=0.001 * i))
    batches = c.ready(now=0.01)
    assert [len(b.requests) for b in batches] == [4, 4]  # 1 leftover waits
    assert all(b.bucket == 4 for b in batches)
    assert len(c) == 1
    assert c.ready(now=0.01) == []             # leftover is younger than wait


def test_coalescer_deadline_flush_and_occupancy():
    c = Coalescer(max_batch=8, max_wait=0.005, min_bucket=2)
    c.put(_req(0, t=1.0))
    c.put(_req(1, t=1.001))
    c.put(_req(2, t=1.002))
    assert c.ready(now=1.004) == []            # oldest has waited 4ms < 5ms
    assert c.oldest_deadline() == pytest.approx(1.005)
    (mb,) = c.ready(now=1.0051)                # oldest expired -> cut all 3
    assert len(mb.requests) == 3 and mb.bucket == 4
    assert mb.occupancy == pytest.approx(3 / 4)
    assert len(c) == 0 and c.oldest_deadline() is None


def test_coalescer_cuts_exactly_at_its_reported_deadline():
    """Regression: (t + w) - t can round below w in float64; a clock
    advanced exactly to oldest_deadline() must still trigger the cut
    (this once made replay_trace spin forever)."""
    assert (1e6 + 0.002) - 1e6 < 0.002          # the rounding this guards
    for t in (1.0, 123.456, 1e6, 1.7e9):        # incl. epoch-sized stamps
        c = Coalescer(max_batch=8, max_wait=0.002)
        c.put(_req(0, t=t))
        (mb,) = c.ready(now=c.oldest_deadline())
        assert len(mb.requests) == 1


def test_coalescer_deterministic_under_out_of_order_submits():
    """Same requests, permuted submission order + out-of-order timestamps:
    identical packing (sorted by (t_submit, seq))."""
    def pack(order):
        c = Coalescer(max_batch=4, max_wait=0.0)
        for i in order:
            c.put(_req(i, t=2.0 - 0.001 * i))  # later submits = older stamps
        return [[r.rid for r in mb.requests] for mb in c.flush(now=9.0)]

    base = pack(range(8))
    assert base == [[7, 6, 5, 4], [3, 2, 1, 0]]   # t_submit order, not rid
    for order in ([7, 3, 5, 1, 6, 2, 4, 0], list(reversed(range(8)))):
        assert pack(order) == base


# ---------------------------------------------------------------------------
# score cache
# ---------------------------------------------------------------------------


def test_cache_exact_hit_and_lru_eviction():
    cache = ScoreCache(capacity=2)
    q = [np.float32([i, i]) for i in range(3)]
    cache.put(q[0], "a")
    cache.put(q[1], "b")
    assert cache.get(q[0]) == ("a", "exact")   # refreshes q0's LRU slot
    cache.put(q[2], "c")                       # evicts q1 (least recent)
    assert cache.get(q[1]) is None
    assert cache.get(q[0]) == ("a", "exact")
    assert cache.get(q[2]) == ("c", "exact")
    st = cache.stats()
    assert st["size"] == 2 and st["misses"] == 1 and st["exact_hits"] == 3
    assert st["hit_rate"] == pytest.approx(3 / 4)


def test_cache_cosine_threshold_hits():
    cache = ScoreCache(capacity=8, cosine_threshold=0.99)
    q = np.float32([1.0, 0.0, 0.0])
    cache.put(q, "hot")
    near = np.float32([1.0, 0.02, 0.0])        # cos ~ 0.9998
    far = np.float32([0.0, 1.0, 0.0])          # cos = 0
    assert cache.get(near) == ("hot", "cosine")
    assert cache.get(far) is None
    assert cache.get(2.0 * q) == ("hot", "cosine")  # scale-invariant
    exact = cache.stats()
    assert exact["cosine_hits"] == 2 and exact["misses"] == 1


def test_cache_invalidate_drops_entries_keeps_counters():
    cache = ScoreCache(capacity=4)
    q = np.float32([3.0])
    cache.put(q, "x")
    assert cache.get(q) == ("x", "exact")
    cache.invalidate()
    assert len(cache) == 0
    assert cache.get(q) is None
    st = cache.stats()
    assert st["invalidations"] == 1 and st["hits"] == 1 and st["misses"] == 1


# ---------------------------------------------------------------------------
# trace generator + virtual clock
# ---------------------------------------------------------------------------


def test_trace_reproducible_ascending_and_rate_sane():
    cfg = TraceConfig(duration=20.0, seed=3)
    times, qids = generate_trace(cfg)
    t2, q2 = generate_trace(cfg)
    assert np.array_equal(times, t2) and np.array_equal(qids, q2)
    assert np.all(np.diff(times) > 0) and times[-1] < cfg.duration
    assert qids.min() >= 0 and qids.max() < cfg.pool
    measured = len(times) / cfg.duration
    # long-run MMPP rate: generous 35% tolerance for a 20s sample
    assert abs(measured - cfg.expected_rate) / cfg.expected_rate < 0.35


def test_trace_zipf_mix_is_skewed():
    times, qids = generate_trace(TraceConfig(duration=30.0, zipf_s=1.3,
                                             pool=64, seed=1))
    counts = np.bincount(qids, minlength=64)
    # hottest query dominates a uniform mix by a wide margin
    assert counts.max() > 3 * len(times) / 64
    assert counts[0] == counts.max()           # rank 0 is the hottest


def test_query_pool_shape_and_clock():
    pool = make_query_pool(N, D, 7, seed=0)
    assert pool.shape == (7, D) and pool.dtype == np.float32
    clk = VirtualClock()
    clk.advance_to(1.5)
    clk.advance(0.5)
    clk.advance_to(1.0)                        # never rewinds
    assert clk.now() == clk() == 2.0
    with pytest.raises(ValueError):
        clk.advance(-0.1)


# ---------------------------------------------------------------------------
# engine mechanics (fake step_fn — no jax)
# ---------------------------------------------------------------------------


def _fake_engine(**kw):
    calls = []

    def step_fn(queries, n_valid):
        calls.append((queries.shape, n_valid))
        ids = np.full(queries.shape[0], -1, np.int32)
        ids[:n_valid] = queries[:n_valid, 0].astype(np.int32)
        return ids, None

    return ServingEngine(step_fn, **kw), calls


def test_engine_pads_to_bucket_and_masks():
    clk = VirtualClock()
    eng, calls = _fake_engine(max_batch=8, max_wait_ms=1.0, clock=clk.now)
    rids = [eng.submit(np.float32([i, 0.0])) for i in range(3)]
    assert eng.poll() == []                    # not full, not expired
    clk.advance(0.002)
    done = eng.poll()
    assert calls == [((4, 2), 3)]              # 3 queries -> bucket 4
    assert sorted(r.rid for r in done) == rids
    assert [int(r.ids) for r in sorted(done, key=lambda r: r.rid)] == [0, 1, 2]
    assert all(r.bucket == 4 and r.batch_n == 3 for r in done)
    st = eng.stats()
    assert st["n_batches"] == 1
    assert st["mean_batch_occupancy"] == pytest.approx(3 / 4)


def test_engine_serial_server_latency_model():
    """Two bursts flushed back-to-back: the second batch queues behind the
    first (t_start == first batch's t_done), so its latency includes the
    queueing delay."""
    clk = VirtualClock()
    eng, _ = _fake_engine(max_batch=2, max_wait_ms=0.0, clock=clk.now)
    for i in range(4):
        eng.submit(np.float32([i, 0.0]))
    done = sorted(eng.drain(), key=lambda r: r.rid)
    b1, b2 = done[0], done[2]
    assert b1.t_flush == b2.t_flush == 0.0
    assert b2.t_start == pytest.approx(b1.t_done)
    assert b2.latency > b1.latency
    assert latency_stats(done)["n"] == 4
    assert latency_stats([])["p99_ms"] == 0.0


def test_engine_cache_hits_and_version_invalidation():
    version = [0]
    clk = VirtualClock()
    eng, calls = _fake_engine(max_batch=4, max_wait_ms=0.0, clock=clk.now,
                              cache=ScoreCache(16),
                              version_fn=lambda: version[0])
    q = np.float32([7.0, 0.0])
    eng.submit(q)
    (first,) = eng.drain()
    assert not first.cached and len(calls) == 1
    eng.submit(q)                              # exact hit, no compute
    (hit,) = eng.drain()
    assert hit.cached and int(hit.ids) == int(first.ids)
    # a hit is served in the measured lookup time — positive (the old
    # clock-quantized 0.0 hid the lookup cost) but well under a millisecond
    assert len(calls) == 1 and 0.0 < hit.latency < 1e-3
    version[0] += 1                            # weights refreshed
    eng.submit(q)
    (recomputed,) = eng.drain()
    assert not recomputed.cached and len(calls) == 2
    assert eng.cache.stats()["invalidations"] == 1


def test_cache_invalidated_after_restore_rewind(mesh8, tmp_path):
    """A checkpoint restore REWINDS the step counter; retraining back to a
    previously-cached step value yields different-version weights at the
    SAME step. A bare step probe cannot see that — ``weights_version`` is
    (restore count, step) precisely so the cache invalidates here."""
    exp = Experiment.from_config(
        system="paper", classes=N, feat_dim=D, batch=8, mesh=mesh8,
        head=_head_cfg("full"), log_every=0,
        ckpt_dir=str(tmp_path / "ck"), ckpt_every=1)
    exp.fit(2, use_fccs_batch=False)
    cache = ScoreCache(64)
    eng = exp.serving_engine(top_k=3, cache=cache)
    q = make_query_pool(N, D, 1, seed=3)[0]
    eng.submit(q)
    eng.drain()
    eng.submit(q)
    (hit,) = eng.drain()
    assert hit.cached

    v0 = exp.weights_version
    exp.restore(step=1)
    exp.fit(1, use_fccs_batch=False)           # back at step 2
    # same step counter as when the score was cached, different version
    assert exp.weights_version[1] == v0[1]
    assert exp.weights_version != v0
    eng.submit(q)
    (fresh,) = eng.drain()
    assert not fresh.cached
    assert cache.stats()["invalidations"] == 1


def test_replay_trace_flushes_lull_tails_at_their_deadline():
    """A query arriving right before a long lull must be flushed at its
    max-wait deadline, not at the next arrival."""
    clk = VirtualClock()
    eng, _ = _fake_engine(max_batch=8, max_wait_ms=2.0, clock=clk.now)
    times = np.float64([0.0, 0.001, 1.0])      # 1s lull after two arrivals
    qids = np.int32([0, 1, 0])
    pool = np.float32([[5.0, 0.0], [6.0, 0.0]])
    done = replay_trace(eng, clk, times, qids, pool)
    assert len(done) == 3
    early = sorted(done, key=lambda r: r.rid)[0]
    assert early.t_done == pytest.approx(0.002, abs=1e-4)  # not 1.0
    assert max(r.latency for r in done) < 0.01


# ---------------------------------------------------------------------------
# engine <-> per-query bitwise parity on the real serve steps
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def _exp_cache():
    return {}


def _paper_exp(_exp_cache, mesh8, impl, backend):
    key = (impl, backend)
    if key not in _exp_cache:
        _exp_cache[key] = Experiment.from_config(
            system="paper", classes=N, feat_dim=D, batch=8, mesh=mesh8,
            head=_head_cfg(impl, backend), log_every=0)
    return _exp_cache[key]


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("impl", ALL_HEADS)
def test_engine_batched_equals_per_query(impl, backend, mesh8, _exp_cache):
    """One micro-batch of K queries returns bitwise the same ids/scores as
    K single-query submissions (each padded to the min bucket) — the
    coalescer's shape choices must never change answers."""
    exp = _paper_exp(_exp_cache, mesh8, impl, backend)
    top_k = 3 if exp.trainer.head.params_are_class_weights else None
    queries = make_query_pool(N, D, 5, seed=42)
    eng = exp.serving_engine(top_k=top_k, max_batch=8)

    for q in queries:
        eng.submit(q)
    batched = {r.rid: r for r in eng.drain()}
    assert len(batched) == 5
    assert all(r.bucket == 8 and r.batch_n == 5 for r in batched.values())

    for i, q in enumerate(queries):
        eng.submit(q)
        (single,) = eng.drain()
        assert single.bucket == 2
        ref = batched[i]
        assert np.array_equal(np.asarray(ref.ids), np.asarray(single.ids))
        assert np.asarray(ref.ids).min() >= 0   # padded rows never leak
        if top_k is not None:
            assert np.array_equal(np.asarray(ref.scores),
                                  np.asarray(single.scores))
            assert np.all(np.isfinite(np.asarray(ref.scores)))
        else:
            assert single.scores is None


def test_serve_facade_routes_through_engine(mesh8, _exp_cache):
    """Experiment.serve(batch=...) — the engine path — returns the same
    ids for the same queries as direct engine submission, any batch size
    (no ring-divisibility constraint)."""
    exp = _paper_exp(_exp_cache, mesh8, "full", "ref")
    preds = exp.serve(batch=5)
    assert preds.shape == (5,) and preds.dtype == np.int32
    ids, scores = exp.serve(batch=3, top_k=4, return_scores=True)
    assert ids.shape == (3, 4) and scores.shape == (3, 4)
    assert np.all(np.diff(scores, axis=1) <= 0)     # descending scores
    assert exp.serve(batch=1).shape == (1,)         # below the ring size


def test_topk_rejected_for_sketch_heads(mesh8, _exp_cache):
    exp = _paper_exp(_exp_cache, mesh8, "mach", "ref")
    with pytest.raises(NotImplementedError, match="top-k"):
        exp.serving_engine(top_k=3)


def test_zoo_engine_matches_per_query():
    """The GSPMD feature-serving step behind the same engine: batched ==
    per-query, greedy ids in-vocab."""
    exp = Experiment.from_config(
        system="zoo", arch="smollm_135m", reduced=True, batch=8, seq=32,
        head=HeadConfig(softmax_impl="full"), log_every=0)
    d = exp.model_cfg.d_model
    queries = make_query_pool(exp.model_cfg.vocab_size, d, 3, seed=7)
    eng = exp.serving_engine(max_batch=4)
    for q in queries:
        eng.submit(q)
    batched = {r.rid: r for r in eng.drain()}
    for i, q in enumerate(queries):
        eng.submit(q)
        (single,) = eng.drain()
        assert np.array_equal(np.asarray(batched[i].ids),
                              np.asarray(single.ids))
        assert 0 <= int(single.ids) < exp.model_cfg.vocab_size


# ---------------------------------------------------------------------------
# validation + bench trajectory
# ---------------------------------------------------------------------------


def test_serve_argument_validation(mesh8, _exp_cache):
    exp = _paper_exp(_exp_cache, mesh8, "full", "ref")
    with pytest.raises(ValueError, match="positive query count"):
        exp.serve(batch=0)
    with pytest.raises(ValueError, match="positive query count"):
        exp.serve(batch=-3)
    with pytest.raises(ValueError, match=r"top_k must be in \[1,"):
        exp.serve(batch=4, top_k=0)
    with pytest.raises(ValueError, match=str(N)):
        exp.serve(batch=4, top_k=N + 1)
    with pytest.raises(ValueError, match=r"top_k must be in \[1,"):
        exp.serving_engine(top_k=10 ** 9)


@pytest.mark.parametrize("argv", [
    ["--batch", "0"],
    ["--topk", "-1"],
    ["--system", "paper", "--classes", "512", "--topk", "513"],
    ["--cache", "-2"],
    ["--max-wait-ms", "-1"],
])
def test_launcher_rejects_bad_args(argv):
    from repro.launch import serve
    with pytest.raises(SystemExit) as e:
        serve.main(argv)
    assert e.value.code == 2                   # argparse error, pre-jax


def test_write_bench_appends_schema_records(tmp_path):
    p1 = write_bench("t", {"a": 1}, root=str(tmp_path))
    p2 = write_bench("t", {"a": 2}, root=str(tmp_path))
    assert p1 == p2 == str(tmp_path / "BENCH_t.json")
    records = json.loads((tmp_path / "BENCH_t.json").read_text())
    assert [r["payload"]["a"] for r in records] == [1, 2]
    assert all(r["schema"] == 1 and r["table"] == "t" and "written" in r
               and "platform" in r for r in records)
    (tmp_path / "BENCH_bad.json").write_text('{"not": "a list"}')
    with pytest.raises(ValueError, match="trajectory"):
        write_bench("bad", {}, root=str(tmp_path))
