"""KNN softmax (paper §3.2): exact distributed graph build, compression,
active-class selection (Algorithm 1) invariants, lossless-limit equivalence."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import knn_graph as kg
from repro.core import knn_softmax as ks
from repro.core import sharded_softmax as ss

KSPEC = {"accuracy": P(), "logz": P(), "active_frac": P(),
         "label_recall": P()}


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    kf, kw, ky = jax.random.split(key, 3)
    N, D, B = 64, 32, 16
    return (jax.random.normal(kf, (B, D)),
            jax.random.normal(kw, (N, D)),
            jax.random.randint(ky, (B,), 0, N))


def test_ring_build_is_exact(mesh2x4, problem):
    _, w, _ = problem
    g_ref = kg.knn_graph_ref(w, 8)
    w_sh = jax.device_put(w, NamedSharding(mesh2x4, P("model", None)))
    g = np.asarray(kg.build_graph_distributed(mesh2x4, w_sh, k=8, kprime=16))
    assert (np.sort(g, 1) == np.sort(np.asarray(g_ref), 1)).all()


def test_self_is_first_neighbor(problem):
    """Normalized W: w_y ranks first in its own list — the property
    Algorithm 1's lossless label inclusion relies on."""
    _, w, _ = problem
    g = np.asarray(kg.knn_graph_ref(w, 8))
    assert (g[:, 0] == np.arange(w.shape[0])).all()


def test_compression_roundtrip(problem):
    """CSR per shard contains exactly the local-owned neighbor entries."""
    _, w, _ = problem
    n = w.shape[0]
    g = np.asarray(kg.knn_graph_ref(w, 8))
    cg = kg.compress_graph(g, 4)
    n_loc = n // 4
    for p in range(4):
        offs = np.asarray(cg.offsets[p])
        nbrs = np.asarray(cg.neighbors[p])
        for row in range(n):
            got = sorted(nbrs[offs[row]:offs[row + 1]].tolist())
            want = sorted((g[row][(g[row] // n_loc) == p] % n_loc).tolist())
            assert got == want, (p, row)
    # paper's memory claim: sum of shard storage ~= full graph
    total_entries = sum(int(cg.offsets[p][-1]) for p in range(4))
    assert total_entries == g.size


def _knn_fn(mesh, B, m_local, k_cap, pad_random=False):
    body = functools.partial(
        ks.knn_softmax_local, model_axis="model", batch_axes=("data",),
        global_batch=B, m_local=m_local, k_cap=k_cap, cosine_scale=16.0,
        pad_random=pad_random)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None), P("data"), P("model", None),
                  P("model", None), P("model", None), P("model", None)),
        out_specs=(P(), dict(KSPEC)))


def test_label_recall_is_one(mesh2x4, problem):
    f, w, y = problem
    g = np.asarray(kg.knn_graph_ref(w, 8))
    cg = kg.compress_graph(g, 4)
    fn = _knn_fn(mesh2x4, f.shape[0], m_local=24, k_cap=8)
    with jax.set_mesh(mesh2x4):
        loss, m = jax.jit(fn)(f, y, w, cg.offsets, cg.neighbors, cg.ranks)
    assert float(m["label_recall"]) == 1.0
    assert bool(jnp.isfinite(loss))


def test_all_active_limit_equals_full_softmax(mesh2x4, problem):
    """K = N and M_local = V_local: KNN softmax == full cosine softmax."""
    f, w, y = problem
    n = w.shape[0]
    g = np.asarray(kg.knn_graph_ref(w, n))
    cg = kg.compress_graph(g, 4)
    fn = _knn_fn(mesh2x4, f.shape[0], m_local=n // 4, k_cap=n)
    with jax.set_mesh(mesh2x4):
        loss, m = jax.jit(fn)(f, y, w, cg.offsets, cg.neighbors, cg.ranks)
    loss_ref, _ = ss.ce_ref(f, y, w, cosine_scale=16.0)
    assert abs(float(loss) - float(loss_ref)) < 1e-4


def test_knn_loss_lower_bounds_full(mesh2x4, problem):
    """Fewer active classes -> smaller Z -> loss <= full softmax loss."""
    f, w, y = problem
    g = np.asarray(kg.knn_graph_ref(w, 8))
    cg = kg.compress_graph(g, 4)
    fn = _knn_fn(mesh2x4, f.shape[0], m_local=12, k_cap=8)
    with jax.set_mesh(mesh2x4):
        loss, _ = jax.jit(fn)(f, y, w, cg.offsets, cg.neighbors, cg.ranks)
    loss_full, _ = ss.ce_ref(f, y, w, cosine_scale=16.0)
    assert float(loss) <= float(loss_full) + 1e-5


def test_knn_grads_touch_only_active_rows(mesh2x4, problem):
    f, w, y = problem
    g = np.asarray(kg.knn_graph_ref(w, 4))
    cg = kg.compress_graph(g, 4)
    # loss-only shard_map: old-jax transpose chokes on the symbolic-zero
    # cotangents of the stop-gradient'd metrics outputs
    body = functools.partial(
        ks.knn_softmax_local, model_axis="model", batch_axes=("data",),
        global_batch=f.shape[0], m_local=10, k_cap=4, cosine_scale=16.0,
        pad_random=False)
    fn = jax.shard_map(
        lambda *a: body(*a)[0], mesh=mesh2x4,
        in_specs=(P("data", None), P("data"), P("model", None),
                  P("model", None), P("model", None), P("model", None)),
        out_specs=P())
    with jax.set_mesh(mesh2x4):
        gw = jax.jit(jax.grad(
            lambda w_: fn(f, y, w_, cg.offsets, cg.neighbors, cg.ranks)))(w)
    rows = np.abs(np.asarray(gw)).sum(axis=1)
    n_nonzero = int((rows > 0).sum())
    # bound: m_local per (model shard x data row) = 10 * 4 * 2
    assert 0 < n_nonzero <= 80
    # and far fewer than N rows are touched (the paper's sparse-update win)
    assert n_nonzero < 0.75 * w.shape[0]
