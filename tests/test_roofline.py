"""Roofline analysis layer: record analysis, MODEL_FLOPS, report rendering,
hillclimb knob parsing, mesh/parallel-config factories."""
import jax
import jax.numpy as jnp
import pytest

from benchmarks.hillclimb import parse_rules
from repro.configs.base import ParallelConfig, get_model_config
from repro.launch.mesh import make_host_parallel_config, make_parallel_config
from repro.roofline import analysis as an


def _fake_record(flops=1e12, nbytes=1e9, coll=1e7, mesh="16x16"):
    return {
        "arch": "smollm_135m", "shape": "train_4k", "mesh": mesh,
        "mode": "train", "knn": False, "n_params": 135_000_000,
        "memory": {"argument_bytes": 2 << 30, "output_bytes": 1 << 30,
                   "temp_bytes": 4 << 30, "peak_bytes": 5 << 30},
        "cost": {"flops": 1.0, "bytes_accessed": 1.0},
        "hlo": {"flops": flops, "bytes": nbytes},
        "collectives": {"total_bytes": coll},
    }


def test_analyze_record_terms():
    row = an.analyze_record(_fake_record())
    assert row.compute_s == pytest.approx(1e12 / an.PEAK_FLOPS)
    assert row.memory_s == pytest.approx(1e9 / an.HBM_BW)
    assert row.collective_s == pytest.approx(1e7 / an.ICI_BW)
    assert row.dominant == "compute"
    assert row.n_chips == 256
    assert row.fits  # 2 + 5 GiB < 16


def test_analyze_record_dominance_switch():
    row = an.analyze_record(_fake_record(flops=1.0, nbytes=1e14))
    assert row.dominant == "memory"
    row = an.analyze_record(_fake_record(flops=1.0, coll=1e13))
    assert row.dominant == "collective"


def test_analyze_record_skips_errors():
    assert an.analyze_record({"error": "boom"}) is None


def test_model_flops_regimes():
    cfg = get_model_config("smollm_135m")
    train = an.model_flops(cfg, "train_4k")
    prefill = an.model_flops(cfg, "prefill_32k")
    decode = an.model_flops(cfg, "decode_32k")
    # train >= 3x prefill-per-token (bwd) and decode << both
    assert train > 0 and prefill > 0 and decode > 0
    assert decode < prefill < train * 2
    # 6ND lower bound for train
    assert train >= 6 * 1.2e8 * 256 * 4096


def test_moe_active_params_lt_total():
    cfg = get_model_config("qwen3_moe_30b_a3b")
    import jax as _j

    from repro.models import lm
    sds = _j.eval_shape(lambda: lm.init_model(_j.random.PRNGKey(0), cfg))
    total = sum(l.size for l in _j.tree.leaves(sds))
    active = an.active_params(cfg)
    assert active < 0.3 * total  # top-8 of 128 experts


def test_markdown_render_and_hillclimb_mark():
    rows = [an.analyze_record(_fake_record())]
    md = an.to_markdown(rows, hillclimbed={("smollm_135m", "train_4k")})
    assert "**(hillclimbed)**" in md
    assert md.count("|") > 10


def test_parse_rules():
    assert parse_rules(["seq=model"]) == (("seq", "model"),)
    assert parse_rules(["vocab=data,model"]) == (("vocab", ("data", "model")),)
    assert parse_rules(["embed=none"]) == (("embed", None),)


def test_parallel_config_factories():
    p = make_parallel_config(multi_pod=True)
    assert p.axis_names == ("pod", "data", "model")
    assert p.batch_axes == ("pod", "data")
    assert p.mesh_axis_for_param("embed") == "data"   # FSDP
    assert p.mesh_axis_for("embed") is None           # activations unchanged
    p2 = make_parallel_config(fsdp=False)
    assert p2.param_rules is None
    ph = make_host_parallel_config(2, 4)
    assert ph.mesh_shape == (2, 4)


def test_rule_precedence_first_match_wins():
    p = ParallelConfig(mesh_shape=(2, 4), axis_names=("data", "model"),
                       rules=(("seq", "model"), ("seq", None)))
    assert p.mesh_axis_for("seq") == "model"
