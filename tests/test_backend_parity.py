"""ref-vs-pallas backend parity: every registry head must produce the same
loss, gradients, and predictions on either compute backend (fp32 tolerance),
and the fused kernels must grad-check against dense autodiff oracles.

The Pallas kernels run in interpret mode on this CPU container; the grid /
blocking / masking logic is identical to the TPU lowering, so parity here
gates the routed path end-to-end (kernels -> core bodies -> head strategies
-> hybrid trainer).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api.heads import make_head
from repro.configs.base import HeadConfig, ModelConfig, TrainConfig
from repro.data.synthetic import ClassificationStream, sku_feature_batch
from repro.kernels import ops
from repro.train import hybrid

ALL_HEADS = ["full", "knn", "selective", "mach", "sampled", "csoft"]

N, D, B = 512, 32, 32


def _head_cfg(impl, backend):
    return HeadConfig(softmax_impl=impl, backend=backend, knn_k=8,
                      knn_kprime=16, active_frac=0.2, sampled_n=128,
                      mach_b=32, csoft_b=32)


@pytest.fixture(scope="module")
def feats_cfg():
    return ModelConfig(name="parity", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       dtype="float32")


@pytest.fixture(scope="module")
def batch():
    return sku_feature_batch(0, B, ClassificationStream(N, D, seed=0))


def _one_step(mcfg, hcfg, mesh, inputs):
    """One hybrid-trainer SGD step + eval: returns (loss, metrics, new head
    params, eval accuracy)."""
    tcfg = TrainConfig(optimizer="sgd")
    head = make_head(mcfg, hcfg)
    with jax.set_mesh(mesh):
        state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg,
                                  8, head=head)
        state = hybrid.refresh_head_state(head, mesh, state)
        step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh, head=head,
                                      state_template=state)
        new_state, loss, metrics = step(state, inputs, 1.0)
        ev = hybrid.make_eval_step(mcfg, hcfg, mesh, state, head=head)
        acc = ev(state, inputs)
    return (float(loss), metrics,
            np.asarray(jax.device_get(
                jax.tree.leaves(new_state.head_params)[0])), float(acc))


@pytest.mark.parametrize("impl", ALL_HEADS)
def test_head_backend_parity(impl, feats_cfg, batch, mesh8):
    """Loss, post-step head weights (== gradients through SGD), train
    accuracy, and deploy-style eval accuracy all match across backends."""
    ref = _one_step(feats_cfg, _head_cfg(impl, "ref"), mesh8, batch)
    pal = _one_step(feats_cfg, _head_cfg(impl, "pallas"), mesh8, batch)
    assert abs(ref[0] - pal[0]) < 1e-5, f"{impl}: loss diverged"
    np.testing.assert_allclose(pal[2], ref[2], rtol=1e-5, atol=1e-5,
                               err_msg=f"{impl}: head grads diverged")
    assert abs(float(ref[1]["accuracy"]) - float(pal[1]["accuracy"])) < 1e-6
    assert abs(ref[3] - pal[3]) < 1e-6, f"{impl}: eval pred diverged"


def test_full_backend_parity_padded_vocab(batch, mesh8):
    """Megatron-style vocab padding: the pallas limit masking must agree
    with the ref NEG_INF masking (N=500 real classes padded to 512)."""
    mcfg = ModelConfig(name="pad", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=N,
                       real_vocab_size=500, dtype="float32")
    inputs = {"features": batch["features"],
              "labels": jnp.minimum(batch["labels"], 499)}
    ref = _one_step(mcfg, _head_cfg("full", "ref"), mesh8, inputs)
    pal = _one_step(mcfg, _head_cfg("full", "pallas"), mesh8, inputs)
    assert abs(ref[0] - pal[0]) < 1e-5
    np.testing.assert_allclose(pal[2], ref[2], rtol=1e-5, atol=1e-5)


def test_sampled_log_uniform_backend_parity(feats_cfg, batch, mesh8):
    """The Zipfian sampler's in-kernel accidental-hit masking + logQ bias
    must match the ref concat-and-mask formulation."""
    mesh = mesh8
    cfgs = [dataclasses.replace(_head_cfg("sampled", be),
                                sampled_dist="log_uniform")
            for be in ("ref", "pallas")]
    ref = _one_step(feats_cfg, cfgs[0], mesh, batch)
    pal = _one_step(feats_cfg, cfgs[1], mesh, batch)
    assert abs(ref[0] - pal[0]) < 1e-5
    np.testing.assert_allclose(pal[2], ref[2], rtol=1e-5, atol=1e-5)


def test_knn_pallas_graph_build_matches_ref(mesh8):
    """The dist_topk-routed ring graph build returns the same exact KNN
    graph as the einsum ring (both re-ranked fp32)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import knn_graph as kg

    w = jax.random.normal(jax.random.PRNGKey(3), (256, 16), jnp.float32)
    with jax.set_mesh(mesh8):
        ws = jax.device_put(w, NamedSharding(mesh8, P("hybrid", None)))
        g_ref = jax.device_get(kg.build_graph_distributed(
            mesh8, ws, k=8, kprime=16, model_axis="hybrid", backend="ref"))
        g_pal = jax.device_get(kg.build_graph_distributed(
            mesh8, ws, k=8, kprime=16, model_axis="hybrid",
            backend="pallas"))
    # identical candidate sets after fp32 re-rank (row order may tie-break
    # differently only on exact score ties, which the random W avoids)
    assert (np.asarray(g_ref) == np.asarray(g_pal)).all()


def test_dgc_pallas_threshold_matches_ref():
    """DGCConfig.backend='pallas' routes threshold selection through the
    topk_dc kernel and selects the identical top-k mask."""
    from repro.configs.base import DGCConfig
    from repro.core import sparsify as sp

    grads = {"a": jax.random.normal(jax.random.PRNGKey(0), (1000,)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (37, 11))}
    state = sp.init_dgc_state(grads)
    outs = {}
    for backend in ("ref", "pallas"):
        cfg = DGCConfig(enabled=True, sparsity=0.9, chunk=256,
                        backend=backend)
        out, new_state, info = sp.dgc_exchange(grads, state, cfg)
        outs[backend] = (out, info)
    for k in grads:
        np.testing.assert_allclose(np.asarray(outs["ref"][0][k]),
                                   np.asarray(outs["pallas"][0][k]))
    assert float(outs["ref"][1]["wire_bytes"]) == \
        float(outs["pallas"][1]["wire_bytes"])


def test_topk_serve_backend_parity(feats_cfg, batch, mesh8):
    """Top-k retrieval serving: d&c-kernel selection returns the same ids
    and scores as lax.top_k."""
    tcfg = TrainConfig(optimizer="sgd")
    outs = {}
    for backend in ("ref", "pallas"):
        hcfg = _head_cfg("full", backend)
        head = make_head(feats_cfg, hcfg)
        with jax.set_mesh(mesh8):
            state = hybrid.init_state(jax.random.PRNGKey(0), feats_cfg,
                                      hcfg, tcfg, 8, head=head)
            step = hybrid.make_topk_serve_step(feats_cfg, hcfg, mesh8,
                                               state, 7, head=head)
            vals, ids = jax.device_get(step(state, batch))
        outs[backend] = (np.asarray(vals), np.asarray(ids))
    np.testing.assert_allclose(outs["pallas"][0], outs["ref"][0],
                               rtol=1e-6, atol=1e-6)
    assert (outs["ref"][1] == outs["pallas"][1]).all()
    # greedy argmax serve must agree with the top-1 column
    with jax.set_mesh(mesh8):
        hcfg = _head_cfg("full", "pallas")
        head = make_head(feats_cfg, hcfg)
        state = hybrid.init_state(jax.random.PRNGKey(0), feats_cfg, hcfg,
                                  tcfg, 8, head=head)
        serve = hybrid.make_serve_step(feats_cfg, hcfg, mesh8, state,
                                       head=head)
        preds = jax.device_get(serve(state, batch))
    assert (np.asarray(preds) == outs["pallas"][1][:, 0]).all()


def test_topk_serve_rejects_sketch_heads(feats_cfg, mesh8):
    hcfg = _head_cfg("mach", "ref")
    head = make_head(feats_cfg, hcfg)
    tcfg = TrainConfig(optimizer="sgd")
    with jax.set_mesh(mesh8):
        state = hybrid.init_state(jax.random.PRNGKey(0), feats_cfg, hcfg,
                                  tcfg, 8, head=head)
    with pytest.raises(NotImplementedError):
        hybrid.make_topk_serve_step(feats_cfg, hcfg, mesh8, state, 5,
                                    head=head)


def test_backend_config_validation():
    with pytest.raises(ValueError):
        HeadConfig(backend="cuda")
    from repro.configs.base import DGCConfig
    with pytest.raises(ValueError):
        DGCConfig(backend="triton")
