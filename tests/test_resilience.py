"""Fault-injection + resumable-training tests (docs/resilience.md).

The contract: a run killed mid-training and resumed by a FRESH trainer from
its latest full-state checkpoint is step-for-step equivalent to a run that
was never interrupted. Every head on the hybrid trainer (plus
full/knn/sampled/csoft on the zoo) recovers BITWISE on this container —
the data stream, FCCS schedule, and per-step sampling are pure functions
of the saved cursor, and XLA CPU execution is run-to-run deterministic.
``EQUIVALENCE`` below is the asserted class per head × backend; if a
future path loses determinism it must be downgraded HERE and in
docs/resilience.md, not silently.

Injection points exercised:
  * mid-epoch — kill between checkpoints; work since the last snapshot is
    lost and replayed from the restored cursor;
  * mid-refresh-interval — the knn/selective snapshot carries aux (graph /
    LSH tables) that is STALE relative to the params, exactly as the
    killed run's was; restore must not rebuild it;
  * post-DGC-accumulation — error-feedback residuals u/v are mid-flight
    and ride the snapshot;
  * straggler delay — numerics must be untouched; only wall-clock moves.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt_lib
from repro.api import Experiment
from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                TrainConfig)
from repro.resilience import (FaultPlan, SimulatedFault, fault_hook,
                              kill_and_recover, tree_compare)

# the asserted recovery class per (head, backend) — see module docstring
EQUIVALENCE = {
    ("full", "ref"): "bitwise",
    ("knn", "ref"): "bitwise",
    ("selective", "ref"): "bitwise",
    ("mach", "ref"): "bitwise",
    ("sampled", "ref"): "bitwise",
    ("csoft", "ref"): "bitwise",
    ("full", "pallas"): "bitwise",
    ("knn", "pallas"): "bitwise",
}

ZOO_EQUIVALENCE = {
    "full": "bitwise", "knn": "bitwise",
    "sampled": "bitwise", "csoft": "bitwise",
}


def _head_cfg(head: str, backend: str = "ref") -> HeadConfig:
    # rebuild_every=5 with ckpt_every=4 and kill_at=6 puts the kill
    # mid-refresh-interval for knn/selective: the restored snapshot (step
    # 4) carries the PRE-refresh aux, and the refresh after replayed step 4
    # must rebuild the identical graph the killed run built.
    return HeadConfig(softmax_impl=head, backend=backend, knn_k=8,
                      knn_kprime=16, active_frac=0.25, rebuild_every=5,
                      sampled_n=64, mach_b=64, mach_r=2, csoft_b=64,
                      csoft_r=2)


def _paper_factory(tmp_path, head: str, backend: str = "ref",
                   dgc: bool = False, seed: int = 0):
    hcfg = _head_cfg(head, backend)
    tcfg = TrainConfig(
        optimizer="sgd",
        fccs=FCCSConfig(eta0=0.5, t_warm=2, b0=16, b_min=16, b_max=64,
                        t_ini=2, t_final=8),
        dgc=DGCConfig(enabled=dgc, sparsity=0.95, chunk=512))

    def make_exp(ckpt_dir):
        return Experiment.from_config(
            system="paper", classes=256, feat_dim=32, batch=16, head=hcfg,
            train=tcfg, ckpt_dir=ckpt_dir, ckpt_every=4, log_every=0,
            seed=seed)
    return make_exp


def _zoo_factory(tmp_path, head: str):
    hcfg = _head_cfg(head)

    def make_exp(ckpt_dir):
        return Experiment.from_config(
            system="zoo", arch="smollm_135m", reduced=True, head=hcfg,
            batch=8, seq=16, ckpt_dir=ckpt_dir, ckpt_every=2, log_every=0)
    return make_exp


# ---------------------------------------------------------------------------
# the headline matrix: kill mid-run, restore, assert equivalence class
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("head,backend", sorted(EQUIVALENCE))
def test_paper_kill_and_recover(head, backend, tmp_path, mesh8):
    make_exp = _paper_factory(tmp_path, head, backend)
    rep = kill_and_recover(
        make_exp, total_steps=8, kill_at=6, ckpt_dir=str(tmp_path / "ck"),
        equivalence=EQUIVALENCE[(head, backend)], head=f"{head}/{backend}",
        fit_kw={"use_fccs_batch": False})
    # kill at 6 with snapshots every 4: two steps of work lost and replayed
    assert rep.restored_step == 4 and rep.steps_replayed == 2
    assert rep.ok, rep.summary()


@pytest.mark.parametrize("head", sorted(ZOO_EQUIVALENCE))
def test_zoo_kill_and_recover(head, tmp_path):
    make_exp = _zoo_factory(tmp_path, head)
    rep = kill_and_recover(
        make_exp, total_steps=6, kill_at=5, ckpt_dir=str(tmp_path / "ck"),
        equivalence=ZOO_EQUIVALENCE[head], head=f"zoo/{head}",
        fit_kw={"lr": 0.5})
    assert rep.restored_step == 4 and rep.steps_replayed == 1
    assert rep.ok, rep.summary()


# ---------------------------------------------------------------------------
# injection-point specifics
# ---------------------------------------------------------------------------


def test_paper_kill_post_dgc_accumulation(tmp_path, mesh8):
    """DGC error-feedback residuals are mid-flight at the kill: they must
    ride the snapshot or the resumed gradient exchange diverges."""
    make_exp = _paper_factory(tmp_path, "full", dgc=True)
    rep = kill_and_recover(
        make_exp, total_steps=8, kill_at=6, ckpt_dir=str(tmp_path / "ck"),
        head="full+dgc", fit_kw={"use_fccs_batch": False})
    assert rep.ok, rep.summary()
    # the snapshot really carries the error-feedback buffers
    exp = make_exp(str(tmp_path / "ck"))
    tree = exp.trainer._snapshot()
    assert "dgc" in tree and set(tree["dgc"]) == {"u", "v"}


def test_paper_kill_mid_fccs_ramp(tmp_path, mesh8):
    """FCCS batch growth: the kill lands inside the cosine ramp, so the
    resumed run must pick up the SAME accumulation factor / batch size
    schedule from the cursor (a restart-from-zero would re-warm the LR and
    shrink the batch)."""
    make_exp = _paper_factory(tmp_path, "full")
    rep = kill_and_recover(
        make_exp, total_steps=8, kill_at=6, ckpt_dir=str(tmp_path / "ck"),
        head="full+fccs", fit_kw={"use_fccs_batch": True})
    assert rep.ok, rep.summary()
    # batch actually grew across the ramp in both runs
    batches = [r["batch"] for r in rep.reference_history]
    assert batches[-1] > batches[0]
    resumed = {r["step"]: r["batch"] for r in rep.resumed_history}
    for r in rep.reference_history:
        if r["step"] in resumed:
            assert resumed[r["step"]] == r["batch"]


def test_paper_delay_fault_is_numerically_invisible(tmp_path, mesh8):
    """A straggler delay must not perturb the trajectory — only time."""
    make_exp = _paper_factory(tmp_path, "full")
    ref = make_exp(None)
    ref.fit(4, use_fccs_batch=False)

    slept = []
    slow = make_exp(None)
    hook = fault_hook(FaultPlan(delay_at=2, delay_s=123.0),
                      sleep=slept.append)
    slow.fit(4, use_fccs_batch=False, step_hook=hook)
    assert slept == [123.0]
    cmp = tree_compare(slow.trainer._snapshot(), ref.trainer._snapshot())
    assert cmp["bitwise"], cmp["mismatches"]


# ---------------------------------------------------------------------------
# plumbing: facade resume, hook semantics, snapshot contract
# ---------------------------------------------------------------------------


def test_fit_resume_true_runs_only_the_tail(tmp_path, mesh8):
    make_exp = _paper_factory(tmp_path, "full")
    victim = make_exp(str(tmp_path / "ck"))
    with pytest.raises(SimulatedFault):
        victim.fit(8, use_fccs_batch=False,
                   step_hook=fault_hook(FaultPlan(kill_at=6)))

    resumed = make_exp(str(tmp_path / "ck"))
    hist = resumed.fit(8, use_fccs_batch=False, resume=True)
    # restored at 4 -> only steps 4..7 ran in this "process"
    assert [r["step"] for r in hist] == [4, 5, 6, 7]
    assert resumed.trainer._t == 8 and int(resumed.trainer.state.step) == 8
    # idempotent relaunch: target already reached -> no extra steps
    again = make_exp(str(tmp_path / "ck"))
    assert again.fit(8, use_fccs_batch=False, resume=True) == []


def test_fit_resume_without_checkpoint_is_cold_start(tmp_path, mesh8):
    make_exp = _paper_factory(tmp_path, "full")
    exp = make_exp(str(tmp_path / "empty"))
    hist = exp.fit(3, use_fccs_batch=False, resume=True)
    assert [r["step"] for r in hist] == [0, 1, 2]


def test_restore_without_ckpt_dir_raises(mesh8, tmp_path):
    exp = _paper_factory(tmp_path, "full")(None)
    with pytest.raises(ValueError, match="ckpt_dir"):
        exp.restore()


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="injects nothing"):
        FaultPlan()
    with pytest.raises(ValueError, match="delay_s"):
        FaultPlan(delay_at=1, delay_s=-1.0)
    with pytest.raises(ValueError, match="kill_at"):
        kill_and_recover(lambda d: None, total_steps=4, kill_at=0,
                         ckpt_dir="x")
    with pytest.raises(ValueError, match="equivalence"):
        kill_and_recover(lambda d: None, total_steps=4, kill_at=2,
                         ckpt_dir="x", equivalence="vibes")


def test_snapshot_contract_covers_head_aux(tmp_path, mesh8):
    """The checkpoint must include head-owned aux (the MACH lesson: sketch
    state is part of the model) — here the knn graph: restoring into a
    fresh trainer yields the SAME aux arrays even though the fresh
    trainer's warm-start graph has different shapes."""
    make_exp = _paper_factory(tmp_path, "knn")
    exp = make_exp(str(tmp_path / "ck"))
    exp.fit(6, use_fccs_batch=False)        # refresh fired at step 5
    exp.trainer.save_checkpoint()
    aux_before = [np.asarray(a) for a in exp.state.head_aux]

    fresh = make_exp(str(tmp_path / "ck"))
    fresh.restore()
    for a, b in zip([np.asarray(x) for x in fresh.state.head_aux],
                    aux_before):
        np.testing.assert_array_equal(a, b)
    assert fresh.trainer._t == 6


def test_step_hook_fires_before_the_step(tmp_path, mesh8):
    """Kill before step k leaves the state exactly at step k's entry: k
    steps taken, cursor k."""
    exp = _paper_factory(tmp_path, "full")(None)
    with pytest.raises(SimulatedFault):
        exp.fit(8, use_fccs_batch=False,
                step_hook=fault_hook(FaultPlan(kill_at=3)))
    assert exp.trainer._t == 3 and int(exp.trainer.state.step) == 3
    assert len(exp.trainer.history) == 3


# ---------------------------------------------------------------------------
# checkpoint layer: atomicity + retention
# ---------------------------------------------------------------------------


def test_checkpoint_write_is_atomic(tmp_path):
    import os
    path = str(tmp_path / "ck")
    ckpt_lib.save(path, {"x": jnp.arange(4.0)}, step=1)
    assert sorted(os.listdir(path)) == ["ckpt_1.msgpack.zst"]
    # overwrite same step: replaced, never duplicated / truncated
    ckpt_lib.save(path, {"x": jnp.arange(4.0) * 2}, step=1)
    tree, _ = ckpt_lib.restore(path, {"x": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(tree["x"]),
                                  [0.0, 2.0, 4.0, 6.0])
    assert not [f for f in os.listdir(path) if ".tmp" in f]


def test_checkpoint_retention_prunes_oldest_first(tmp_path):
    path = str(tmp_path / "ck")
    for s in (1, 5, 3, 9, 7):
        ckpt_lib.save(path, {"x": jnp.asarray(float(s))}, step=s, keep=3)
    assert ckpt_lib.all_steps(path) == [5, 7, 9]
    assert ckpt_lib.latest_step(path) == 9
    # prune() reports the doomed steps oldest-first
    ckpt_lib.save(path, {"x": jnp.asarray(0.0)}, step=11)
    assert ckpt_lib.prune(path, keep=2) == [5, 7]
    assert ckpt_lib.all_steps(path) == [9, 11]
    with pytest.raises(ValueError, match="keep"):
        ckpt_lib.prune(path, keep=0)


def test_checkpoint_keep_never_prunes_the_new_file(tmp_path):
    path = str(tmp_path / "ck")
    for s in range(6):
        ckpt_lib.save(path, {"x": jnp.asarray(float(s))}, step=s, keep=1)
        assert ckpt_lib.all_steps(path) == [s]


# ---------------------------------------------------------------------------
# compression-format compatibility (the hypothesis round-trip property test
# lives in tests/test_property.py; these regressions run without hypothesis)
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_mixed_dtypes_and_namedtuples(tmp_path):
    from repro.optim.optimizers import OptState
    tree = {
        "f32": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "f16": jnp.asarray([1.5, -2.25], jnp.float16),
        "i8": jnp.asarray([[-128, 127]], jnp.int8),
        "bool": jnp.asarray([True, False]),
        "empty": jnp.zeros((0, 4), jnp.float32),
        "scalar": jnp.asarray(7, jnp.int32),
        "opt": OptState(step=jnp.asarray(3, jnp.int32),
                        mu=({"w": jnp.ones((2,))}, ()), nu=None),
        "nested": [(), {"deep": (jnp.asarray(0.5),)}],
    }
    ckpt_lib.save(str(tmp_path), tree, step=9)
    out, step = ckpt_lib.restore(str(tmp_path), tree)
    assert step == 9
    fa = jax.tree_util.tree_flatten_with_path(tree)
    fb = jax.tree_util.tree_flatten_with_path(out)
    assert fa[1] == fb[1]
    for (pa, a), (_, b) in zip(fa[0], fb[0]):
        a, b = np.asarray(a), np.asarray(jax.device_get(b))
        assert a.dtype == b.dtype and a.shape == b.shape, pa
        assert a.tobytes() == b.tobytes(), pa


def test_zlib_written_checkpoint_restores_under_either_codec(tmp_path,
                                                             monkeypatch):
    """Cross-restore: a zlib-written file (container without the zstandard
    wheel) must restore whether or not zstandard is importable at read
    time — the ``_ZSTD_MAGIC`` sniff routes it to zlib either way."""
    from repro.checkpoint import checkpoint as mod
    tree = {"x": jnp.arange(8.0)}
    monkeypatch.setattr(mod, "zstandard", None)    # force the zlib writer
    fname = ckpt_lib.save(str(tmp_path), tree, step=1)
    blob = open(fname, "rb").read()
    assert blob[:4] != mod._ZSTD_MAGIC
    monkeypatch.undo()                              # whatever the env has
    out, _ = ckpt_lib.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(8.0))


@pytest.mark.skipif(
    __import__("repro.checkpoint.checkpoint",
               fromlist=["zstandard"]).zstandard is None,
    reason="zstandard wheel not installed")
def test_zstd_written_checkpoint_roundtrips(tmp_path):
    from repro.checkpoint import checkpoint as mod
    tree = {"x": jnp.arange(8.0)}
    fname = ckpt_lib.save(str(tmp_path), tree, step=1)
    assert open(fname, "rb").read()[:4] == mod._ZSTD_MAGIC
    out, _ = ckpt_lib.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(8.0))


def test_zstd_checkpoint_without_zstandard_errors_clearly(tmp_path,
                                                          monkeypatch):
    """A zstd frame on a zlib-only container must fail loudly naming the
    missing module — not with an opaque zlib decode error."""
    from repro.checkpoint import checkpoint as mod
    (tmp_path / "ckpt_5.msgpack.zst").write_bytes(
        mod._ZSTD_MAGIC + b"\x00" * 16)
    monkeypatch.setattr(mod, "zstandard", None)
    with pytest.raises(RuntimeError, match="zstandard"):
        ckpt_lib.restore(str(tmp_path), {"x": jnp.zeros(1)})
