"""Pallas flash-attention kernel vs jnp oracle (shape/flag sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention


def _oracle(q, k, v, causal, window):
    bh, s, dh = q.shape
    t = k.shape[1]
    sc = jnp.einsum("bsd,btd->bst", q, k) / (dh ** 0.5)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    valid = jnp.ones((s, t), bool)
    if causal:
        valid &= kp <= qp
    if window:
        valid &= kp > qp - window
    sc = jnp.where(valid[None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    p = jnp.where(jnp.any(valid, -1)[None, :, None], p, 0.0)
    return jnp.einsum("bst,btd->bsd", p, v)


@pytest.mark.parametrize("bh,s,t,dh,causal,window,bq,bkv", [
    (4, 256, 256, 64, True, 0, 128, 128),
    (2, 200, 300, 32, False, 0, 64, 128),   # ragged + padding
    (3, 256, 256, 64, True, 100, 64, 64),   # sliding window
    (1, 512, 512, 128, True, 0, 128, 256),
])
def test_flash_matches_oracle(bh, s, t, dh, causal, window, bq, bkv):
    key = jax.random.PRNGKey(bh * s + t)
    q = jax.random.normal(key, (bh, s, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (bh, t, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (bh, t, dh))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_kv=bkv)
    ref = _oracle(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (2, 128, 64)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 128, 64)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 128, 64)).astype(dtype)
    out = flash_attention(q, k, v, block_q=64, block_kv=64)
    ref = _oracle(q.astype(jnp.float32), k.astype(jnp.float32),
                  v.astype(jnp.float32), True, 0)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=3e-2 if dtype == jnp.bfloat16 else 2e-5)
