"""Elastic resharding tests (repro.elastic; docs/resilience.md).

The contract: a full-state checkpoint written on an ``n``-shard mesh
restores onto an ``m``-shard mesh (shrink, grow, and non-divisible) with

  * dense heads (full / knn / selective / sampled) — the GLOBAL ``[V, D]``
    class-weight rows, FE params, and optimizer moments bit-identical, and
    deploy-style top-k ids AND scores bit-identical to the source run
    (per-row local dot products merged over the ring — no cross-shard
    float reduction, so the mesh size cannot perturb them);
  * knn / selective aux — the per-shard CSRs re-pack EXACTLY (the graph /
    tables are preserved mid-refresh-interval stale, as stored), and
    n->m->n round-trips to bitwise identity;
  * sketch heads (mach / csoft) — bucket weights and hash tables kept
    verbatim while the stored bucket count divides the dst ring (bitwise
    decode equivalence); otherwise re-bucketed with the same universal
    hash family at the new modulus (the one lossy case);
  * DGC error feedback — redistributed mass-preservingly;
  * a mismatched restore without ``reshard`` (or with a different class
    count at all) raises ``ReshardError`` up front.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import Experiment
from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                TrainConfig)
from repro.core import baselines as bl
from repro.elastic import (MeshGeometry, ReshardError, decompress_graph,
                           geometry_from_meta, lsh_bucket_map, plan_reshard,
                           place_row_sharded, resize_vocab_rows,
                           validate_geometry)
from repro.resilience import elastic_kill_and_recover, tree_compare
from repro.train import hybrid

# V=240 divides every ring size used here (8, 4, 3, 6, 2); 8->4 / 4->8 are
# the aligned shrink/grow legs and 8->3 the non-divisible (chunked) leg.
V, D, B = 240, 16, 24

DENSE = ["full", "knn", "selective", "sampled"]
SKETCH = ["mach", "csoft"]


def _hcfg(head, backend="ref"):
    # rebuild_every=5 with 8 training steps leaves the knn/selective aux
    # refreshed at step 5 and STALE at the step-8 snapshot — the re-pack
    # must preserve exactly that staleness
    return HeadConfig(softmax_impl=head, backend=backend, knn_k=8,
                      knn_kprime=16, active_frac=0.25, rebuild_every=5,
                      sampled_n=64, mach_b=64, mach_r=2, csoft_b=64,
                      csoft_r=2)


def _make(head, n_dev, ckpt_dir, *, dgc=False, seed=0):
    tcfg = TrainConfig(
        optimizer="sgd",
        fccs=FCCSConfig(eta0=0.5, t_warm=2, b0=B, b_min=B, b_max=2 * B,
                        t_ini=2, t_final=8),
        dgc=DGCConfig(enabled=dgc, sparsity=0.95, chunk=512))
    return Experiment.from_config(
        system="paper", classes=V, feat_dim=D, batch=B, head=_hcfg(head),
        train=tcfg, mesh=hybrid.make_hybrid_mesh(n_dev),
        ckpt_dir=ckpt_dir, ckpt_every=4, log_every=0, seed=seed)


def _np(a):
    return np.asarray(jax.device_get(a))


def _train_src(head, ckpt_dir, n_dev=8, **kw):
    src = _make(head, n_dev, ckpt_dir, **kw)
    src.fit(8, use_fccs_batch=False)
    return src


# ---------------------------------------------------------------------------
# plan geometry (host-side, jax-free)
# ---------------------------------------------------------------------------


def test_plan_aligned_shrink():
    p = plan_reshard(MeshGeometry(8, n_classes=V), MeshGeometry(4))
    assert p.aligned and p.n_rows == V
    assert sum(t.rows for t in p.transfers) == V
    # dst shard q owns src shards {2q, 2q+1}; only shard 0's first block
    # stays put -> 240 - 30 = 210 displaced rows
    assert p.moved_rows == 210
    assert p.bytes_moved(row_bytes=D * 4) == 210 * D * 4


def test_plan_aligned_grow():
    p = plan_reshard(MeshGeometry(4, n_classes=V), MeshGeometry(8))
    assert p.aligned and sum(t.rows for t in p.transfers) == V


def test_plan_unaligned():
    p = plan_reshard(MeshGeometry(8, n_classes=V), MeshGeometry(3))
    assert not p.aligned
    assert sum(t.rows for t in p.transfers) == V
    assert 0 < p.moved_rows <= V
    # every transfer is a contiguous interval inside one src and one dst
    # block
    for t in p.transfers:
        assert t.start // (V // 8) == (t.stop - 1) // (V // 8) == t.src_shard
        assert t.start // (V // 3) == (t.stop - 1) // (V // 3) == t.dst_shard


def test_plan_identity_moves_nothing():
    p = plan_reshard(MeshGeometry(8, n_classes=V), MeshGeometry(8))
    assert p.aligned and p.moved_rows == 0


def test_plan_rejects_non_divisible():
    with pytest.raises(ReshardError, match="not divisible"):
        plan_reshard(MeshGeometry(8, n_classes=V), MeshGeometry(7))


def test_validate_geometry():
    a = MeshGeometry(8, 8, V)
    b = MeshGeometry(4, 4, V)
    validate_geometry(a, a)
    with pytest.raises(ReshardError, match="reshard"):
        validate_geometry(a, b)
    validate_geometry(a, b, reshard=True)
    with pytest.raises(ReshardError, match="classes"):
        validate_geometry(MeshGeometry(8, 8, 2 * V), a, reshard=True)
    # pre-elastic checkpoints carry no geometry meta -> caller's own
    assert geometry_from_meta(None, b) == b
    assert geometry_from_meta({"n_model": 8, "n_data": 8,
                               "n_classes": V}, b) == a


# ---------------------------------------------------------------------------
# host-side transforms
# ---------------------------------------------------------------------------


def test_place_row_sharded_unaligned():
    mesh = hybrid.make_hybrid_mesh(3)
    host = np.arange(V * D, dtype=np.float32).reshape(V, D)
    plan = plan_reshard(MeshGeometry(8, n_classes=V), MeshGeometry(3))
    out = place_row_sharded(host, mesh, hybrid.AXIS, plan,
                            max_stage_rows=7)   # force many chunks
    np.testing.assert_array_equal(_np(out), host)
    for q, sh in enumerate(out.addressable_shards):
        np.testing.assert_array_equal(
            _np(sh.data), host[q * (V // 3):(q + 1) * (V // 3)])


def test_decompress_graph_roundtrip():
    from repro.core import knn_graph as kg
    rng = np.random.default_rng(0)
    g = rng.integers(0, 16, (16, 3)).astype(np.int32)
    cg = kg.compress_graph(g, 4)
    back = decompress_graph(cg.offsets, cg.neighbors, cg.ranks)
    np.testing.assert_array_equal(back, g)


def test_resize_vocab_rows():
    a = np.arange(12, dtype=np.float32).reshape(6, 2)
    grown = resize_vocab_rows(a, 6, 8, n_real=5)
    assert grown.shape == (8, 2)
    np.testing.assert_array_equal(grown[:6], a)
    assert (grown[6:] == 0).all()
    np.testing.assert_array_equal(resize_vocab_rows(grown, 8, 6, n_real=5),
                                  a)
    with pytest.raises(ReshardError, match="real"):
        resize_vocab_rows(a, 6, 4, n_real=5)
    # non-vocab-leading leaves pass through untouched
    np.testing.assert_array_equal(resize_vocab_rows(a, 7, 9, n_real=5), a)


# ---------------------------------------------------------------------------
# the dense matrix: every dense head restores n->m bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("src_n,dst_n", [(8, 4), (4, 8), (8, 3)])
@pytest.mark.parametrize("head", DENSE)
def test_dense_elastic_restore(head, src_n, dst_n, tmp_path):
    ck = str(tmp_path / "ck")
    src = _train_src(head, ck, n_dev=src_n)
    dst = _make(head, dst_n, ck)
    assert dst.restore(reshard=True) == 8
    assert dst.trainer._t == 8

    a, b = src.trainer._snapshot(), dst.trainer._snapshot()
    # global [V, D] class rows, FE params, and BOTH moment mirrors are
    # bit-identical — the reshard is pure re-placement for dense heads
    np.testing.assert_array_equal(_np(a["head"]["params"]),
                                  _np(b["head"]["params"]))
    cmp = tree_compare({"fe": a["fe"], "opt": a["opt"]},
                       {"fe": b["fe"], "opt": b["opt"]})
    assert cmp["bitwise"], cmp["mismatches"]

    # aux shapes bake in the ring size, but the graph/tables they encode
    # must be preserved exactly (mid-refresh staleness included)
    if head == "knn":
        np.testing.assert_array_equal(
            decompress_graph(*a["head"]["aux"]),
            decompress_graph(*b["head"]["aux"]))
    if head == "selective":
        np.testing.assert_array_equal(_np(a["head"]["aux"][0]),
                                      _np(b["head"]["aux"][0]))
        np.testing.assert_array_equal(
            lsh_bucket_map(a["head"]["aux"][1], a["head"]["aux"][2]),
            lsh_bucket_map(b["head"]["aux"][1], b["head"]["aux"][2]))

    # deploy-style retrieval is bitwise across mesh sizes: per-row local
    # dots merged by gather, never reduced across shards
    inputs = src.data_fn(10**6, B)
    ids_a, sc_a = src.serve(inputs, top_k=5, return_scores=True)
    ids_b, sc_b = dst.serve(inputs, top_k=5, return_scores=True)
    np.testing.assert_array_equal(_np(ids_a), _np(ids_b))
    np.testing.assert_array_equal(_np(sc_a), _np(sc_b))


@pytest.mark.parametrize("head,mid_n", [("full", 4), ("full", 3),
                                        ("knn", 4), ("selective", 4),
                                        ("mach", 4)])
def test_roundtrip_identity(head, mid_n, tmp_path):
    """n -> m -> n restores the ORIGINAL snapshot bit-for-bit (mach rides
    the keep-verbatim leg: B=64 still divides mid_n=4)."""
    ck_a, ck_b = str(tmp_path / "a"), str(tmp_path / "b")
    src = _train_src(head, ck_a)
    mid = _make(head, mid_n, ck_a)
    assert mid.restore(reshard=True) == 8
    mid.trainer.ckpt_dir = ck_b
    mid.trainer.save_checkpoint()
    back = _make(head, 8, ck_b)
    assert back.restore(reshard=True) == 8
    cmp = tree_compare(src.trainer._snapshot(), back.trainer._snapshot())
    assert cmp["bitwise"], cmp["mismatches"]


# ---------------------------------------------------------------------------
# sketch heads: keep-verbatim vs re-bucket
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("head", SKETCH)
def test_sketch_keep_verbatim(head, tmp_path):
    """B=64 divides the dst ring of 4: buckets, hashes, and moments are
    kept verbatim — bitwise decode equivalence."""
    ck = str(tmp_path / "ck")
    src = _train_src(head, ck)
    dst = _make(head, 4, ck)
    assert dst.restore(reshard=True) == 8
    a, b = src.trainer._snapshot(), dst.trainer._snapshot()
    cmp = tree_compare({k: a[k] for k in ("fe", "head", "opt")},
                       {k: b[k] for k in ("fe", "head", "opt")})
    assert cmp["bitwise"], cmp["mismatches"]
    inputs = src.data_fn(10**6, B)
    np.testing.assert_array_equal(_np(src.serve(inputs)),
                                  _np(dst.serve(inputs)))


@pytest.mark.parametrize("head", SKETCH)
def test_sketch_rebucket(head, tmp_path):
    """B=64 does NOT divide 3: the head re-hashes classes with the SAME
    universal family at B=66 and transfers class-mean bucket weights (the
    documented lossy leg)."""
    ck = str(tmp_path / "ck")
    src = _train_src(head, ck)
    dst = _make(head, 3, ck)
    assert dst.restore(reshard=True) == 8
    a, b = src.trainer._snapshot(), dst.trainer._snapshot()
    w_old, w_new = _np(a["head"]["params"]), _np(b["head"]["params"])
    r = w_old.shape[0]
    assert w_new.shape == (r, 66, D)
    h_old = _np(a["head"]["aux"][0])
    h_new = _np(b["head"]["aux"][0])
    seed = dst.head._hash_seed
    np.testing.assert_array_equal(
        h_new, bl.mach_hashes(V, 66, n_rep=r, seed=seed))
    # every new bucket carries EXACTLY the mean of its member classes' old
    # bucket weights — recomputed here independently with a sequential
    # accumulation in class-id order (np.add.at semantics)
    for rep in range(r):
        for nb in range(66):
            members = np.where(h_new[rep] == nb)[0]
            if not members.size:
                assert (w_new[rep, nb] == 0).all()
                continue
            acc = np.zeros(D, np.float32)
            for j in members:
                acc = acc + w_old[rep, h_old[rep][j]]
            expect = (acc.astype(np.float64)
                      / members.size).astype(np.float32)
            np.testing.assert_array_equal(w_new[rep, nb], expect)
    # moments got the identical transfer
    mu_hp = _np(b["opt"].mu[1])
    assert mu_hp.shape == (r, 66, D)
    # the resharded run keeps training (shapes re-trace cleanly)
    dst.fit(10, use_fccs_batch=False)
    assert np.isfinite(dst.trainer.history[-1]["loss"])


# ---------------------------------------------------------------------------
# DGC error feedback
# ---------------------------------------------------------------------------


def test_dgc_mass_preserved(tmp_path):
    """8 -> 4 workers: per-parameter total pending residual is preserved
    exactly (power-of-two split — the f32 sums are associatively exact)."""
    ck = str(tmp_path / "ck")
    src = _train_src("full", ck, dgc=True)
    dst = _make("full", 4, ck, dgc=True)
    assert dst.restore(reshard=True) == 8
    for leafname in ("u", "v"):
        for la, lb in zip(
                jax.tree.leaves(src.trainer._snapshot()["dgc"][leafname]),
                jax.tree.leaves(dst.trainer._snapshot()["dgc"][leafname])):
            xa, xb = _np(la), _np(lb)
            assert xb.shape[0] == 4
            np.testing.assert_array_equal(xa.sum(axis=0), xb.sum(axis=0))
    dst.fit(10, use_fccs_batch=False)
    assert np.isfinite(dst.trainer.history[-1]["loss"])


# ---------------------------------------------------------------------------
# validation errors surface up front
# ---------------------------------------------------------------------------


def test_mesh_mismatch_without_reshard_raises(tmp_path):
    ck = str(tmp_path / "ck")
    _train_src("full", ck)
    dst = _make("full", 4, ck)
    with pytest.raises(ReshardError, match="reshard"):
        dst.restore()


def test_class_count_mismatch_raises(tmp_path):
    ck = str(tmp_path / "ck")
    _train_src("full", ck)
    bad = Experiment.from_config(
        system="paper", classes=2 * V, feat_dim=D, batch=B,
        head=_hcfg("full"), mesh=hybrid.make_hybrid_mesh(8),
        ckpt_dir=ck, ckpt_every=4, log_every=0)
    with pytest.raises(ReshardError, match="classes"):
        bad.restore(reshard=True)


# ---------------------------------------------------------------------------
# telemetry: the restore span grows a reshard child + bytes counter
# ---------------------------------------------------------------------------


def test_reshard_telemetry(tmp_path):
    from repro.telemetry import Tracer
    ck = str(tmp_path / "ck")
    _train_src("full", ck)

    same = _make("full", 8, ck)
    tr = Tracer()
    same.trainer.telemetry = tr
    same.restore()
    assert [e.name for e in tr.events if e.name.startswith("train.")] \
        == ["train.restore"]
    assert "reshard.bytes_moved" not in tr.counters

    dst = _make("full", 4, ck)
    tr = Tracer()
    dst.trainer.telemetry = tr
    dst.restore(reshard=True)
    by_name = {e.name: e for e in tr.events}
    assert "train.reshard" in by_name and "train.restore" in by_name
    assert by_name["train.reshard"].depth \
        == by_name["train.restore"].depth + 1
    assert tr.counters["reshard.bytes_moved"] > 0
    assert dst.trainer.last_reshard["bytes_moved"] \
        == tr.counters["reshard.bytes_moved"]
    assert "8->4" in dst.trainer.last_reshard["plan"]


# ---------------------------------------------------------------------------
# the shrink/grow recovery leg (repro.resilience)
# ---------------------------------------------------------------------------


def test_elastic_kill_and_recover(tmp_path):
    def factory(n):
        return lambda ck: _make("full", n, ck)

    # the hybrid head gradient's effective scale is proportional to the
    # ring size (grad-inside-shard_map psum transpose — see the harness
    # docstring), so the victim's 8-ring pre-kill steps follow a slightly
    # different trajectory than the 4-ring reference; measured gap on this
    # config is <= 3.3e-2 per overlapping step
    rep = elastic_kill_and_recover(
        factory(8), factory(4), total_steps=8, kill_at=6,
        ckpt_dir=str(tmp_path / "ck"), head="full/8->4",
        fit_kw={"use_fccs_batch": False}, loss_tol=0.15)
    assert rep.restored_step == 4 and rep.steps_replayed == 2
    assert rep.reshard_bytes_moved > 0
    assert rep.reshard_s >= 0
    assert rep.src_mesh != rep.dst_mesh
    assert len(rep.resumed_history) == 4     # steps 4..7 on the dst mesh
    assert rep.ok, rep.summary()
    assert "reshard" in rep.summary()


# ---------------------------------------------------------------------------
# zoo (GSPMD) elastic restores
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("head", ["full", "mach"])
def test_zoo_elastic_restore(head, tmp_path):
    ck = str(tmp_path / "ck")
    hcfg = _hcfg(head)
    src = Experiment.from_config(
        system="zoo", arch="smollm_135m", reduced=True, head=hcfg,
        batch=8, seq=16, n_model=4, ckpt_dir=ck, ckpt_every=2, log_every=0)
    src.fit(4, lr=0.5)

    blocked = Experiment.from_config(
        system="zoo", arch="smollm_135m", reduced=True, head=hcfg,
        batch=8, seq=16, n_model=2, ckpt_dir=ck, log_every=0)
    with pytest.raises(ReshardError, match="reshard"):
        blocked.restore()

    assert blocked.restore(reshard=True) == 4
    a, b = src._snapshot(), blocked._snapshot()
    # padded vocab is identical here (512 divides both rings), so the
    # model tree — embedding rows included — moves bit-for-bit; mach rides
    # the keep-verbatim leg (B=64 divides 2)
    cmp = tree_compare({"model": a["model"], "head": a["head"],
                        "opt": a["opt"]},
                       {"model": b["model"], "head": b["head"],
                        "opt": b["opt"]})
    assert cmp["bitwise"], cmp["mismatches"]
    blocked.fit(6, lr=0.5)
    assert np.isfinite(blocked.history[-1]["loss"])


# ---------------------------------------------------------------------------
# launcher surface (satellite: --resume CKPT / --resume-reshard /
# --ckpt-keep validation)
# ---------------------------------------------------------------------------


def test_launcher_resume_args(tmp_path):
    from repro.launch.train import parse_args
    d = str(tmp_path / "ck")

    a = parse_args(["--resume", d])
    assert a.resume is True and a.ckpt_dir == d

    f = os.path.join(d, "ckpt_8.msgpack.zst")
    a = parse_args(["--resume", f])
    assert a.resume is True and a.ckpt_dir == d

    a = parse_args(["--resume-reshard", "--ckpt-dir", d])
    assert a.resume is True and a.resume_reshard

    with pytest.raises(SystemExit):
        parse_args(["--resume"])                       # no dir anywhere
    with pytest.raises(SystemExit):
        parse_args(["--resume", d, "--ckpt-dir", d + "2"])
    with pytest.raises(SystemExit):
        parse_args(["--ckpt-keep", "0"])
    with pytest.raises(SystemExit):
        parse_args(["--ckpt-keep", "-1"])
    assert parse_args([]).ckpt_keep is None
    assert parse_args(["--ckpt-keep", "3"]).ckpt_keep == 3


# ---------------------------------------------------------------------------
# 16-way growth (more devices than this process has) via a subprocess
# ---------------------------------------------------------------------------


def test_grow_to_16_subprocess(tmp_path):
    """Restoring onto MORE devices than the writing run (8 -> 16) needs a
    fresh process (device count is fixed at jax init); the child asserts
    bitwise head params for ALL SIX heads — dense rows re-partition
    exactly, sketch buckets are kept verbatim (16 | 64)."""
    heads = DENSE + SKETCH
    for head in heads:
        src = _train_src(head, str(tmp_path / f"ck_{head}"))
        np.save(str(tmp_path / f"w_{head}.npy"),
                _np(src.trainer._snapshot()["head"]["params"]))
    prog = f"""
import numpy as np
from repro.api.bootstrap import ensure_host_devices
ensure_host_devices(16)
import jax
from tests.test_elastic import _make, _np
tmp = {str(tmp_path)!r}
for head in {heads!r}:
    dst = _make(head, 16, f"{{tmp}}/ck_{{head}}")
    assert dst.restore(reshard=True) == 8
    w = _np(dst.trainer._snapshot()["head"]["params"])
    np.testing.assert_array_equal(w, np.load(f"{{tmp}}/w_{{head}}.npy"))
print("OK16")
"""
    env = dict(os.environ,
               PYTHONPATH=f"src:{os.getcwd()}:"
                          + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         cwd="/root/repo", capture_output=True, text=True)
    assert out.returncode == 0 and "OK16" in out.stdout, out.stderr
