"""End-to-end behaviour of the paper's system (hybrid-parallel trainer):
training convergence with full/KNN softmax heads, DGC-on convergence, FCCS
loop, head refresh cadence, eval/deploy path. These are the integration
tests for deliverable (b)/(c)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.api.heads import make_head
from repro.configs.base import (DGCConfig, FCCSConfig, HeadConfig,
                                ModelConfig, TrainConfig)
from repro.data.synthetic import ClassificationStream, lm_batch, sku_feature_batch
from repro.train import hybrid
from repro.train.trainer import PaperTrainer

N_CLASSES, D, B = 512, 64, 64


def _model_cfg():
    return ModelConfig(name="feats", family="feats", n_layers=0, d_model=D,
                       n_heads=0, n_kv_heads=0, d_ff=0,
                       vocab_size=N_CLASSES, dtype="float32")


def _train_cfg(**kw):
    return TrainConfig(optimizer="sgd", momentum=0.9,
                       dgc=kw.pop("dgc", DGCConfig(enabled=False)), **kw)


@pytest.fixture(scope="module")
def stream():
    return ClassificationStream(N_CLASSES, D, seed=0)


def _run(mesh8, stream, impl, steps=80, dgc=None, n_micro=1, lr=4.0,
         active_frac=0.3):
    mcfg = _model_cfg()
    hcfg = HeadConfig(softmax_impl=impl, knn_k=16, knn_kprime=32,
                      active_frac=active_frac)
    tcfg = _train_cfg(dgc=dgc or DGCConfig(enabled=False))
    head = make_head(mcfg, hcfg)
    state = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8,
                              head=head)
    step = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, n_micro=n_micro,
                                  head=head, state_template=state)
    with jax.set_mesh(mesh8):
        state = hybrid.refresh_head_state(head, mesh8, state)
        losses = []
        metrics = {}
        for t in range(steps):
            inputs = sku_feature_batch(t, B, stream)
            state, loss, metrics = step(state, inputs, lr)
            losses.append(float(loss))
            if impl == "knn" and t == steps // 2:
                state = hybrid.refresh_head_state(head, mesh8, state)
        ev = hybrid.make_eval_step(mcfg, hcfg, mesh8, state, head=head)
        acc = float(ev(state, sku_feature_batch(10**6, 4 * B, stream)))
    return losses, acc, metrics


def test_full_softmax_trains(mesh8, stream):
    losses, acc, _ = _run(mesh8, stream, "full")
    assert losses[-1] < 0.5 * losses[0]
    assert acc > 0.4


def test_knn_softmax_matches_full(mesh8, stream):
    """Paper Table 2: KNN softmax tracks full softmax accuracy. The paper's
    lossless condition is M >= |union of label neighborhoods| — at this toy
    N/B ratio that needs active_frac 0.5 (benchmarks/table2 docstring)."""
    _, acc_full, _ = _run(mesh8, stream, "full", steps=150)
    _, acc_knn, m = _run(mesh8, stream, "knn", steps=150, active_frac=0.5)
    assert float(m["label_recall"]) == 1.0
    assert acc_knn > acc_full - 0.08, (acc_knn, acc_full)


def test_dgc_trains_without_accuracy_loss(mesh8):
    """Paper Table 5: sparsified training converges comparably. DGC acts on
    the FE (data-parallel) grads, so this uses a real LM trunk."""
    import dataclasses

    from tests.conftest import reduced_cfg
    cfg = dataclasses.replace(reduced_cfg("smollm_135m"),
                              tie_embeddings=False)
    hcfg = HeadConfig()
    losses = {}
    wire = {}
    for name, dgc in (("dense", DGCConfig(enabled=False)),
                      ("dgc", DGCConfig(enabled=True, sparsity=0.95,
                                        momentum=0.9, chunk=512))):
        tcfg = _train_cfg(dgc=dgc)
        state = hybrid.init_state(jax.random.PRNGKey(2), cfg, hcfg, tcfg, 8)
        step = hybrid.make_train_step(cfg, hcfg, tcfg, mesh8,
                                      state_template=state)
        ls = []
        with jax.set_mesh(mesh8):
            for t in range(25):
                state, loss, m = step(state, lm_batch(t, 16, 32,
                                                      cfg.vocab_size), 0.3)
                ls.append(float(loss))
        losses[name] = ls
        wire[name] = (float(m["comm_wire_bytes"]),
                      float(m["comm_dense_bytes"]))
    # both converge, comparably
    assert losses["dgc"][-1] < losses["dgc"][0]
    assert losses["dgc"][-1] < losses["dense"][-1] + 0.5
    # and DGC actually cut the wire bytes
    assert wire["dgc"][0] < 0.25 * wire["dgc"][1]


def test_microbatch_equals_oneshot(mesh8, stream):
    """§3.3.1 pipeline: micro-batched step == single-shot step (same grads)."""
    mcfg = _model_cfg()
    hcfg = HeadConfig()
    tcfg = _train_cfg()
    s1 = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8)
    s2 = hybrid.init_state(jax.random.PRNGKey(0), mcfg, hcfg, tcfg, 8)
    step1 = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, n_micro=1,
                                   state_template=s1)
    step4 = hybrid.make_train_step(mcfg, hcfg, tcfg, mesh8, n_micro=4,
                                   state_template=s2)
    inputs = sku_feature_batch(0, B, stream)
    with jax.set_mesh(mesh8):
        s1, l1, _ = step1(s1, inputs, 1.0)
        s2, l2, _ = step4(s2, inputs, 1.0)
    assert abs(float(l1) - float(l2)) < 1e-4
    dw = float(jnp.max(jnp.abs(s1.w_head - s2.w_head)))
    assert dw < 1e-4, dw


def test_paper_trainer_fccs_loop(mesh8, stream):
    """Driver: FCCS warmup + batch growth + head refresh, end to end."""
    mcfg = _model_cfg()
    hcfg = HeadConfig(softmax_impl="knn", knn_k=8, knn_kprime=16,
                      active_frac=0.3, rebuild_every=20)
    fcfg = FCCSConfig(eta0=4.0, t_warm=5, b0=B, b_min=B, b_max=4 * B,
                      t_ini=10, t_final=40)
    tcfg = TrainConfig(optimizer="sgd", fccs=fcfg)
    trainer = PaperTrainer(mcfg, hcfg, tcfg, mesh8,
                           lambda t, b: sku_feature_batch(t, b, stream),
                           hw_batch=B, log_every=0)
    hist = trainer.run(45)
    assert hist[-1]["batch"] == 4 * B          # cosine growth reached B_max
    assert hist[0]["batch"] == B
    assert hist[-1]["loss"] < hist[0]["loss"]
    acc = trainer.evaluate(sku_feature_batch(10**6, 2 * B, stream))
    assert acc > 0.2


def test_use_knn_backcompat_alias(mesh8, stream):
    """PaperTrainer(use_knn=True) still selects the knn head."""
    mcfg = _model_cfg()
    trainer = PaperTrainer(mcfg, HeadConfig(active_frac=0.3),
                           TrainConfig(optimizer="sgd"), mesh8,
                           lambda t, b: sku_feature_batch(t, b, stream),
                           hw_batch=B, use_knn=True, log_every=0)
    assert trainer.head_cfg.softmax_impl == "knn"
    assert trainer.head.name == "knn"


def test_lm_trunk_hybrid_training(mesh8):
    """The hybrid trainer also drives a small LM trunk (FE = transformer)."""
    from tests.conftest import reduced_cfg
    cfg = dataclasses.replace(reduced_cfg("smollm_135m"),
                              tie_embeddings=False)
    hcfg = HeadConfig()
    tcfg = _train_cfg()
    state = hybrid.init_state(jax.random.PRNGKey(1), cfg, hcfg, tcfg, 8)
    step = hybrid.make_train_step(cfg, hcfg, tcfg, mesh8, n_micro=1,
                                  state_template=state)
    with jax.set_mesh(mesh8):
        losses = []
        for t in range(10):
            inputs = lm_batch(t, 16, 32, cfg.vocab_size)
            state, loss, _ = step(state, inputs, 0.3)
            losses.append(float(loss))
    assert losses[-1] < losses[0]
