"""Hypothesis property tests on system invariants (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this container")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from jax.sharding import PartitionSpec as P

from repro.configs.base import FCCSConfig, ParallelConfig
from repro.core import fccs
from repro.core import knn_softmax as ks
from repro.core import sparsify as sp
from repro.kernels import ops
from repro.models.layers import multihead_attention
from repro.models.ssm import ssd_chunked
from repro.train.gspmd import fit_spec

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# divide-and-conquer top-k is exact for any (n, k, chunk)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n=st.integers(10, 5000), k=st.integers(1, 64),
       chunk=st.sampled_from([64, 256, 1024]), seed=st.integers(0, 2**16))
def test_topk_dc_always_exact(n, k, chunk, seed):
    k = min(k, n)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    v1, _ = ops.topk_dc(x, k, chunk=chunk)
    v2, _ = jax.lax.top_k(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))


# ---------------------------------------------------------------------------
# DGC conservation: sent + residual == velocity, always
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n=st.integers(8, 2000), sparsity=st.floats(0.5, 0.999),
       seed=st.integers(0, 2**16))
def test_dgc_conservation(n, sparsity, seed):
    from repro.configs.base import DGCConfig
    g = {"p": jax.random.normal(jax.random.PRNGKey(seed), (n,))}
    cfg = DGCConfig(enabled=True, sparsity=sparsity, momentum=0.7, chunk=64)
    st_ = sp.init_dgc_state(g)
    out, st2, _ = sp.dgc_exchange(g, st_, cfg)
    err = float(jnp.max(jnp.abs(out["p"] + st2.v["p"] - g["p"])))
    assert err < 1e-5


# ---------------------------------------------------------------------------
# Algorithm-1 selection: no duplicate active ids; self always selected
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(n_loc=st.integers(8, 64), b=st.integers(1, 16), k=st.integers(2, 8),
       seed=st.integers(0, 2**16))
def test_select_active_invariants(n_loc, b, k, seed):
    key = jax.random.PRNGKey(seed)
    # synthetic "self-first" graph on one shard covering all n_loc classes
    nbrs = jax.random.randint(key, (n_loc, k), 0, n_loc)
    nbrs = nbrs.at[:, 0].set(jnp.arange(n_loc))  # self first
    offsets = jnp.arange(n_loc + 1, dtype=jnp.int32) * k
    neighbors = nbrs.reshape(-1).astype(jnp.int32)
    y = jax.random.randint(jax.random.fold_in(key, 1), (b,), 0, n_loc)
    m_local = max(b, n_loc // 2)
    ids, valid = ks.select_active(y, offsets, neighbors, v_loc=n_loc,
                                  m_local=m_local, k_cap=k, pad_random=False)
    sel = np.asarray(ids)[np.asarray(valid)]
    assert len(set(sel.tolist())) == len(sel), "duplicate active ids"
    assert set(np.asarray(y).tolist()) <= set(sel.tolist()), "label missing"


# ---------------------------------------------------------------------------
# fit_spec: respects divisibility and never reuses a mesh axis
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(dims=st.lists(st.integers(1, 64), min_size=1, max_size=4),
       seed=st.integers(0, 100))
def test_fit_spec_invariants(dims, seed):
    par = ParallelConfig(mesh_shape=(2, 4), axis_names=("data", "model"))
    rng = np.random.default_rng(seed)
    options = [None, "data", "model", ("data", "model")]
    entries = [options[rng.integers(0, len(options))] for _ in dims]
    spec = fit_spec(P(*entries), tuple(dims), par)
    sizes = {"data": 2, "model": 4}
    used = []
    for d, e in zip(dims, tuple(spec)):
        names = (e,) if isinstance(e, str) else (e or ())
        n = 1
        for a in names:
            assert a not in used, "axis reused"
            used.append(a)
            n *= sizes[a]
        assert d % n == 0, "non-divisible sharding survived"


# ---------------------------------------------------------------------------
# FCCS: batch size monotone and bounded on any valid config
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(b0=st.integers(1, 512), mult=st.integers(2, 64),
       t_ini=st.integers(1, 50), dur=st.integers(2, 200))
def test_fccs_monotone_bounded(b0, mult, t_ini, dur):
    cfg = FCCSConfig(b0=b0, b_min=b0, b_max=b0 * mult, t_ini=t_ini,
                     t_final=t_ini + dur)
    prev = 0
    for t in range(0, t_ini + dur + 10, max(1, dur // 13)):
        b = fccs.batch_size(t, cfg)
        assert b0 <= b <= b0 * mult
        assert b >= prev
        prev = b


# ---------------------------------------------------------------------------
# flash attention == direct attention (any shape), incl. window
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([512, 1024, 2048]), hq=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]), window=st.sampled_from([None, 64, 300]),
       seed=st.integers(0, 2**16))
def test_flash_equals_direct(s, hq, g, window, seed):
    key = jax.random.PRNGKey(seed)
    hk = hq // g if hq % g == 0 else hq
    dh, b = 16, 1
    q = jax.random.normal(key, (b, s, hq, dh))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hk, dh))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hk, dh))
    pos = jnp.arange(s)
    flash = multihead_attention(q, k, v, q_positions=pos, k_positions=pos,
                                causal=True, window=window,
                                q_block=128, kv_block=128)
    direct = multihead_attention(q, k, v, q_positions=pos, k_positions=pos,
                                 causal=True, window=window,
                                 q_block=1 << 20, kv_block=1 << 20)
    np.testing.assert_allclose(np.asarray(flash), np.asarray(direct),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# SSD chunked scan == naive recurrence
# ---------------------------------------------------------------------------


def _ssd_naive(x, dt, A, B, C):
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    state = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        decay = jnp.exp(dt[:, t] * A)                       # [b,h]
        state = decay[:, :, None, None] * state + jnp.einsum(
            "bhn,bh,bhp->bhnp", Bh[:, t], dt[:, t],
            x[:, t].astype(jnp.float32))
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state))
    return jnp.stack(ys, axis=1), state


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([16, 33, 64]), chunk=st.sampled_from([8, 16]),
       seed=st.integers(0, 2**16))
def test_ssd_chunked_equals_naive(s, chunk, seed):
    key = jax.random.PRNGKey(seed)
    b, h, p, g, n = 2, 4, 8, 1, 8
    if s % chunk:
        s = (s // chunk + 1) * chunk  # ssd_chunked requires multiple
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (h,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 3), (b, s, g, n))
    C = jax.random.normal(jax.random.fold_in(key, 4), (b, s, g, n))
    y1, st1 = ssd_chunked(x, dt, A, B, C, chunk)
    y2, st2 = _ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=2e-4)


# ---------------------------------------------------------------------------
# checkpoint save -> restore is the identity on arbitrary nested pytrees
# ---------------------------------------------------------------------------


class _OptLike(__import__("typing").NamedTuple):
    """NamedTuple node, like the real optimizer state."""
    step: object
    mu: object
    nu: object


_DTYPES = [np.float32, np.float16, np.int32, np.int8, np.uint8, np.bool_]


def _np_leaf(draw):
    dtype = draw(st.sampled_from(_DTYPES))
    shape = tuple(draw(st.lists(st.integers(0, 4), min_size=0, max_size=3)))
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    if dtype == np.bool_:
        return rng.integers(0, 2, size=shape).astype(np.bool_)
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return rng.integers(info.min, int(info.max) + 1,
                            size=shape).astype(dtype)
    return rng.standard_normal(size=shape).astype(dtype)


@st.composite
def _pytrees(draw, depth=3):
    """Arbitrary nested dict/tuple/list/NamedTuple pytrees with mixed-dtype
    (and possibly empty / zero-length) array leaves."""
    if depth == 0 or draw(st.booleans()):
        return _np_leaf(draw)
    kind = draw(st.sampled_from(["dict", "tuple", "list", "ntuple"]))
    n = draw(st.integers(1, 3))
    kids = [draw(_pytrees(depth=depth - 1)) for _ in range(n)]
    if kind == "dict":
        return {f"k{i}": c for i, c in enumerate(kids)}
    if kind == "tuple":
        return tuple(kids)
    if kind == "list":
        return list(kids)
    while len(kids) < 3:
        kids.append(_np_leaf(draw))
    return _OptLike(*kids[:3])


@settings(max_examples=25, deadline=None)
@given(tree=_pytrees(), step=st.integers(0, 10**6))
def test_checkpoint_roundtrip_identity(tree, step):
    import shutil
    import tempfile

    from repro import checkpoint as ckpt_lib
    d = tempfile.mkdtemp(prefix="ckpt_prop_")
    try:
        ckpt_lib.save(d, tree, step=step)
        out, got_step = ckpt_lib.restore(d, tree, step)
        assert got_step == step
        fa = jax.tree_util.tree_flatten_with_path(tree)
        fb = jax.tree_util.tree_flatten_with_path(out)
        assert fa[1] == fb[1], "tree structure changed"
        for (pa, a), (_, b) in zip(fa[0], fb[0]):
            a, b = np.asarray(a), np.asarray(jax.device_get(b))
            assert a.dtype == b.dtype and a.shape == b.shape, pa
            assert a.tobytes() == b.tobytes(), pa
    finally:
        shutil.rmtree(d, ignore_errors=True)
