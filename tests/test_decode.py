"""Prefill -> decode continuation must equal the full forward pass, for
every architecture family (KV rotating buffers, SSM state carry, whisper
cross-attention caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, InputShape
from repro.models import decoder as dec_lib
from repro.models import encdec as encdec_lib
from repro.models import lm
from tests.conftest import reduced_cfg

S = 17  # deliberately not a multiple of chunk/window sizes


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_continuation_matches_full_forward(arch):
    cfg = reduced_cfg(arch)
    params = lm.init_model(jax.random.PRNGKey(1), cfg)
    inputs = lm.input_example(cfg, InputShape("t", S, 2, "train"),
                              jax.random.PRNGKey(1))
    h_full, _, _ = lm.backbone(params, cfg, inputs)
    window = lm.decode_window(cfg, S)
    pre = dict(inputs)
    pre["tokens"] = inputs["tokens"][:, :S - 1]
    pre.pop("labels", None)
    _, _, caches = lm.backbone(params, cfg, pre, want_cache=True,
                               cache_window=window)
    if cfg.family == "encdec":
        enc_out = encdec_lib.encode(params["encdec"], cfg,
                                    inputs["frames"].astype(jnp.float32))
        ck, cv = encdec_lib.build_cross_cache(params["encdec"], cfg, enc_out)
        pad = window - caches["k"].shape[2]
        padw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
        caches = {"k": jnp.pad(caches["k"], padw),
                  "v": jnp.pad(caches["v"], padw),
                  "cross_k": ck, "cross_v": cv}
    slots = dec_lib.init_cache_slots(cfg, window,
                                     prefill_positions=jnp.arange(S - 1))
    h_dec, _, _ = lm.decode(params, cfg,
                            {"token": inputs["tokens"][:, S - 1:S]},
                            caches, slots, window=window)
    err = float(jnp.max(jnp.abs(h_dec[:, 0] - h_full[:, -1])))
    assert err < 5e-4, f"{arch}: {err}"


@pytest.mark.parametrize("arch", ["smollm_135m", "mamba2_370m", "hymba_1_5b"])
def test_multi_step_decode_matches_forward(arch):
    """Decode 5 tokens sequentially == teacher forcing."""
    cfg = reduced_cfg(arch)
    params = lm.init_model(jax.random.PRNGKey(2), cfg)
    inputs = lm.input_example(cfg, InputShape("t", S, 2, "train"),
                              jax.random.PRNGKey(2))
    h_full, _, _ = lm.backbone(params, cfg, inputs)
    window = lm.decode_window(cfg, S)
    n_pre = S - 5
    _, _, caches = lm.backbone(params, cfg,
                               {"tokens": inputs["tokens"][:, :n_pre]},
                               want_cache=True, cache_window=window)
    slots = dec_lib.init_cache_slots(cfg, window,
                                     prefill_positions=jnp.arange(n_pre))
    for i in range(5):
        tok = inputs["tokens"][:, n_pre + i:n_pre + i + 1]
        h_dec, caches, slots = lm.decode(params, cfg, {"token": tok}, caches,
                                         slots, window=window)
        err = float(jnp.max(jnp.abs(h_dec[:, 0] - h_full[:, n_pre + i])))
        assert err < 5e-4, f"{arch} step {i}: {err}"


def test_sliding_window_decode_bounded_cache():
    """With a sliding window, the rotating cache gives the same result as an
    unwindowed run restricted to the window."""
    cfg = dataclasses.replace(reduced_cfg("smollm_135m"), sliding_window=8)
    params = lm.init_model(jax.random.PRNGKey(3), cfg)
    inputs = lm.input_example(cfg, InputShape("t", S, 2, "train"),
                              jax.random.PRNGKey(3))
    h_full, _, _ = lm.backbone(params, cfg, inputs)  # windowed full fwd
    window = lm.decode_window(cfg, S)
    assert window == 8
    _, _, caches = lm.backbone(params, cfg,
                               {"tokens": inputs["tokens"][:, :S - 1]},
                               want_cache=True, cache_window=window)
    slots = dec_lib.init_cache_slots(cfg, window,
                                     prefill_positions=jnp.arange(S - 1))
    h_dec, _, _ = lm.decode(params, cfg,
                            {"token": inputs["tokens"][:, S - 1:S]},
                            caches, slots, window=window)
    err = float(jnp.max(jnp.abs(h_dec[:, 0] - h_full[:, -1])))
    assert err < 5e-4, err
