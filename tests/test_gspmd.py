"""GSPMD zoo trainer: train/prefill/serve across families on the host mesh,
param sharding rules, vocab padding, KNN-softmax train variant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import (HeadConfig, InputShape, TrainConfig,
                                get_model_config, pad_vocab)
from repro.data.synthetic import lm_batch
from repro.models import lm
from repro.optim import make_optimizer
from repro.train import gspmd
from tests.conftest import reduced_cfg

ARCHS = ["smollm_135m", "qwen3_moe_30b_a3b", "mamba2_370m", "hymba_1_5b",
         "whisper_tiny", "gemma_2b"]


def _setup(arch, mesh, par):
    cfg = reduced_cfg(arch)
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    shards = gspmd.param_shardings(cfg, par, mesh)
    params = jax.tree.map(jax.device_put, params, shards)
    return cfg, params


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss(arch, mesh2x4, par2x4):
    with jax.set_mesh(mesh2x4):
        cfg, params = _setup(arch, mesh2x4, par2x4)
        tcfg = TrainConfig(optimizer="sgd")
        shape = InputShape("t", 32, 8, "train")
        opt = make_optimizer(tcfg)
        opt_state = opt.init(params)
        step = jax.jit(gspmd.make_train_step(cfg, HeadConfig(), par2x4, tcfg,
                                             mesh2x4, shape))
        # deterministic check: repeated steps on ONE batch reduce its loss
        inputs = lm_batch(0, 8, 32, cfg.vocab_size)
        if cfg.family == "encdec":
            inputs["frames"] = jax.random.normal(
                jax.random.PRNGKey(0), (8, cfg.enc_seq, cfg.d_model),
                jnp.float32)
        losses = []
        for t in range(4):
            params, opt_state, loss, metrics = step(params, opt_state,
                                                    inputs, 0.05)
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        assert all(np.isfinite(losses))


@pytest.mark.parametrize("arch", ["smollm_135m", "mamba2_370m",
                                  "qwen3_moe_30b_a3b"])
def test_serve_step_runs(arch, mesh2x4, par2x4):
    with jax.set_mesh(mesh2x4):
        cfg, params = _setup(arch, mesh2x4, par2x4)
        shape = InputShape("d", 64, 8, "decode")
        caches, slots, window = lm.init_decode_state(cfg, 8, 64)
        serve = jax.jit(gspmd.make_serve_step(cfg, par2x4, mesh2x4, shape))
        tok = jnp.zeros((8, 1), jnp.int32)
        for _ in range(3):
            tok, caches, slots = serve(params, caches, slots, tok)
        assert tok.shape == (8, 1)
        assert bool(jnp.all((tok >= 0) & (tok < cfg.vocab_size)))


def test_prefill_then_serve_consistent(mesh2x4, par2x4):
    """Greedy token from prefill equals teacher-forced argmax."""
    with jax.set_mesh(mesh2x4):
        cfg, params = _setup("smollm_135m", mesh2x4, par2x4)
        S, B = 16, 8
        shape = InputShape("p", S, B, "prefill")
        prefill = jax.jit(gspmd.make_prefill_step(cfg, par2x4, mesh2x4,
                                                  shape))
        inputs = {"tokens": lm_batch(0, B, S, cfg.vocab_size)["tokens"]}
        tok, caches = prefill(params, inputs)
        # reference: full forward + argmax over head at last position
        h, _, _ = lm.backbone(params, cfg, inputs)
        w = lm.head_weight(params, cfg)
        ref = jnp.argmax(h[:, -1, :] @ w.T, axis=-1)
        assert jnp.array_equal(tok, ref)


def test_vocab_padding_preserves_loss(mesh2x4, par2x4):
    """pad_vocab + n_valid masking: padded logits don't change the loss."""
    with jax.set_mesh(mesh2x4):
        cfg = reduced_cfg("smollm_135m")           # vocab 512, divisible
        cfgp = pad_vocab(dataclasses.replace(cfg, vocab_size=510), 8)
        assert cfgp.vocab_size == 512 and cfgp.real_vocab_size == 510
        params = lm.init_model(jax.random.PRNGKey(0), cfgp)
        loss_fn = gspmd.make_loss_fn(cfgp, HeadConfig(), par2x4, mesh2x4,
                                     global_tokens=8 * 32)
        inputs = lm_batch(0, 8, 32, 510)
        loss, _ = loss_fn(params, inputs)
        # poison the padded rows; loss must not move
        w = lm.head_weight(params, cfgp)
        params2 = jax.tree.map(lambda x: x, params)
        tbl = params2["embed"]["table"]
        params2["embed"]["table"] = tbl.at[510:].set(100.0)
        # padded tokens also flow through tied embedding only for ids >= 510
        loss2, _ = loss_fn(params2, inputs)
        assert abs(float(loss) - float(loss2)) < 1e-4


def test_knn_train_step_gspmd(mesh2x4, par2x4):
    """The paper's technique as a first-class zoo feature: KNN-softmax train
    step on an LM head."""
    import numpy as np

    from repro.core import knn_graph as kg
    with jax.set_mesh(mesh2x4):
        cfg = dataclasses.replace(reduced_cfg("smollm_135m"),
                                  tie_embeddings=False)
        params = lm.init_model(jax.random.PRNGKey(0), cfg)
        shards = gspmd.param_shardings(cfg, par2x4, mesh2x4)
        params = jax.tree.map(jax.device_put, params, shards)
        hcfg = HeadConfig(knn_k=8, active_frac=0.5)
        tcfg = TrainConfig(optimizer="sgd")
        shape = InputShape("t", 32, 8, "train")
        g = np.asarray(kg.knn_graph_ref(params["head"], 8))
        cg = kg.compress_graph(g, 4)
        opt = make_optimizer(tcfg)
        opt_state = opt.init(params)
        step = jax.jit(gspmd.make_train_step(cfg, hcfg, par2x4, tcfg,
                                             mesh2x4, shape, use_knn=True))
        inputs = lm_batch(0, 8, 32, cfg.vocab_size)
        params, opt_state, loss, metrics = step(
            params, opt_state, inputs, (cg.offsets, cg.neighbors, cg.ranks), 0.2)
        assert bool(jnp.isfinite(loss))
        assert float(metrics["label_recall"]) == 1.0


def test_param_shardings_respect_rules(par2x4, mesh2x4):
    cfg = reduced_cfg("qwen3_moe_30b_a3b")
    specs = gspmd.param_pspecs(cfg, par2x4)
    # expert weights sharded on the expert axis over "model"
    assert tuple(specs["blocks"]["moe"]["wi_gate"])[1] == "model"
    # embedding: vocab over model
    assert tuple(specs["embed"]["table"])[0] == "model"
