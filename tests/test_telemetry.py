"""repro.telemetry: span nesting under a fake clock, the disabled
tracer's zero-allocation guarantee, Chrome-trace round-trip, the analytic
comm ledger vs the compiled step's HLO, and JSONL sink append semantics
(ISSUE 9 / docs/telemetry.md)."""
import json

import pytest

from repro.telemetry import (NULL_TRACER, CommLedger, MetricsSink, Tracer,
                             train_step_ledger)
from repro.telemetry.tracer import _NullSpan


class FakeClock:
    """Deterministic ns clock: every read advances by ``tick_ns``."""

    def __init__(self, tick_ns: int = 1000):
        self.t = 0
        self.tick_ns = tick_ns

    def __call__(self) -> int:
        self.t += self.tick_ns
        return self.t


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_nesting_and_determinism_under_fake_clock():
    tr = Tracer(clock_ns=FakeClock(1000))
    with tr.span("outer"):
        with tr.span("inner", attrs={"k": 1}):
            pass
        with tr.span("inner"):
            pass
    # spans close inner-first; depth recorded at entry
    assert [(e.name, e.depth) for e in tr.events] == [
        ("inner", 1), ("inner", 1), ("outer", 0)]
    # fake clock: enter/exit each consume one 1000ns tick, so every
    # leaf span lasts exactly one tick and the outer one spans all reads
    inner1, inner2, outer = tr.events
    assert inner1.dur_ns == 1000 and inner2.dur_ns == 1000
    assert outer.start_ns == 1000 and outer.dur_ns == 5000
    # a second identical run produces identical events (determinism)
    tr2 = Tracer(clock_ns=FakeClock(1000))
    with tr2.span("outer"):
        with tr2.span("inner", attrs={"k": 1}):
            pass
        with tr2.span("inner"):
            pass
    assert tr2.events == tr.events


def test_span_stats_and_counters():
    tr = Tracer(clock_ns=FakeClock(500))
    for _ in range(3):
        with tr.span("step"):
            pass
    tr.add_span("step", start_ns=10_000, dur_ns=2_000)
    st = tr.span_stats("step")
    assert st["count"] == 4
    assert st["total_s"] == pytest.approx((3 * 500 + 2000) * 1e-9)
    assert tr.span_stats("absent") == {"count": 0, "total_s": 0.0}
    assert tr.count("steps") == 1.0
    assert tr.count("steps", 2.0) == 3.0
    tr.gauge("occupancy", 0.5)
    assert tr.counters["steps"] == 3.0 and tr.gauges["occupancy"] == 0.5


def test_null_tracer_is_zero_alloc_no_op():
    before = _NullSpan.instances
    for _ in range(10_000):
        with NULL_TRACER.span("hot", attrs=None):
            pass
        NULL_TRACER.count("hot.steps")
        NULL_TRACER.gauge("hot.g", 1)
        NULL_TRACER.log_metrics({"x": 1})
    # the module-level singleton is the ONLY instance ever made: the hot
    # loop above allocated zero spans
    assert _NullSpan.instances == before == 1
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.events == ()
    assert NULL_TRACER.span_stats("hot") == {"count": 0, "total_s": 0.0}
    assert NULL_TRACER.count("hot.steps") == 0.0
    NULL_TRACER.close()  # harmless


# ---------------------------------------------------------------------------
# chrome-trace export
# ---------------------------------------------------------------------------


def test_chrome_trace_round_trip(tmp_path):
    tr = Tracer(clock_ns=FakeClock(1000))
    with tr.span("train.step", attrs={"step": 0}):
        with tr.span("train.data"):
            pass
    tr.count("train.steps")
    tr.gauge("mem.peak_bytes.host_rss", 123)
    path = tr.write_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        loaded = json.load(f)
    assert loaded == tr.chrome_trace()
    events = loaded["traceEvents"]
    assert [e["name"] for e in events] == ["train.data", "train.step"]
    for e in events:
        assert e["ph"] == "X" and e["pid"] == 0 and e["tid"] == 0
    # µs timestamps from the ns clock; attrs + depth ride in args
    assert events[1]["ts"] == 1.0 and events[1]["dur"] == 3.0
    assert events[1]["args"] == {"depth": 0, "step": 0}
    assert events[0]["args"]["depth"] == 1
    assert loaded["counters"] == {"train.steps": 1.0}
    assert loaded["gauges"] == {"mem.peak_bytes.host_rss": 123}
    assert loaded["displayTimeUnit"] == "ms"


# ---------------------------------------------------------------------------
# metrics sink (JSONL)
# ---------------------------------------------------------------------------


def test_metrics_sink_appends_across_reopens(tmp_path):
    path = str(tmp_path / "metrics.jsonl")
    with MetricsSink(path) as sink:
        sink.write({"step": 0, "loss": 2.0})
        sink.write({"step": 1, "loss": 1.5})
        assert sink.n_rows == 2
    # a fresh sink on the same path APPENDS (resume semantics), never
    # truncates
    tr = Tracer(metrics_path=path)
    tr.log_metrics({"step": 2, "loss": 1.0})
    tr.close()
    with open(path) as f:
        rows = [json.loads(line) for line in f]
    assert [r["step"] for r in rows] == [0, 1, 2]


# ---------------------------------------------------------------------------
# comm-volume ledger
# ---------------------------------------------------------------------------


def test_ledger_bookkeeping():
    led = CommLedger()
    led.add("all-gather", "x", 100).add("all-reduce", "y", 50, count=2)
    pk = led.per_kind()
    assert pk["all-gather"] == {"bytes": 100.0, "count": 1}
    assert pk["all-reduce"] == {"bytes": 50.0, "count": 2}
    assert pk["total_bytes"] == led.total_bytes() == 150.0
    with pytest.raises(ValueError, match="unknown collective kind"):
        led.add("broadcast", "z", 1)
    with pytest.raises(ValueError, match="extend"):
        train_step_ledger(n_dev=4, rows=8, feat_dim=4, head="mach")
    # compare flags per-kind byte divergence and nothing else
    assert led.compare({"all-gather": {"bytes": 100.0},
                        "all-reduce": {"bytes": 50.0}}) == []
    bad = led.compare({"all-gather": {"bytes": 100.0},
                       "all-reduce": {"bytes": 75.0}})
    assert len(bad) == 1 and bad[0].startswith("all-reduce")
    # a kind only the measurement saw still diverges
    assert led.compare({"all-gather": {"bytes": 100.0},
                        "all-reduce": {"bytes": 50.0},
                        "all-to-all": {"bytes": 7.0}}) != []


@pytest.mark.parametrize("head,backend", [
    ("full", "ref"), ("full", "pallas"),
    ("knn", "ref"), ("knn", "pallas"),
])
def test_ledger_matches_compiled_hlo_mesh4(head, backend):
    """The analytic ledger must match the compiled hybrid train step's
    HLO collective bytes on a 4-device mesh (exact at n_micro=1)."""
    from repro.launch.dryrun import lower_paper_one

    r = lower_paper_one(classes=256, head=head, backend=backend,
                        batch=32, feat_dim=16, n_micro=1, n_dev=4)
    assert r["ledger_divergence"] == [], r["ledger_divergence"]
    assert r["ledger"]["total_bytes"] > 0
    # and the ledger total equals the HLO total within the same rtol
    meas = r["collectives"]["total_bytes"]
    assert meas == pytest.approx(r["ledger"]["total_bytes"], rel=0.02)


def test_ledger_matches_compiled_hlo_micro_pipeline():
    """n_micro > 1 runs the CE completion inside a scan; XLA CSE may
    merge a duplicate pmax, so the model is ~7% high — rtol 10%."""
    from repro.launch.dryrun import lower_paper_one

    r = lower_paper_one(classes=256, head="full", backend="ref",
                        batch=32, feat_dim=16, n_micro=2, n_dev=4)
    assert r["ledger_divergence"] == [], r["ledger_divergence"]


def test_ledger_fe_param_terms():
    """LM-style trunks add the backward reduce-scatter and the dense
    gradient exchange; the feats trunk (fe_param_count=0) charges
    neither."""
    feats = train_step_ledger(n_dev=4, rows=32, feat_dim=16)
    assert "reduce-scatter" not in feats.per_kind()
    lm = train_step_ledger(n_dev=4, rows=32, feat_dim=16,
                           fe_param_count=1000)
    pk = lm.per_kind()
    assert pk["reduce-scatter"]["bytes"] == 32 * 16 * 4 / 4
    labels = {e.label: e.bytes for e in lm.entries}
    assert labels["fe_grad_exchange"] == 4000.0
    with pytest.raises(ValueError, match="divisible"):
        train_step_ledger(n_dev=4, rows=33, feat_dim=16, n_micro=2)
