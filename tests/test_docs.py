"""Docs can't rot silently: the link checker passes on the committed docs
and actually fails on broken references."""
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CHECKER = ROOT / "scripts" / "check_docs.py"


def _run(*args):
    return subprocess.run([sys.executable, str(CHECKER), *args],
                          capture_output=True, text=True)


def test_docs_tree_exists():
    for name in ("architecture.md", "heads.md", "paper_map.md"):
        assert (ROOT / "docs" / name).exists(), name
    assert (ROOT / "README.md").exists()


def test_checked_docs_have_no_broken_references():
    res = _run()
    assert res.returncode == 0, res.stdout + res.stderr


def test_checker_catches_rot(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see `repro.api.heads` (fine), `repro.no.such_module`, "
                   "`scripts/does_not_exist.py` and [x](missing/file.md)\n")
    res = _run(str(bad))
    assert res.returncode == 1
    assert "repro.no.such_module" in res.stderr
    assert "scripts/does_not_exist.py" in res.stderr
    assert "missing/file.md" in res.stderr
    assert "repro.api.heads" not in res.stderr
