"""IVF serving-index tests: recall vs exact at the default nprobe,
bit-for-bit exactness at nprobe == n_clusters (both systems), ref-vs-pallas
rerank parity (kernel- and engine-level), the checkpoint round-trip /
weights_version lifecycle, and the facade argument validation."""
import dataclasses

import jax
import numpy as np
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.api import Experiment
from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import HeadConfig
from repro.kernels import ops
from repro.serving import IVFIndex
from repro.serving.index import default_n_clusters, default_nprobe
from repro.train import hybrid

W_HEADS = ["full", "knn", "selective", "sampled"]


def _head_cfg(impl, backend="ref"):
    return HeadConfig(softmax_impl=impl, backend=backend, active_frac=0.5,
                      knn_k=8, knn_kprime=16, sampled_n=64)


def _paper_exp(mesh, classes, feat_dim, head="full", backend="ref",
               batch=32, **kw):
    return Experiment.from_config(
        system="paper", classes=classes, feat_dim=feat_dim, batch=batch,
        mesh=mesh, head=_head_cfg(head, backend), log_every=0, **kw)


def _install_clustered_weights(exp, classes, feat_dim, *, offset=0.3,
                               seed=0):
    """Install tight clustered class weights (a converged-cosine-head
    stand-in — the quantizer needs cluster structure to index) and return
    the [classes, feat_dim] prototype matrix."""
    rng = np.random.default_rng(seed)
    n_cent = max(2, classes // 64)
    centers = rng.standard_normal((n_cent, feat_dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    protos = (centers[rng.integers(0, n_cent, classes)]
              + rng.standard_normal((classes, feat_dim)).astype(np.float32)
              * (offset / np.sqrt(feat_dim)))
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    protos = protos.astype(np.float32)
    v_pad = exp.state.head_params.shape[0]
    w_host = (np.pad(protos, ((0, v_pad - classes), (0, 0)))
              if v_pad != classes else protos)
    # head_params is uncommitted: device_put with the MESH sharding (the
    # state array's own sharding would commit to one device)
    w = jax.device_put(w_host, NamedSharding(exp.mesh, P(hybrid.AXIS, None)))
    exp.trainer.state = exp.trainer.state._replace(head_params=w)
    return protos


def _query_pool(protos, n, *, noise=0.1, seed=1):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, protos.shape[0], n)
    d = protos.shape[1]
    return (protos[labels]
            + rng.standard_normal((n, d)).astype(np.float32)
            * (noise / np.sqrt(d))).astype(np.float32)


# ---------------------------------------------------------------------------
# defaults + fit invariants
# ---------------------------------------------------------------------------


def test_defaults():
    assert default_n_clusters(4096) == 64
    assert default_n_clusters(1) == 1
    assert default_nprobe(64) == 2          # C/32 floor-ed at 2 probes
    assert default_nprobe(1) == 2           # resolve_nprobe clamps to C
    assert default_nprobe(320) == 10


def test_fit_packs_every_valid_row_once(mesh8):
    exp = _paper_exp(mesh8, classes=256, feat_dim=16)
    idx = exp.ivf_index(refit=True)
    v_loc = exp.state.head_params.shape[0] // 8
    assert idx.cap == -(-(5 * v_loc) // (4 * idx.n_clusters))
    assert int(idx.counts.sum()) == 256     # every valid row, exactly once
    m = np.asarray(jax.device_get(idx.members))
    for s in range(m.shape[0]):
        rows = m[s][m[s] >= 0]
        assert rows.size == np.unique(rows).size
    assert idx.resolve_nprobe() == min(2, idx.n_clusters)
    assert idx.resolve_nprobe(10 ** 9) == idx.n_clusters
    assert idx.resolve_nprobe(1) == 1


# ---------------------------------------------------------------------------
# retrieval quality
# ---------------------------------------------------------------------------


def test_recall_at_default_nprobe(mesh8):
    """recall@5 >= 0.95 vs the exact scan at the DEFAULT nprobe, on
    clustered weights + near-prototype queries (deterministic seeds)."""
    classes, d, mb, pool, k = 2048, 32, 32, 128, 5
    exp = _paper_exp(mesh8, classes=classes, feat_dim=d, batch=mb)
    protos = _install_clustered_weights(exp, classes, d)
    q = _query_pool(protos, pool)
    exact = exp.serving_engine(top_k=k, max_batch=mb, max_wait_ms=0.0,
                               cache=None)
    ivf = exp.serving_engine(top_k=k, max_batch=mb, max_wait_ms=0.0,
                             cache=None, index="ivf")
    recalls = []
    for b in range(0, pool, mb):
        ids_e = np.asarray(exact.step_fn(q[b:b + mb], mb)[0])
        ids_i = np.asarray(ivf.step_fn(q[b:b + mb], mb)[0])
        recalls += [len(set(ids_e[i]) & set(ids_i[i])) / k
                    for i in range(mb)]
    assert np.mean(recalls) >= 0.95


def test_nprobe_full_is_exact_paper(mesh8):
    """nprobe == n_clusters probes every cell; balanced packing drops no
    row, so the result is the exact scan bit-for-bit."""
    exp = _paper_exp(mesh8, classes=256, feat_dim=16, batch=8)
    idx = exp.ivf_index(refit=True)
    ids_e, sc_e = exp.serve(batch=8, top_k=5, return_scores=True)
    ids_i, sc_i = exp.serve(batch=8, top_k=5, return_scores=True,
                            index="ivf", nprobe=idx.n_clusters)
    np.testing.assert_array_equal(np.asarray(ids_e), np.asarray(ids_i))
    # scores agree to float accumulation order (gather+matvec vs gemm)
    np.testing.assert_allclose(np.asarray(sc_e), np.asarray(sc_i),
                               rtol=1e-6, atol=1e-6)


def test_nprobe_full_is_exact_zoo():
    exp = Experiment.from_config(
        system="zoo", arch="smollm_135m", reduced=True, batch=4, seq=32,
        head=_head_cfg("full"))
    idx = exp.ivf_index(refit=True)
    q = np.random.default_rng(0).standard_normal(
        (4, exp.model_cfg.d_model)).astype(np.float32)
    ids_e, sc_e = exp.serve(top_k=5, queries=q, return_scores=True)
    ids_i, sc_i = exp.serve(top_k=5, queries=q, return_scores=True,
                            index="ivf", nprobe=idx.n_clusters)
    np.testing.assert_array_equal(np.asarray(ids_e), np.asarray(ids_i))
    np.testing.assert_allclose(np.asarray(sc_e), np.asarray(sc_i),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# ref-vs-pallas rerank parity
# ---------------------------------------------------------------------------


def test_ivf_rerank_kernel_matches_ref():
    """ops.ivf_rerank == gather + lax.top_k, including -1 pad slots and a
    row whose candidate list is shorter than k (pads with id -1)."""
    rng = np.random.default_rng(0)
    b, v, d, a, k = 4, 64, 8, 12, 5
    f = rng.standard_normal((b, d)).astype(np.float32)
    w = rng.standard_normal((v, d)).astype(np.float32)
    cand = rng.integers(0, v, (b, a)).astype(np.int32)
    cand[0, 7:] = -1                        # padded row
    cand[1, 3:] = -1                        # fewer candidates than k
    vals, ids = ops.ivf_rerank(f, w, cand, k)
    vals, ids = np.asarray(vals), np.asarray(ids)
    for i in range(b):
        live = cand[i][cand[i] >= 0]
        sc = f[i] @ w[live].T
        order = np.argsort(-sc, kind="stable")[:k]
        np.testing.assert_allclose(vals[i][:live.size], sc[order],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(ids[i][:live.size], live[order])
        assert (ids[i][live.size:] == -1).all()


@pytest.mark.parametrize("head", W_HEADS)
def test_engine_backend_parity(mesh8, head):
    """The engine's IVF step returns identical ids for the ref and pallas
    rerank backends, for every W-head."""
    classes, d, mb = 256, 16, 8
    ids = {}
    for backend in ("ref", "pallas"):
        exp = _paper_exp(mesh8, classes=classes, feat_dim=d, head=head,
                         backend=backend, batch=mb)
        protos = _install_clustered_weights(exp, classes, d)
        q = _query_pool(protos, mb)
        eng = exp.serving_engine(top_k=3, max_batch=mb, max_wait_ms=0.0,
                                 cache=None, index="ivf")
        out_ids, out_vals = eng.step_fn(q, mb)
        ids[backend] = np.asarray(out_ids)
        vals = np.asarray(out_vals)
        assert ids[backend].shape == (mb, 3) and vals.shape == (mb, 3)
    np.testing.assert_array_equal(ids["ref"], ids["pallas"])


# ---------------------------------------------------------------------------
# lifecycle: checkpoint round-trip, version invalidation, refit
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_bitwise(mesh8, tmp_path):
    """state_to_save -> repro.checkpoint -> state_from_restore reproduces
    the index bitwise, and a restored index is installable (no refit)."""
    exp = _paper_exp(mesh8, classes=256, feat_dim=16, batch=8)
    idx = exp.ivf_index(refit=True)
    ckpt.save(str(tmp_path / "ivf"), idx.state_to_save(), step=0)
    tree, step = ckpt.restore(str(tmp_path / "ivf"), idx.state_to_save(),
                              step=0)
    assert step == 0
    back = IVFIndex.state_from_restore(tree, exp.mesh,
                                       model_axis=hybrid.AXIS)
    np.testing.assert_array_equal(np.asarray(jax.device_get(back.centroids)),
                                  np.asarray(jax.device_get(idx.centroids)))
    np.testing.assert_array_equal(np.asarray(jax.device_get(back.members)),
                                  np.asarray(jax.device_get(idx.members)))
    np.testing.assert_array_equal(back.counts, idx.counts)
    assert (back.n_clusters, back.cap, back.nprobe, back.iters,
            back.version) == (idx.n_clusters, idx.cap, idx.nprobe,
                              idx.iters, idx.version)
    exp.install_ivf_index(back)
    assert exp.ivf_index() is back          # fresh version -> no refit
    ids_a, _ = exp.serve(batch=8, top_k=3, return_scores=True, index="ivf")
    exp.install_ivf_index(idx)
    ids_b, _ = exp.serve(batch=8, top_k=3, return_scores=True, index="ivf")
    np.testing.assert_array_equal(np.asarray(ids_a), np.asarray(ids_b))


def test_refit_when_weights_version_moves(mesh8):
    exp = _paper_exp(mesh8, classes=256, feat_dim=16, batch=8)
    idx = exp.ivf_index()
    assert exp.ivf_index() is idx           # cached while version holds
    exp.fit(1, use_fccs_batch=False)
    idx2 = exp.ivf_index()
    assert idx2 is not idx                  # train step -> version moved
    assert idx2.version == tuple(exp.weights_version)
    assert exp.ivf_index(refit=True) is not idx2


def test_stale_index_not_served(mesh8):
    """The engine's step builder refits through exp.ivf_index(), so a
    serve after a train step never uses the stale index's version."""
    exp = _paper_exp(mesh8, classes=256, feat_dim=16, batch=8)
    exp.ivf_index()
    exp.fit(1, use_fccs_batch=False)
    exp.serve(batch=8, top_k=3, index="ivf")
    assert exp._ivf.version == tuple(exp.weights_version)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------


def test_index_requires_topk(mesh8):
    exp = _paper_exp(mesh8, classes=256, feat_dim=16, batch=8)
    with pytest.raises(ValueError, match="top-k"):
        exp.serve(batch=8, index="ivf")
    with pytest.raises(ValueError, match="unknown serving index"):
        exp.serve(batch=8, top_k=3, index="lsh")


def test_sketch_head_refused(mesh8):
    exp = _paper_exp(mesh8, classes=256, feat_dim=16, head="mach", batch=8)
    with pytest.raises(NotImplementedError, match="class matrix"):
        exp.ivf_index()


def test_restored_index_replaces_unfit(mesh8):
    """install_ivf_index on a fresh experiment (never fit) is the resumed-
    server path: serve uses the installed index without refitting."""
    exp = _paper_exp(mesh8, classes=256, feat_dim=16, batch=8)
    idx = exp.ivf_index(refit=True)
    exp2 = _paper_exp(mesh8, classes=256, feat_dim=16, batch=8)
    moved = dataclasses.replace(idx, version=tuple(exp2.weights_version))
    exp2.install_ivf_index(moved)
    assert exp2.ivf_index() is moved
