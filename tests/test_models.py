"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(<=2 layers, d_model<=512, <=4 experts) of the same family, run one forward
and one train step on CPU, assert output shapes and finiteness.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, InputShape, get_model_config
from repro.models import lm
from tests.conftest import reduced_cfg

SHAPE = InputShape("smoke", 32, 2, "train")


def _inputs(cfg):
    return lm.input_example(cfg, SHAPE, jax.random.PRNGKey(0))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = reduced_cfg(arch)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    h, aux, _ = lm.backbone(params, cfg, _inputs(cfg))
    assert h.shape == (SHAPE.global_batch, SHAPE.seq_len, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step_no_nans(arch):
    """One full CE train step (single device) decreases-or-equals loss and
    produces finite grads."""
    cfg = reduced_cfg(arch)
    params = lm.init_model(jax.random.PRNGKey(0), cfg)
    inputs = _inputs(cfg)

    def loss_fn(p):
        h, aux, _ = lm.backbone(p, cfg, inputs)
        f = h.reshape(-1, cfg.d_model).astype(jnp.float32)
        y = inputs["labels"].reshape(-1)
        w = lm.head_weight(p, cfg).astype(jnp.float32)
        logits = f @ w.T
        logz = jax.nn.logsumexp(logits, axis=-1)
        corr = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
        return jnp.mean(logz - corr) + aux

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss0))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    loss1 = loss_fn(params2)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0) + 1e-3


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact published dims."""
    expect = {
        "mamba2_370m": dict(n_layers=48, d_model=1024, vocab_size=50280),
        "kimi_k2_1t_a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                                n_kv_heads=8, vocab_size=163840),
        "qwen3_moe_30b_a3b": dict(n_layers=48, d_model=2048, n_heads=32,
                                  n_kv_heads=4, vocab_size=151936),
        "phi3_mini_3_8b": dict(n_layers=32, d_model=3072, n_heads=32,
                               n_kv_heads=32, d_ff=8192, vocab_size=32064),
        "qwen3_1_7b": dict(n_layers=28, d_model=2048, n_heads=16,
                           n_kv_heads=8, d_ff=6144, vocab_size=151936),
        "gemma_2b": dict(n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
                         d_ff=16384, vocab_size=256000, head_dim=256),
        "whisper_tiny": dict(n_layers=4, d_model=384, n_heads=6,
                             d_ff=1536, vocab_size=51865),
        "chameleon_34b": dict(n_layers=48, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=22016, vocab_size=65536),
        "smollm_135m": dict(n_layers=30, d_model=576, n_heads=9,
                            n_kv_heads=3, d_ff=1536, vocab_size=49152),
        "hymba_1_5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001),
    }
    moe = {"kimi_k2_1t_a32b": (384, 8), "qwen3_moe_30b_a3b": (128, 8)}
    ssm_state = {"mamba2_370m": 128, "hymba_1_5b": 16}
    for arch, fields in expect.items():
        cfg = get_model_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
        if arch in moe:
            assert (cfg.moe.n_experts, cfg.moe.top_k) == moe[arch]
        if arch in ssm_state:
            assert cfg.ssm.d_state == ssm_state[arch]
    assert get_model_config("kimi_k2_1t_a32b").d_ff == 2048


def test_kimi_is_a_trillion_params():
    cfg = get_model_config("kimi_k2_1t_a32b")
    sds = jax.eval_shape(lambda: lm.init_model(jax.random.PRNGKey(0), cfg))
    n = sum(l.size for l in jax.tree.leaves(sds))
    assert n > 0.9e12, f"{n/1e12:.2f}T"


def test_smollm_param_count():
    cfg = get_model_config("smollm_135m")
    sds = jax.eval_shape(lambda: lm.init_model(jax.random.PRNGKey(0), cfg))
    n = sum(l.size for l in jax.tree.leaves(sds))
    assert 1.2e8 < n < 1.5e8, n / 1e6
