"""``benchmarks.common.write_bench`` trajectory-file I/O contract.

The BENCH_<table>.json files at the repo root are append-only trajectories:
every PR's speed/accuracy claim appends one schema-versioned record.
These tests pin the parts a future schema bump or a crashed run could
silently break: old records survive appends verbatim, corrupt files are
refused WITHOUT being clobbered, and the trajectories already committed
in-repo keep parsing under the current schema.
"""
import json
import os

import pytest

from benchmarks.common import BENCH_SCHEMA, REPO_ROOT, write_bench


def test_schema_bump_keeps_legacy_records_verbatim(tmp_path):
    """A trajectory started under an older schema still accepts appends;
    the legacy record is byte-preserved and only NEW records carry the
    current schema version (readers dispatch per record, not per file)."""
    legacy = {"schema": 0, "table": "t", "payload": {"old_metric": 3.5}}
    (tmp_path / "BENCH_t.json").write_text(json.dumps([legacy]))
    write_bench("t", {"new_metric": 1.0}, root=str(tmp_path))
    records = json.loads((tmp_path / "BENCH_t.json").read_text())
    assert records[0] == legacy                    # untouched, un-upgraded
    assert records[1]["schema"] == BENCH_SCHEMA
    assert records[1]["payload"] == {"new_metric": 1.0}
    # and appending again under the current schema keeps both
    write_bench("t", {"new_metric": 2.0}, root=str(tmp_path))
    records = json.loads((tmp_path / "BENCH_t.json").read_text())
    assert [r.get("schema") for r in records] == [0, BENCH_SCHEMA,
                                                  BENCH_SCHEMA]


def test_append_to_corrupt_file_raises_and_preserves_it(tmp_path):
    """A half-written file (crashed run) must fail the append with a clear
    error AND survive byte-for-byte — the history is the deliverable."""
    p = tmp_path / "BENCH_x.json"
    p.write_text('[{"schema": 1, "truncated": ')
    before = p.read_text()
    with pytest.raises(ValueError, match="corrupt"):
        write_bench("x", {"a": 1}, root=str(tmp_path))
    assert p.read_text() == before


def test_append_to_non_array_raises_and_preserves_it(tmp_path):
    p = tmp_path / "BENCH_y.json"
    p.write_text('{"not": "a list"}')
    with pytest.raises(ValueError, match="trajectory"):
        write_bench("y", {}, root=str(tmp_path))
    assert json.loads(p.read_text()) == {"not": "a list"}


@pytest.mark.parametrize("fname", ["BENCH_serve.json", "BENCH_table3.json"])
def test_in_repo_trajectories_parse_under_current_schema(fname):
    """The trajectories committed by earlier PRs must stay readable: a
    JSON array of records whose schema is at most the current version,
    each carrying the keys the hillclimb tooling keys on."""
    path = os.path.join(REPO_ROOT, fname)
    records = json.loads(open(path).read())
    assert isinstance(records, list) and records
    table = fname[len("BENCH_"):-len(".json")]
    for r in records:
        assert r["table"] == table
        assert 0 <= r["schema"] <= BENCH_SCHEMA
        assert isinstance(r["payload"], dict) and r["payload"]
        assert "written" in r and "platform" in r and "n_devices" in r
