"""``benchmarks.common.write_bench`` trajectory-file I/O contract.

The BENCH_<table>.json files at the repo root are append-only trajectories:
every PR's speed/accuracy claim appends one schema-versioned record.
These tests pin the parts a future schema bump or a crashed run could
silently break: old records survive appends verbatim, corrupt files are
refused WITHOUT being clobbered, and the trajectories already committed
in-repo keep parsing under the current schema.
"""
import json
import os

import pytest

from benchmarks.common import (BENCH_SCHEMA, REPO_ROOT, check_regression,
                               comparable, git_rev, write_bench)


def test_schema_bump_keeps_legacy_records_verbatim(tmp_path):
    """A trajectory started under an older schema still accepts appends;
    the legacy record is byte-preserved and only NEW records carry the
    current schema version (readers dispatch per record, not per file)."""
    legacy = {"schema": 0, "table": "t", "payload": {"old_metric": 3.5}}
    (tmp_path / "BENCH_t.json").write_text(json.dumps([legacy]))
    write_bench("t", {"new_metric": 1.0}, root=str(tmp_path))
    records = json.loads((tmp_path / "BENCH_t.json").read_text())
    assert records[0] == legacy                    # untouched, un-upgraded
    assert records[1]["schema"] == BENCH_SCHEMA
    assert records[1]["payload"] == {"new_metric": 1.0}
    # and appending again under the current schema keeps both
    write_bench("t", {"new_metric": 2.0}, root=str(tmp_path))
    records = json.loads((tmp_path / "BENCH_t.json").read_text())
    assert [r.get("schema") for r in records] == [0, BENCH_SCHEMA,
                                                  BENCH_SCHEMA]


def test_append_to_corrupt_file_raises_and_preserves_it(tmp_path):
    """A half-written file (crashed run) must fail the append with a clear
    error AND survive byte-for-byte — the history is the deliverable."""
    p = tmp_path / "BENCH_x.json"
    p.write_text('[{"schema": 1, "truncated": ')
    before = p.read_text()
    with pytest.raises(ValueError, match="corrupt"):
        write_bench("x", {"a": 1}, root=str(tmp_path))
    assert p.read_text() == before


def test_append_to_non_array_raises_and_preserves_it(tmp_path):
    p = tmp_path / "BENCH_y.json"
    p.write_text('{"not": "a list"}')
    with pytest.raises(ValueError, match="trajectory"):
        write_bench("y", {}, root=str(tmp_path))
    assert json.loads(p.read_text()) == {"not": "a list"}


def test_records_stamp_git_rev(tmp_path):
    """Every appended record carries the short SHA of the tree it ran in
    (with ``-dirty`` when the checkout is modified) for traceability."""
    rev = git_rev()
    assert rev == "unknown" or 4 <= len(rev.replace("-dirty", "")) <= 40
    assert git_rev(root=str(tmp_path)) == "unknown"   # not a git checkout
    write_bench("g", {}, root=str(tmp_path))
    (rec,) = json.loads((tmp_path / "BENCH_g.json").read_text())
    assert rec["git_rev"] == rev


def _rec(payload, platform="cpu", n=8):
    return {"platform": platform, "n_devices": n, "payload": payload}


def test_comparable_requires_same_environment_and_config():
    a = _rec({"quick": False, "config": {"classes": 4096}})
    assert comparable(a, _rec({"quick": False, "config": {"classes": 4096}}))
    assert not comparable(a, _rec({"quick": True,
                                   "config": {"classes": 4096}}))
    assert not comparable(a, _rec({"quick": False,
                                   "config": {"classes": 256}}))
    assert not comparable(a, _rec(a["payload"], platform="tpu"))
    assert not comparable(a, _rec(a["payload"], n=16))


def test_check_regression_directions_and_threshold():
    prev = _rec({"p99_ms": 10.0, "qps": 100.0, "legs": {"a": 1.0, "b": 2.0}})
    metrics = {"p99_ms": "lower", "qps": "higher", "legs.*": "lower"}
    # within tolerance both ways
    ok = _rec({"p99_ms": 12.0, "qps": 90.0, "legs": {"a": 1.1, "b": 1.0}})
    assert check_regression(prev, ok, metrics, threshold=0.25) == []
    # cost grew / score shrank beyond tolerance
    bad = _rec({"p99_ms": 20.0, "qps": 50.0, "legs": {"a": 2.0, "b": 2.0}})
    fails = check_regression(prev, bad, metrics, threshold=0.25)
    assert len(fails) == 3
    assert any("p99_ms" in f for f in fails)
    assert any("qps" in f for f in fails)
    assert any("legs.a" in f for f in fails)


def test_check_regression_skips_absent_and_degenerate_metrics():
    """Absent legs, non-numeric values, and <= 0 baselines must not fail
    the gate — a benchmark that grew a new leg stays comparable."""
    prev = _rec({"p99_ms": 0.0, "note": "warm"})
    new = _rec({"p99_ms": 99.0, "note": "cold", "fresh_leg": 1.0})
    metrics = {"p99_ms": "lower", "note": "lower", "fresh_leg": "lower",
               "missing.deep": "higher"}
    assert check_regression(prev, new, metrics) == []


@pytest.mark.parametrize("fname", ["BENCH_serve.json", "BENCH_table3.json"])
def test_in_repo_trajectories_parse_under_current_schema(fname):
    """The trajectories committed by earlier PRs must stay readable: a
    JSON array of records whose schema is at most the current version,
    each carrying the keys the hillclimb tooling keys on."""
    path = os.path.join(REPO_ROOT, fname)
    records = json.loads(open(path).read())
    assert isinstance(records, list) and records
    table = fname[len("BENCH_"):-len(".json")]
    for r in records:
        assert r["table"] == table
        assert 0 <= r["schema"] <= BENCH_SCHEMA
        assert isinstance(r["payload"], dict) and r["payload"]
        assert "written" in r and "platform" in r and "n_devices" in r
