"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with shape
and dtype sweeps (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# topk_dc (divide-and-conquer top-k, paper Fig. 5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [100, 2048, 10000, 65536])
@pytest.mark.parametrize("k", [1, 16, 100])
def test_topk_dc_exact(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
    v1, i1 = ops.topk_dc(x, k, chunk=512)
    v2, i2 = ref.topk_flat_ref(x, min(k, n))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    assert set(np.asarray(i1).tolist()) == set(np.asarray(i2).tolist())


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_dc_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(7), (4096,)).astype(dtype)
    v1, i1 = ops.topk_dc(x, 32, chunk=256)
    v2, _ = ref.topk_flat_ref(x.astype(jnp.float32), 32)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-2)


@pytest.mark.parametrize("chunk", [128, 2048])
def test_topk_threshold_matches(chunk):
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (9999,)))
    for k in (1, 10, 500):
        t = ops.topk_threshold(x, k, chunk=chunk)
        vals, _ = jax.lax.top_k(x, k)
        assert float(t) == float(vals[-1])


# ---------------------------------------------------------------------------
# knn dist_topk (fused scoring + running top-k')
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nq,nk,d", [(64, 128, 32), (100, 300, 64),
                                     (128, 96, 16)])
def test_dist_topk_matches_ref(nq, nk, d):
    key = jax.random.PRNGKey(nq + nk)
    q = jax.random.normal(key, (nq, d))
    km = jax.random.normal(jax.random.fold_in(key, 1), (nk, d))
    v1, i1 = ops.dist_topk(q, km, 8, block_q=32, block_n=64, col_offset=100)
    v2, i2 = ref.dist_topk_ref(q, km, 8, col_offset=100)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert (np.sort(np.asarray(i1), 1) == np.sort(np.asarray(i2), 1)).all()


def test_dist_topk_kprime_exceeds_nk():
    q = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    km = jax.random.normal(jax.random.PRNGKey(1), (5, 8))
    v, i = ops.dist_topk(q, km, 8, block_q=16, block_n=128)
    assert ((i >= 0).sum(axis=1) == 5).all()  # only 5 real candidates


# ---------------------------------------------------------------------------
# fused streaming CE softmax (the paper's softmax-stage hotspot)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,d,v,bv", [(8, 16, 100, 32), (24, 32, 1000, 256),
                                      (16, 64, 512, 512)])
def test_fused_ce_forward(b, d, v, bv):
    key = jax.random.PRNGKey(b * v)
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.1
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    l1 = ops.fused_ce(f, w, y, 1.0, bv)
    l2 = ref.ce_loss_ref(f, w, y)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_fused_ce_grads():
    key = jax.random.PRNGKey(3)
    b, d, v = 24, 32, 1000
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.1
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    g1f, g1w = jax.grad(lambda f_, w_: ops.fused_ce(f_, w_, y, 1.0, 256),
                        argnums=(0, 1))(f, w)
    g2f, g2w = ref.ce_grads_ref(f, w, y)
    np.testing.assert_allclose(np.asarray(g1f), np.asarray(g2f), atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1w), np.asarray(g2w), atol=1e-6)


def test_fused_ce_scale():
    key = jax.random.PRNGKey(5)
    b, d, v = 8, 16, 128
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    l1 = ops.fused_ce(f, w, y, 4.0, 64)
    l2 = ref.ce_loss_ref(f, w, y, scale=4.0)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_fused_ce_stats_vs_ref():
    key = jax.random.PRNGKey(6)
    b, d, v = 8, 16, 100
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    m1, z1, c1 = ops.fused_ce_stats(f, w, y, block_v=32)
    m2, z2, c2 = ref.ce_stats_ref(f, w, y)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-5)
