"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with shape
and dtype sweeps (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# topk_dc (divide-and-conquer top-k, paper Fig. 5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [100, 2048, 10000, 65536])
@pytest.mark.parametrize("k", [1, 16, 100])
def test_topk_dc_exact(n, k):
    x = jax.random.normal(jax.random.PRNGKey(n + k), (n,))
    v1, i1 = ops.topk_dc(x, k, chunk=512)
    v2, i2 = ref.topk_flat_ref(x, min(k, n))
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    assert set(np.asarray(i1).tolist()) == set(np.asarray(i2).tolist())


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_dc_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(7), (4096,)).astype(dtype)
    v1, i1 = ops.topk_dc(x, 32, chunk=256)
    v2, _ = ref.topk_flat_ref(x.astype(jnp.float32), 32)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-2)


@pytest.mark.parametrize("chunk", [128, 2048])
def test_topk_threshold_matches(chunk):
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (9999,)))
    for k in (1, 10, 500):
        t = ops.topk_threshold(x, k, chunk=chunk)
        vals, _ = jax.lax.top_k(x, k)
        assert float(t) == float(vals[-1])


# ---------------------------------------------------------------------------
# knn dist_topk (fused scoring + running top-k')
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nq,nk,d", [(64, 128, 32), (100, 300, 64),
                                     (128, 96, 16)])
def test_dist_topk_matches_ref(nq, nk, d):
    key = jax.random.PRNGKey(nq + nk)
    q = jax.random.normal(key, (nq, d))
    km = jax.random.normal(jax.random.fold_in(key, 1), (nk, d))
    v1, i1 = ops.dist_topk(q, km, 8, block_q=32, block_n=64, col_offset=100)
    v2, i2 = ref.dist_topk_ref(q, km, 8, col_offset=100)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), rtol=1e-4,
                               atol=1e-4)
    assert (np.sort(np.asarray(i1), 1) == np.sort(np.asarray(i2), 1)).all()


def test_dist_topk_kprime_exceeds_nk():
    q = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    km = jax.random.normal(jax.random.PRNGKey(1), (5, 8))
    v, i = ops.dist_topk(q, km, 8, block_q=16, block_n=128)
    assert ((i >= 0).sum(axis=1) == 5).all()  # only 5 real candidates


# ---------------------------------------------------------------------------
# fused streaming CE softmax (the paper's softmax-stage hotspot)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,d,v,bv", [(8, 16, 100, 32), (24, 32, 1000, 256),
                                      (16, 64, 512, 512)])
def test_fused_ce_forward(b, d, v, bv):
    key = jax.random.PRNGKey(b * v)
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.1
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    l1 = ops.fused_ce(f, w, y, 1.0, bv)
    l2 = ref.ce_loss_ref(f, w, y)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_fused_ce_grads():
    key = jax.random.PRNGKey(3)
    b, d, v = 24, 32, 1000
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.1
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    g1f, g1w = jax.grad(lambda f_, w_: ops.fused_ce(f_, w_, y, 1.0, 256),
                        argnums=(0, 1))(f, w)
    g2f, g2w = ref.ce_grads_ref(f, w, y)
    np.testing.assert_allclose(np.asarray(g1f), np.asarray(g2f), atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1w), np.asarray(g2w), atol=1e-6)


def test_fused_ce_scale():
    key = jax.random.PRNGKey(5)
    b, d, v = 8, 16, 128
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    l1 = ops.fused_ce(f, w, y, 4.0, 64)
    l2 = ref.ce_loss_ref(f, w, y, scale=4.0)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_fused_ce_stats_vs_ref():
    key = jax.random.PRNGKey(6)
    b, d, v = 8, 16, 100
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    m1, z1, c1 = ops.fused_ce_stats(f, w, y, block_v=32)
    m2, z2, c2 = ref.ce_stats_ref(f, w, y)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-5)


# ---------------------------------------------------------------------------
# ce_shard_stats: limit masking / argmax / distributed-completion grads
# ---------------------------------------------------------------------------


def _masked_dense(f, w, y, n_valid, scale=1.0):
    s = f @ w.T * scale
    s = jnp.where(jnp.arange(w.shape[0])[None, :] < n_valid, s, -1e30)
    return s


@pytest.mark.parametrize("n_valid", [70, 100])
def test_ce_shard_stats_limit_and_amax(n_valid):
    key = jax.random.PRNGKey(11)
    b, d, v = 8, 16, 100
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d))
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, n_valid)
    m, z, corr, amax = ops.ce_shard_stats(
        f, w, y, jnp.asarray(n_valid, jnp.int32), 1.0, 32)
    s = _masked_dense(f, w, y, n_valid)
    np.testing.assert_allclose(np.asarray(m), np.asarray(jnp.max(s, 1)),
                               rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(z),
        np.asarray(jnp.sum(jnp.exp(s - jnp.max(s, 1)[:, None]), 1)),
        rtol=1e-4)
    assert (np.asarray(amax) == np.asarray(jnp.argmax(s, 1))).all()


def test_ce_shard_stats_grads_through_completion():
    """Grad-check the custom_vjp through a log/psum-style completion (the
    distributed tail) against dense autodiff, with vocab padding masked."""
    key = jax.random.PRNGKey(12)
    b, d, v, n_valid = 8, 16, 96, 80
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.3
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, n_valid)

    def loss_kernel(f_, w_):
        m, z, corr, _ = ops.ce_shard_stats(
            f_, w_, y, jnp.asarray(n_valid, jnp.int32), 2.0, 32)
        return jnp.mean(jnp.log(z) + m - corr)

    def loss_dense(f_, w_):
        s = _masked_dense(f_, w_, y, n_valid, scale=2.0)
        corr = jnp.take_along_axis(s, y[:, None], axis=1)[:, 0]
        return jnp.mean(jax.nn.logsumexp(s, axis=1) - corr)

    assert abs(float(loss_kernel(f, w)) - float(loss_dense(f, w))) < 1e-5
    g1 = jax.grad(loss_kernel, (0, 1))(f, w)
    g2 = jax.grad(loss_dense, (0, 1))(f, w)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# sparse_ce (fused active-class gather + CE)
# ---------------------------------------------------------------------------


def _sparse_setup(seed=13, b=10, d=16, v=100, a=37):
    key = jax.random.PRNGKey(seed)
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.3
    y = jax.random.randint(jax.random.fold_in(key, 2), (b,), 0, v)
    ids = jax.random.permutation(jax.random.fold_in(key, 3), v)[:a]
    ids = ids.at[0].set(y[0]).astype(jnp.int32)   # one guaranteed label hit
    valid = jnp.ones((a,), jnp.int32).at[5].set(0)
    bias = jax.random.normal(jax.random.fold_in(key, 4), (a,)) * 0.1
    return f, w, y, ids, valid, bias


@pytest.mark.parametrize("block_a", [8, 16, 128])
def test_sparse_ce_forward_vs_dense(block_a):
    f, w, y, ids, valid, bias = _sparse_setup()
    m, z, corr, amax = ops.sparse_ce_stats(
        f, w, ids, ids, bias, valid, y, 2.0, block_a, False)
    s = f @ w[ids].T * 2.0 + bias[None, :]
    s = jnp.where(valid[None, :] > 0, s, -jnp.inf)
    hit = (ids[None, :] == y[:, None]) & (valid[None, :] > 0)
    # corr counts the label column once (the ref path's argmax(hit)):
    first = hit & (jnp.cumsum(hit, axis=1) == 1)
    np.testing.assert_allclose(np.asarray(m), np.asarray(jnp.max(s, 1)),
                               rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(z),
        np.asarray(jnp.sum(jnp.where(valid[None, :] > 0,
                                     jnp.exp(s - jnp.max(s, 1)[:, None]),
                                     0.0), 1)), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(corr), np.asarray(jnp.sum(jnp.where(first, s, 0.0), 1)),
        rtol=1e-5, atol=1e-6)
    assert (np.asarray(amax) == np.asarray(jnp.argmax(s, 1))).all()


@pytest.mark.parametrize("block_a", [8, 64])
def test_sparse_ce_duplicate_label_hits_count_once(block_a):
    """Random-filler collisions can put the SAME label id in two candidate
    slots (select_active dedups fillers against chosen ids, not against
    each other). The ref path's argmax(hit) takes the label logit once;
    corr and the backward onehot must match — including across tile
    boundaries (block_a=8 splits the duplicates into different tiles)."""
    key = jax.random.PRNGKey(21)
    b, d, v = 6, 8, 40
    f = jax.random.normal(key, (b, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (v, d)) * 0.3
    y = jnp.asarray([7, 7, 3, 11, 0, 39])
    # label 7 appears at cols 2 and 12 (different tiles at block_a=8)
    ids = jnp.asarray([5, 1, 7, 9, 3, 11, 0, 2, 4, 6, 8, 10, 7, 12, 13, 14],
                      jnp.int32)
    valid = jnp.ones((16,), jnp.int32)
    bias = jnp.zeros((16,), jnp.float32)

    def loss_kernel(f_, w_):
        m, z, corr, _ = ops.sparse_ce_stats(
            f_, w_, ids, ids, bias, valid, y, 1.0, block_a, False)
        return jnp.mean(jnp.log(z) + m - corr)

    def loss_ref(f_, w_):
        s = f_ @ w_[ids].T
        hit = ids[None, :] == y[:, None]
        pos = jnp.argmax(hit, axis=1)          # FIRST hit column, like knn
        corr = jnp.where(jnp.any(hit, axis=1),
                         jnp.take_along_axis(s, pos[:, None], axis=1)[:, 0],
                         0.0)
        return jnp.mean(jax.nn.logsumexp(s, axis=1) - corr)

    assert abs(float(loss_kernel(f, w)) - float(loss_ref(f, w))) < 1e-5
    g1 = jax.grad(loss_kernel, (0, 1))(f, w)
    g2 = jax.grad(loss_ref, (0, 1))(f, w)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                               atol=1e-6)


def test_sparse_ce_grads_vs_dense_autodiff():
    """custom_vjp grad-check: fused gather+CE vs gather-then-dense-softmax
    autodiff (including the scatter-add back into the [V, D] shard)."""
    f, w, y, ids, valid, bias = _sparse_setup()

    def loss_kernel(f_, w_):
        m, z, corr, _ = ops.sparse_ce_stats(
            f_, w_, ids, ids, bias, valid, y, 2.0, 16, False)
        owned = jnp.any((ids[None, :] == y[:, None]) & (valid[None, :] > 0),
                        axis=1)
        return jnp.mean(jnp.log(z) + m - jnp.where(owned, corr, 0.0))

    def loss_dense(f_, w_):
        s = f_ @ w_[ids].T * 2.0 + bias[None, :]
        s = jnp.where(valid[None, :] > 0, s, -1e30)
        hit = (ids[None, :] == y[:, None]) & (valid[None, :] > 0)
        # first hit only (ref-path argmax semantics; ids may hold dupes)
        pos = jnp.argmax(hit, axis=1)
        corr = jnp.where(jnp.any(hit, axis=1),
                         jnp.take_along_axis(s, pos[:, None], axis=1)[:, 0],
                         0.0)
        return jnp.mean(jax.nn.logsumexp(s, axis=1) - corr)

    assert abs(float(loss_kernel(f, w)) - float(loss_dense(f, w))) < 1e-5
    g1 = jax.grad(loss_kernel, (0, 1))(f, w)
    g2 = jax.grad(loss_dense, (0, 1))(f, w)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                               atol=1e-6)


def test_sparse_ce_mask_hits():
    """mask_hits drops candidates equal to the row label from z (the
    sampled head's accidental-hit correction) — forward and backward."""
    f, w, y, ids, valid, bias = _sparse_setup()

    def loss_kernel(f_, w_):
        m, z, _, _ = ops.sparse_ce_stats(
            f_, w_, ids, ids, bias, valid, y, 1.0, 16, True)
        ly = jnp.einsum("bd,bd->b", f_, w_[y])
        mm = jax.lax.stop_gradient(jnp.maximum(m, ly))
        zt = (z * jnp.where(jnp.isfinite(m),
                            jnp.exp(jax.lax.stop_gradient(m) - mm), 0.0)
              + jnp.exp(ly - mm))
        return jnp.mean(jnp.log(zt) + mm - ly)

    def loss_dense(f_, w_):
        s = f_ @ w_[ids].T + bias[None, :]
        keep = (valid[None, :] > 0) & (ids[None, :] != y[:, None])
        s = jnp.where(keep, s, -1e30)
        ly = jnp.einsum("bd,bd->b", f_, w_[y])
        cat = jnp.concatenate([s, ly[:, None]], axis=1)
        return jnp.mean(jax.nn.logsumexp(cat, axis=1) - ly)

    assert abs(float(loss_kernel(f, w)) - float(loss_dense(f, w))) < 1e-5
    g1 = jax.grad(loss_kernel, (0, 1))(f, w)
    g2 = jax.grad(loss_dense, (0, 1))(f, w)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]),
                               atol=1e-6)


def test_sparse_ce_duplicate_ids_scatter():
    """Duplicate candidate ids must accumulate their weight grads (the
    scatter-add), exactly like dense autodiff through a duplicated gather."""
    f, w, y, _, _, _ = _sparse_setup(a=8)
    ids = jnp.asarray([3, 3, 7, 1, 3, 9, 7, 0], jnp.int32)
    valid = jnp.ones((8,), jnp.int32)
    bias = jnp.zeros((8,), jnp.float32)

    def loss_kernel(w_):
        m, z, _, _ = ops.sparse_ce_stats(
            f, w_, ids, jnp.arange(8, dtype=jnp.int32), bias, valid,
            jnp.full_like(y, -1), 1.0, 8, False)
        return jnp.mean(jnp.log(z) + m)

    def loss_dense(w_):
        s = f @ w_[ids].T
        return jnp.mean(jax.nn.logsumexp(s, axis=1))

    g1 = jax.grad(loss_kernel)(w)
    g2 = jax.grad(loss_dense)(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


# ---------------------------------------------------------------------------
# topk_rows (row-wise d&c selection for top-k serving)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,chunk", [(100, 5, 512), (3000, 7, 512),
                                       (4096, 16, 1024)])
def test_topk_rows_matches_lax(n, k, chunk):
    x = jax.random.normal(jax.random.PRNGKey(n), (6, n))
    v1, i1 = ops.topk_rows(x, k, chunk=chunk)
    v2, i2 = jax.lax.top_k(x, k)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2))
    assert (np.sort(np.asarray(i1), 1) == np.sort(np.asarray(i2), 1)).all()
