"""Checkpoint roundtrip, optimizers, loss scaling, baselines, HLO analyzer,
microbatch pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import baselines as bl
from repro.core.pipeline import microbatched_value_and_grad
from repro.optim import adam, apply_updates, lars, make_optimizer, sgd
from repro.optim.scale import (LossScaleState, dynamic_loss_scale,
                               scaled_grads)
from repro.optim.scale import init_loss_scale
from repro.roofline import hlo


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.optim.optimizers import OptState
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": OptState(step=jnp.asarray(7, jnp.int32),
                            mu={"w": jnp.ones((3, 4)) * 0.5}),
            "meta": [jnp.zeros((2,), jnp.bfloat16)]}
    path = str(tmp_path / "ck")
    ckpt.save(path, tree, step=42)
    assert ckpt.latest_step(path) == 42
    restored, step = ckpt.restore(path, tree)
    assert step == 42
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_multiple_steps(tmp_path):
    path = str(tmp_path / "ck")
    for s in (1, 5, 3):
        ckpt.save(path, {"x": jnp.asarray(float(s))}, step=s)
    assert ckpt.latest_step(path) == 5
    tree, s = ckpt.restore(path, {"x": jnp.asarray(0.0)})
    assert s == 5 and float(tree["x"]) == 5.0


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quadratic_converges(opt, lr, steps=300):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.full(3, 0.1)}  # nonzero: LARS trust needs ||w|| > 0
    state = opt.init(params)
    for _ in range(steps):
        g = {"x": 2 * (params["x"] - target)}
        upd, state = opt.update(g, state, params, lr)
        params = apply_updates(params, upd)
    return float(jnp.max(jnp.abs(params["x"] - target)))


@pytest.mark.parametrize("opt,lr", [(sgd(momentum=0.9), 0.05),
                                    (adam(), 0.1),
                                    (lars(trust_coef=0.05,
                                          weight_decay=0.0), 0.05)])
def test_optimizers_converge_quadratic(opt, lr):
    assert _quadratic_converges(opt, lr) < 0.05


def test_make_optimizer_dispatch():
    from repro.configs.base import TrainConfig
    for name in ("sgd", "lars", "adam"):
        make_optimizer(TrainConfig(optimizer=name))
    with pytest.raises(ValueError):
        make_optimizer(TrainConfig(optimizer="bogus"))


# ---------------------------------------------------------------------------
# loss scaling (paper's fp16 recipe)
# ---------------------------------------------------------------------------


def test_scaled_grads_match_unscaled():
    def loss_fn(p, x):
        return jnp.sum(p["w"] * x) ** 2, {}
    p = {"w": jnp.asarray([1.0, 2.0])}
    x = jnp.asarray([0.5, -1.0])
    (_, _), g1, finite = scaled_grads(loss_fn, p, x,
                                      scale=jnp.asarray(1024.0))
    g2 = jax.grad(lambda p_: loss_fn(p_, x)[0])(p)
    assert bool(finite)
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g2["w"]),
                               rtol=1e-5)


def test_dynamic_scale_shrinks_on_overflow_grows_on_success():
    st = init_loss_scale(1024.0)
    st2, apply = dynamic_loss_scale(st, jnp.asarray(False))
    assert float(st2.scale) == 512.0 and not bool(apply)
    st3 = st
    for _ in range(200):
        st3, _ = dynamic_loss_scale(st3, jnp.asarray(True),
                                    growth_interval=200)
    assert float(st3.scale) >= 2048.0


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


def test_selective_includes_labels():
    key = jax.random.PRNGKey(0)
    N, D, B = 128, 32, 16
    w = jax.random.normal(key, (N, D))
    f = jax.random.normal(jax.random.fold_in(key, 1), (B, D))
    y = jax.random.randint(jax.random.fold_in(key, 2), (B,), 0, N)
    tabs = bl.build_lsh_tables(jax.random.fold_in(key, 3), w, 4, 6)
    ids, valid = bl.selective_active(f, y, tabs, m=64, cap=16)
    assert bool(jnp.isin(y, ids[valid]).all())


def test_selective_is_lossy_vs_full():
    """LSH recall < 1: selective active set misses some true neighbors."""
    key = jax.random.PRNGKey(1)
    N, D, B = 256, 32, 8
    w = jax.random.normal(key, (N, D))
    f = jax.random.normal(jax.random.fold_in(key, 1), (B, D))
    y = jax.random.randint(jax.random.fold_in(key, 2), (B,), 0, N)
    tabs = bl.build_lsh_tables(jax.random.fold_in(key, 3), w, 2, 6)
    ids, valid = bl.selective_active(f, y, tabs, m=64, cap=8)
    assert int(valid.sum()) < N  # not all classes recalled


def test_mach_learns_buckets():
    key = jax.random.PRNGKey(2)
    N, D, B = 64, 16, 32
    head = bl.init_mach(key, N, D, n_buckets=16, n_rep=3)
    protos = jax.random.normal(jax.random.fold_in(key, 5), (N, D))
    wh = head.w
    for t in range(150):
        k = jax.random.fold_in(key, t)
        y = jax.random.randint(k, (B,), 0, N)
        f = protos[y] + 0.05 * jax.random.normal(jax.random.fold_in(k, 1),
                                                 (B, D))
        g = jax.grad(lambda w_: bl.mach_loss(bl.MACHHead(head.hashes, w_),
                                             f, y))(wh)
        wh = wh - 0.5 * g
    y = jnp.arange(32)
    f = protos[y]
    pred = bl.mach_predict(bl.MACHHead(head.hashes, wh), f)
    acc = float(jnp.mean((pred == y).astype(jnp.float32)))
    assert acc > 0.5  # learnable but lossy (paper: below full softmax)


# ---------------------------------------------------------------------------
# loop-aware HLO analyzer
# ---------------------------------------------------------------------------


def _cost_analysis(co):
    ca = co.cost_analysis()
    return ca[0] if isinstance(ca, list) else ca  # old jax wraps in a list


def test_hlo_loop_free_matches_cost_analysis():
    def g(x, w):
        return jax.nn.relu(x @ w)
    co = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 256), jnp.float32)).compile()
    a = hlo.analyze(co.as_text())
    assert a.flops == 2 * 64 * 128 * 256
    assert a.bytes == _cost_analysis(co)["bytes accessed"]


def test_hlo_scan_multiplies_trip_count():
    def g(x):
        def step(c, _):
            return c @ c, None
        y, _ = jax.lax.scan(step, x, None, length=7)
        return y
    co = jax.jit(g).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    a = hlo.analyze(co.as_text())
    assert a.flops == 7 * 2 * 128 ** 3
    # raw cost_analysis counts the body once (the bug we correct); the loop
    # counter contributes a couple of extra scalar flops
    assert _cost_analysis(co)["flops"] < 1.01 * 2 * 128 ** 3


def test_hlo_collectives_in_loops(mesh2x4):
    from jax.sharding import PartitionSpec as P

    def body(x):
        def step(c, _):
            return jax.lax.psum(c @ c, "model"), None
        y, _ = jax.lax.scan(step, x, None, length=5)
        return y
    fn = jax.shard_map(body, mesh=mesh2x4, in_specs=P(None, None),
                       out_specs=P(None, None))
    with jax.set_mesh(mesh2x4):
        co = jax.jit(fn).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    a = hlo.analyze(co.as_text())
    assert a.collectives["all-reduce"]["count"] == 5
    assert a.collectives["all-reduce"]["bytes"] == 5 * 64 * 64 * 4


# ---------------------------------------------------------------------------
# microbatch pipeline
# ---------------------------------------------------------------------------


def test_microbatched_grads_equal_full_batch():
    key = jax.random.PRNGKey(0)
    w = {"w": jax.random.normal(key, (8, 4))}
    x = jax.random.normal(jax.random.fold_in(key, 1), (16, 8))

    def loss_fn(p, inputs):
        return jnp.mean((inputs["x"] @ p["w"]) ** 2), {"m": jnp.zeros(())}

    (l1, _), g1 = microbatched_value_and_grad(loss_fn, w, {"x": x}, 1)
    (l4, _), g4 = microbatched_value_and_grad(loss_fn, w, {"x": x}, 4)
    assert abs(float(l1) - float(l4)) < 1e-6
    np.testing.assert_allclose(np.asarray(g1["w"]), np.asarray(g4["w"]),
                               atol=1e-6)
