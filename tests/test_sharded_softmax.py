"""Hybrid-parallel distributed softmax (paper §3.1) vs single-device oracle:
loss, gradients, cosine-normalized variant, vocab padding mask, distributed
greedy argmax."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import sharded_softmax as ss

MSPEC = {"accuracy": P(), "logz": P()}


def _make(mesh, B, cosine=0.0, n_valid=0, loss_only=False):
    """loss_only drops the metrics output — needed when differentiating
    THROUGH the shard_map (old-jax transpose chokes on the symbolic-zero
    cotangents of the stop-gradient'd metrics)."""
    body = functools.partial(ss.full_softmax_local, model_axis="model",
                             batch_axes=("data",), global_batch=B,
                             cosine_scale=cosine, n_valid=n_valid)
    if loss_only:
        return jax.shard_map(lambda f, y, w: body(f, y, w)[0], mesh=mesh,
                             in_specs=(P("data", None), P("data"),
                                       P("model", None)),
                             out_specs=P())
    return jax.shard_map(body, mesh=mesh,
                         in_specs=(P("data", None), P("data"),
                                   P("model", None)),
                         out_specs=(P(), dict(MSPEC)))


@pytest.fixture(scope="module")
def problem():
    key = jax.random.PRNGKey(0)
    kf, kw, ky = jax.random.split(key, 3)
    N, D, B = 64, 32, 16
    return (jax.random.normal(kf, (B, D)),
            jax.random.normal(kw, (N, D)),
            jax.random.randint(ky, (B,), 0, N))


@pytest.mark.parametrize("cosine", [0.0, 16.0])
def test_loss_matches_oracle(mesh2x4, problem, cosine):
    f, w, y = problem
    fn = _make(mesh2x4, f.shape[0], cosine)
    with jax.set_mesh(mesh2x4):
        loss, m = jax.jit(fn)(f, y, w)
    loss_ref, m_ref = ss.ce_ref(f, y, w, cosine_scale=cosine)
    assert abs(float(loss) - float(loss_ref)) < 1e-4
    assert abs(float(m["accuracy"]) - float(m_ref["accuracy"])) < 1e-6


def test_grads_match_oracle(mesh2x4, problem):
    f, w, y = problem
    fn = _make(mesh2x4, f.shape[0], loss_only=True)
    with jax.set_mesh(mesh2x4):
        gw = jax.jit(jax.grad(lambda w_: fn(f, y, w_)))(w)
        gf = jax.jit(jax.grad(lambda f_: fn(f_, y, w)))(f)
    gw_ref = jax.grad(lambda w_: ss.ce_ref(f, y, w_)[0])(w)
    gf_ref = jax.grad(lambda f_: ss.ce_ref(f_, y, w)[0])(f)
    assert float(jnp.max(jnp.abs(gw - gw_ref))) < 1e-5
    assert float(jnp.max(jnp.abs(gf - gf_ref))) < 1e-5


def test_fc_gradient_is_local(mesh2x4, problem):
    """The paper's key property: each shard's dW depends only on its own
    rows — rows outside a shard get exactly the oracle's rows (no mixing)."""
    f, w, y = problem
    fn = _make(mesh2x4, f.shape[0], loss_only=True)
    with jax.set_mesh(mesh2x4):
        gw = jax.jit(jax.grad(lambda w_: fn(f, y, w_)))(w)
    gw_ref = jax.grad(lambda w_: ss.ce_ref(f, y, w_)[0])(w)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref), atol=1e-5)


def test_vocab_padding_masked(mesh2x4):
    """Padded rows must not perturb Z: loss over padded W == loss over W."""
    key = jax.random.PRNGKey(1)
    N, NP, D, B = 60, 64, 32, 16
    f = jax.random.normal(key, (B, D))
    w = jax.random.normal(jax.random.fold_in(key, 1), (N, D))
    y = jax.random.randint(jax.random.fold_in(key, 2), (B,), 0, N)
    wp = jnp.concatenate([w, jnp.full((NP - N, D), 3.0)])  # poison pad rows
    fn = _make(mesh2x4, B, n_valid=N)
    with jax.set_mesh(mesh2x4):
        loss, _ = jax.jit(fn)(f, y, wp)
    loss_ref, _ = ss.ce_ref(f, y, w)
    assert abs(float(loss) - float(loss_ref)) < 1e-4


def test_distributed_greedy_argmax(mesh2x4, problem):
    f, w, y = problem
    body = functools.partial(ss.serve_logits_local, model_axis="model")
    fn = jax.shard_map(body, mesh=mesh2x4,
                       in_specs=(P("data", None), P("model", None)),
                       out_specs=(P("data"), P("data", "model")))
    with jax.set_mesh(mesh2x4):
        tok, logits = jax.jit(fn)(f, w)
    ref = jnp.argmax(f @ w.T, axis=-1)
    assert jnp.array_equal(tok, ref)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(f @ w.T), rtol=1e-5, atol=1e-5)
