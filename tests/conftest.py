# 8 fake host devices for the distributed (shard_map / GSPMD) tests.
# NOTE: deliberately NOT 512 — only launch/dryrun.py uses the production
# device count, per the dry-run spec. Must run before jax initializes.
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.configs.base import get_model_config  # noqa: E402


@pytest.fixture(scope="session")
def mesh8():
    """1-D ring mesh — the paper's hybrid-parallel layout."""
    from repro.train import hybrid
    return hybrid.make_hybrid_mesh(8)


@pytest.fixture(scope="session")
def mesh2x4():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(2, 4)


@pytest.fixture(scope="session")
def par2x4():
    from repro.launch.mesh import make_host_parallel_config
    return make_host_parallel_config(2, 4)


def reduced_cfg(arch: str):
    """Reduced smoke config in fp32 (CPU numerics)."""
    return dataclasses.replace(get_model_config(arch, reduced=True),
                               dtype="float32")
