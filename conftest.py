# Root conftest: make `repro` (src layout) and the `tests`/`benchmarks`
# packages importable regardless of how pytest is invoked.
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (os.path.join(_ROOT, "src"), _ROOT):
    if p not in sys.path:
        sys.path.insert(0, p)
