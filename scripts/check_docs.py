#!/usr/bin/env python
"""Docs link check: fail on references to modules/files that don't exist.

Scans the markdown docs (docs/*.md, README.md) for

  * relative markdown link targets — ``[text](path)``;
  * inline-code file references — `` `benchmarks/table2_knn_accuracy.py` ``
    and friends (anything path-shaped ending in .py/.sh/.md);
  * inline-code module references — `` `repro.api.heads` `` (dotted paths
    under ``src/``; a trailing attribute segment is allowed, so
    ``repro.api.heads.make_head`` resolves via the module prefix);

and exits non-zero naming every reference that doesn't resolve, so the
docs tree can't rot silently. Fenced code blocks are skipped (examples may
show hypothetical files); inline code is checked. Wired into
scripts/smoke.sh as the first pre-merge step.

  python scripts/check_docs.py [file.md ...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DEFAULT_DOCS = sorted(ROOT.glob("docs/*.md")) + [ROOT / "README.md"]

PATH_RE = re.compile(r"^[A-Za-z0-9_][A-Za-z0-9_./-]*\.(py|sh|md)$")
MODULE_RE = re.compile(r"^repro(\.[A-Za-z_][A-Za-z0-9_]*)+$")
INLINE_CODE_RE = re.compile(r"`([^`\n]+)`")
MD_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def strip_fenced_blocks(text: str) -> str:
    """Blank out fenced code blocks, preserving line numbers."""
    out, fenced = [], False
    for line in text.splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            out.append("")
        else:
            out.append("" if fenced else line)
    return "\n".join(out)


def module_resolves(dotted: str) -> bool:
    """``repro.a.b[.attr]`` -> src/repro/a/b.py, allowing one trailing
    attribute segment if it textually appears in the resolved module."""
    parts = dotted.split(".")
    for end in range(len(parts), 1, -1):
        base = ROOT / "src" / Path(*parts[:end])
        mod = (base.with_suffix(".py") if base.with_suffix(".py").exists()
               else base / "__init__.py")
        if not mod.exists():
            continue
        tail = parts[end:]
        if not tail:
            return True
        if len(tail) == 1 and re.search(
                rf"\b{re.escape(tail[0])}\b", mod.read_text()):
            return True
        return False
    return False


def check_file(path: Path) -> list[str]:
    rel = (path.relative_to(ROOT) if path.is_relative_to(ROOT) else path)
    text = strip_fenced_blocks(path.read_text())
    bad = []

    def exists(target: str) -> bool:
        return ((ROOT / target).exists()
                or (path.parent / target).exists())

    for lineno, line in enumerate(text.splitlines(), 1):
        for m in MD_LINK_RE.finditer(line):
            target = m.group(1)
            if "://" in target or target.startswith(("#", "mailto:")):
                continue
            target = target.split("#", 1)[0]
            if target and not exists(target):
                bad.append(f"{rel}:{lineno}: broken link target {target!r}")
        for m in INLINE_CODE_RE.finditer(line):
            tok = m.group(1).strip()
            if PATH_RE.match(tok):
                if not exists(tok):
                    bad.append(f"{rel}:{lineno}: missing file {tok!r}")
            elif MODULE_RE.match(tok):
                if not module_resolves(tok):
                    bad.append(f"{rel}:{lineno}: unresolvable module {tok!r}")
    return bad


def main(argv: list[str]) -> int:
    files = ([Path(a).resolve() for a in argv]
             if argv else [p for p in DEFAULT_DOCS if p.exists()])
    if not files:
        print("check_docs: no docs found", file=sys.stderr)
        return 1
    failures = []
    for path in files:
        failures.extend(check_file(path))
    for f in failures:
        print(f, file=sys.stderr)
    print(f"check_docs: {len(files)} files, {len(failures)} broken "
          f"references")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
