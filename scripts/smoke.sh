#!/usr/bin/env bash
# Pre-merge smoke gate: `Experiment` end-to-end for every registered softmax
# head on the paper system, plus the reduced zoo LM (train + serve).
# Runs in ~2 minutes on the 8-fake-device CPU container.
#
#   bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

for head in full knn selective mach; do
  lr=2.0
  [ "$head" = mach ] && lr=0.3   # raw-logit bucket CE wants a cooler LR
  echo "=== paper / $head head ==="
  python -m repro.launch.train --system paper --devices 8 --head "$head" \
      --classes 512 --steps 8 --batch 32 --lr "$lr"
done

echo "=== zoo / smollm_135m (reduced) train ==="
python -m repro.launch.train --system zoo --devices 8 --arch smollm_135m \
    --reduced --steps 4 --batch 16 --seq 32 --lr 0.5

echo "=== zoo / smollm_135m (reduced) serve ==="
python -m repro.launch.serve --devices 8 --arch smollm_135m --reduced \
    --prompt-len 16 --gen 8 --batch 4

echo "smoke OK"
