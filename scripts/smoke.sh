#!/usr/bin/env bash
# Pre-merge smoke gate: `Experiment` end-to-end for every registered softmax
# head on the paper system AND through the zoo (GSPMD) registry path, plus
# the reduced zoo LM serve path and the docs link check.
# Runs in a few minutes on the 8-fake-device CPU container.
#
#   bash scripts/smoke.sh
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "=== docs link check ==="
python scripts/check_docs.py

for head in full knn selective mach sampled csoft; do
  lr=2.0
  case "$head" in
    mach|csoft) lr=0.3 ;;   # raw-logit bucket CE wants a cooler LR
  esac
  echo "=== paper / $head head ==="
  python -m repro.launch.train --system paper --devices 8 --head "$head" \
      --classes 512 --steps 8 --batch 32 --lr "$lr"
done

# pallas-backend leg: the fused-kernel hot path (interpret mode on CPU) must
# train and serve the same heads the ref backend does — backend parity is
# gated pre-merge (full = fused streaming CE, knn = sparse CE + dist_topk
# graph build, topk = d&c top-k serving)
for head in full knn; do
  echo "=== paper / $head head / pallas backend ==="
  python -m repro.launch.train --system paper --devices 8 --head "$head" \
      --backend pallas --classes 512 --steps 4 --batch 32 --lr 2.0
done
echo "=== paper / top-5 serve / pallas backend ==="
python -m repro.launch.serve --devices 8 --system paper --classes 512 \
    --head full --batch 16 --topk 5 --backend pallas

# resilience leg: train 4 steps, kill, resume 4 in a fresh experiment, and
# demand bitwise equality with an uninterrupted 8-step reference run
# (docs/resilience.md; the full per-head matrix is tests/test_resilience.py)
echo "=== resilience / kill-and-resume (full + knn) ==="
CKPT_TMP=$(mktemp -d)
python - "$CKPT_TMP" <<'EOF'
import sys

from repro.api.bootstrap import ensure_host_devices
ensure_host_devices(8)

from repro.api import Experiment
from repro.configs.base import HeadConfig
from repro.resilience import kill_and_recover

for head in ("full", "knn"):
    def make_exp(ckpt_dir, head=head):
        return Experiment.from_config(
            system="paper", classes=256, feat_dim=32, batch=16,
            head=HeadConfig(softmax_impl=head, knn_k=8, knn_kprime=16,
                            rebuild_every=5),
            ckpt_dir=ckpt_dir, ckpt_every=4, log_every=0)
    rep = kill_and_recover(
        make_exp, total_steps=8, kill_at=4,
        ckpt_dir=f"{sys.argv[1]}/{head}", head=head,
        fit_kw={"use_fccs_batch": False})
    print(rep.summary())
    assert rep.ok, rep.summary()
EOF
rm -rf "$CKPT_TMP"

# elastic leg (repro.elastic, docs/resilience.md): a checkpoint written on
# the 8-way ring restores onto a SHRUNK (4) and a GROWN (16) mesh —
# reshard=True re-partitions the rows — with bitwise dense-head serve
# parity and decode-equivalent mach retrieval; each mesh size needs its
# own process (device count is fixed before jax initializes)
echo "=== elastic / 8-way ckpt -> 4- and 16-way reshard + serve parity ==="
ELASTIC_TMP=$(mktemp -d)
python - "$ELASTIC_TMP" <<'EOF'
import sys
import numpy as np
from repro.api.bootstrap import ensure_host_devices
ensure_host_devices(8)
from repro.api import Experiment
from repro.configs.base import HeadConfig

for head in ("full", "mach"):
    exp = Experiment.from_config(
        system="paper", classes=256, feat_dim=32, batch=16,
        head=HeadConfig(softmax_impl=head, knn_k=8, knn_kprime=16,
                        rebuild_every=5, mach_b=64, mach_r=2),
        ckpt_dir=f"{sys.argv[1]}/{head}", ckpt_every=4, log_every=0)
    exp.fit(4, use_fccs_batch=False)
    x = exp.data_fn(10**6, 16)
    if head == "full":
        ids, sc = exp.serve(x, top_k=5, return_scores=True)
    else:  # sketch heads decode greedily (no [V, D] matrix to top-k)
        ids, sc = exp.serve(x), np.zeros(())
    np.savez(f"{sys.argv[1]}/{head}_ref.npz", ids=np.asarray(ids),
             sc=np.asarray(sc))
print("elastic: 8-way source checkpoints + serve references written")
EOF
for n in 4 16; do
  python - "$ELASTIC_TMP" "$n" <<'EOF'
import sys
import numpy as np
from repro.api.bootstrap import ensure_host_devices
n = int(sys.argv[2])
ensure_host_devices(n)
from repro.api import Experiment
from repro.configs.base import HeadConfig

for head in ("full", "mach"):
    exp = Experiment.from_config(
        system="paper", classes=256, feat_dim=32, batch=16,
        head=HeadConfig(softmax_impl=head, knn_k=8, knn_kprime=16,
                        rebuild_every=5, mach_b=64, mach_r=2),
        ckpt_dir=f"{sys.argv[1]}/{head}", ckpt_every=4, log_every=0)
    assert exp.restore(reshard=True) == 4
    x = exp.data_fn(10**6, 16)
    ref = np.load(f"{sys.argv[1]}/{head}_ref.npz")
    if head == "full":  # dense ids AND scores are bitwise across meshes
        ids, sc = exp.serve(x, top_k=5, return_scores=True)
        np.testing.assert_array_equal(np.asarray(sc), ref["sc"])
    else:  # sketch decode equivalence (buckets kept verbatim: 4|64, 16|64)
        ids = exp.serve(x)
    np.testing.assert_array_equal(np.asarray(ids), ref["ids"])
    print(f"elastic 8->{n} / {head}: restored step 4, serve parity OK "
          f"(bytes_moved={exp.trainer.last_reshard['bytes_moved']:.0f})")
EOF
done

# launcher path: --resume-reshard continues an 8-ring run on a 4-ring to
# the full step budget
echo "=== elastic / launcher --resume-reshard continuation (8 -> 4) ==="
python -m repro.launch.train --system paper --devices 8 --head full \
    --classes 256 --feat-dim 32 --steps 4 --batch 16 --lr 2.0 \
    --ckpt-dir "$ELASTIC_TMP/launch" --ckpt-every 4
python -m repro.launch.train --system paper --devices 4 --head full \
    --classes 256 --feat-dim 32 --steps 8 --batch 16 --lr 2.0 \
    --ckpt-dir "$ELASTIC_TMP/launch" --ckpt-every 4 --resume-reshard
rm -rf "$ELASTIC_TMP"

# serving tier: tiny load replays (full-softmax retrieval + a sketch head)
# through the coalescing/caching engine; BENCH_serve.json goes to a temp
# dir so smoke never dirties the committed perf trajectory
echo "=== serving tier / load replay (full + csoft) ==="
BENCH_TMP=$(mktemp -d)
trap 'rm -rf "$BENCH_TMP"' EXIT
PYTHONPATH=src:. python benchmarks/serve_replay.py --quick --head full \
    --out "$BENCH_TMP"
PYTHONPATH=src:. python benchmarks/serve_replay.py --quick --head csoft \
    --topk 0 --out "$BENCH_TMP"
python - "$BENCH_TMP" <<'EOF'
import json, sys
records = json.load(open(sys.argv[1] + "/BENCH_serve.json"))
assert len(records) == 2, f"expected 2 replay records, got {len(records)}"
for rec in records:
    for mode in ("uncached", "cached"):
        r = rec["payload"][mode]
        assert r["p99_ms"] > 0.0, (mode, r)
        assert 0.0 <= r["cache_hit_rate"] <= 1.0, (mode, r)
    assert rec["payload"]["cached"]["cache_hit_rate"] > 0.0
print("BENCH_serve.json: p99 + cache hit-rate fields OK")
EOF

# perf regression gate (benchmarks/run.py --check): fresh quick records go
# under $BENCH_TMP and are compared against the committed repo-root
# BENCH_*.json trajectories. The table3 leg runs with telemetry disabled at
# a 2% threshold — it is the proof that the tracing seam costs the hot
# path ~nothing; serve runs at the default 25% wall-clock tolerance.
echo "=== perf gate / table3 + serve vs committed BENCH records ==="
PYTHONPATH=src:. python -m benchmarks.run --quick --only table3 --check \
    --check-threshold 0.02 --bench-root "$BENCH_TMP"
PYTHONPATH=src:. python -m benchmarks.run --quick --only serve --check \
    --bench-root "$BENCH_TMP"

# telemetry leg (docs/telemetry.md): a tiny traced run must emit a
# Chrome-trace whose train.step span count matches the steps run, plus one
# JSONL metrics row per step
echo "=== telemetry / trace + metrics emission ==="
python -m repro.launch.train --system paper --devices 8 --head full \
    --classes 256 --steps 5 --batch 16 \
    --trace-out "$BENCH_TMP/trace.json" \
    --metrics-out "$BENCH_TMP/metrics.jsonl"
python - "$BENCH_TMP" <<'EOF'
import json, sys
trace = json.load(open(sys.argv[1] + "/trace.json"))
steps = [e for e in trace["traceEvents"] if e["name"] == "train.step"]
assert len(steps) == 5, f"expected 5 train.step spans, got {len(steps)}"
assert trace["counters"]["train.steps"] == 5.0, trace["counters"]
rows = [json.loads(l) for l in open(sys.argv[1] + "/metrics.jsonl")]
assert len(rows) == 5, f"expected 5 metrics rows, got {len(rows)}"
print("telemetry: trace parses, 5 train.step spans, 5 metrics rows OK")
EOF

# IVF serving index: full + knn heads through the ref AND pallas rerank
# backends on a tiny config — recall vs the exact scan at the default
# nprobe, and bitwise id equality when every cell is probed
echo "=== serving tier / IVF index (full + knn, ref + pallas) ==="
PYTHONPATH=src:. python - <<'EOF'
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.api import Experiment
from repro.configs.base import HeadConfig
from repro.train import hybrid

classes, d, mb, k = 1024, 16, 16, 5
rng = np.random.default_rng(0)
centers = rng.standard_normal((classes // 64, d)).astype(np.float32)
centers /= np.linalg.norm(centers, axis=1, keepdims=True)
protos = centers[rng.integers(0, len(centers), classes)] + \
    rng.standard_normal((classes, d)).astype(np.float32) * (0.3 / np.sqrt(d))
protos /= np.linalg.norm(protos, axis=1, keepdims=True)
protos = protos.astype(np.float32)
q = (protos[rng.integers(0, classes, mb)] +
     rng.standard_normal((mb, d)).astype(np.float32) * (0.1 / np.sqrt(d))
     ).astype(np.float32)
for head in ("full", "knn"):
    for backend in ("ref", "pallas"):
        exp = Experiment.from_config(
            system="paper", classes=classes, feat_dim=d, batch=mb,
            head=HeadConfig(softmax_impl=head, backend=backend,
                            knn_k=8, knn_kprime=16), log_every=0)
        w = jax.device_put(protos,
                           NamedSharding(exp.mesh, P(hybrid.AXIS, None)))
        exp.trainer.state = exp.trainer.state._replace(head_params=w)
        idx = exp.ivf_index(refit=True)
        exact = np.asarray(exp.serving_engine(
            top_k=k, max_batch=mb, max_wait_ms=0.0,
            cache=None).step_fn(q.copy(), mb)[0])
        ivf = np.asarray(exp.serving_engine(
            top_k=k, max_batch=mb, max_wait_ms=0.0, cache=None,
            index="ivf").step_fn(q.copy(), mb)[0])
        rec = np.mean([len(set(exact[i]) & set(ivf[i])) / k
                       for i in range(mb)])
        full_probe = np.asarray(exp.serving_engine(
            top_k=k, max_batch=mb, max_wait_ms=0.0, cache=None,
            index="ivf", nprobe=idx.n_clusters).step_fn(q.copy(), mb)[0])
        assert (full_probe == exact).all(), (head, backend)
        assert rec >= 0.9, (head, backend, rec)
        print(f"ivf {head}/{backend}: C={idx.n_clusters} cap={idx.cap} "
              f"nprobe={idx.nprobe} recall@{k}={rec:.3f} nprobe=C exact OK")
EOF

# zoo: the default full head plus the two newest registry heads (every head
# goes through the same gspmd.make_head_train_step seam)
for head in full sampled csoft; do
  echo "=== zoo / smollm_135m (reduced) train / $head head ==="
  python -m repro.launch.train --system zoo --devices 8 --arch smollm_135m \
      --reduced --head "$head" --steps 4 --batch 16 --seq 32 --lr 0.5
done

echo "=== zoo / smollm_135m (reduced) serve ==="
python -m repro.launch.serve --devices 8 --arch smollm_135m --reduced \
    --prompt-len 16 --gen 8 --batch 4

echo "smoke OK"
